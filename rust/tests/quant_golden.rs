//! Golden-value tests for the optimal-condition solvers: on closed-form
//! distributions the paper's optima are hand-derivable, so the solvers
//! must hit them to tight tolerance — not just beat the baselines.
//!
//! * **ORQ / Eq. (12)** on a uniform density collapses to the midpoint
//!   rule (Remark 1.1): evenly spaced levels. On two levels the solution
//!   is the support endpoints (Corollary 1.1).
//! * **BinGrad-b / Eq. (17)** is the 1-D 2-means (Lloyd/centroid) fixed
//!   point: conditional means around the threshold. Uniform[0,1] gives
//!   (0.25, 0.75) at threshold 0.5; a two-point distribution gives the
//!   two atoms exactly after one iteration.
//! * **BinGrad-pb / Eq. (15)** solves `b₁·∫₀^∞p = ∫_{b₁}^∞ v·p`. For a
//!   symmetric two-point ±a it gives b₁ = a exactly; for Uniform[−1,1]
//!   the quadratic `b/2 = (1−b²)/4` gives b₁ = √2 − 1.
//!
//! Tolerances: exact (≤ f32 epsilon) where the empirical solver sees the
//! atoms directly, ~2·10⁻³ on dense 4097-point grids (one grid step of
//! discretization error).

use orq::quant::bingrad::{BinGradB, BinGradPb};
use orq::quant::orq::{condition_residual, solve_levels, OrqQuantizer};

/// Dense uniform grid on [lo, hi]: 4097 evenly spaced points.
fn grid(lo: f32, hi: f32) -> Vec<f32> {
    (0..=4096).map(|i| lo + (hi - lo) * i as f32 / 4096.0).collect()
}

const GRID_TOL: f32 = 2e-3;

#[test]
fn orq_uniform_density_gives_evenly_spaced_levels() {
    let g = grid(0.0, 1.0);
    for s in [3usize, 5, 9] {
        let lv = solve_levels(&g, s);
        assert_eq!(lv.len(), s);
        for (k, &b) in lv.iter().enumerate() {
            let expect = k as f32 / (s - 1) as f32;
            assert!(
                (b - expect).abs() < GRID_TOL,
                "s={s} level {k}: {b} vs midpoint-rule {expect}"
            );
        }
    }
    // shifted/scaled support: the optimum is affine-equivariant
    let g = grid(-2.0, 6.0);
    let lv = solve_levels(&g, 5);
    for (k, &b) in lv.iter().enumerate() {
        let expect = -2.0 + 8.0 * k as f32 / 4.0;
        assert!((b - expect).abs() < 8.0 * GRID_TOL, "level {k}: {b} vs {expect}");
    }
}

#[test]
fn orq_two_level_solution_is_the_support() {
    // Corollary 1.1: with s = 2 the optimal levels are exactly the
    // endpoints, on any distribution.
    let g = grid(-1.5, 0.25);
    assert_eq!(solve_levels(&g, 2), vec![-1.5, 0.25]);
    let q = OrqQuantizer::new(2);
    let lv = q.levels_for(&[0.3f32, -0.7, 0.1, 0.2]);
    assert_eq!(lv, vec![-0.7, 0.3]);
}

#[test]
fn orq_refined_solution_satisfies_eq12_on_uniform() {
    // After coordinate descent the exact discrete condition must hold at
    // every interior level — the Eq. (12) residual is ~0.
    let mut g = grid(0.0, 1.0);
    g.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lv = OrqQuantizer::with_refinement(5, 32).levels_for(&g);
    for (k, r) in condition_residual(&g, &lv).iter().enumerate() {
        assert!(*r < 5e-3, "interior level {k} residual {r}");
    }
}

#[test]
fn bingrad_b_uniform_is_quarter_centroids() {
    // Lloyd fixed point on Uniform[0,1]: threshold 1/2, centroids 1/4 and
    // 3/4 (conditional means of the halves).
    let g = grid(0.0, 1.0);
    let (lo, b0, hi) = BinGradB::new().solve_levels(&g);
    assert!((b0 - 0.5).abs() < GRID_TOL, "b0={b0}");
    assert!((lo - 0.25).abs() < GRID_TOL, "lo={lo}");
    assert!((hi - 0.75).abs() < GRID_TOL, "hi={hi}");
}

#[test]
fn bingrad_b_two_point_recovers_the_atoms_exactly() {
    // 25% mass at −1, 75% at +2: conditional means are the atoms
    // themselves, threshold their midpoint — exact in one iteration.
    let mut g = vec![-1.0f32; 64];
    g.resize(256, 2.0);
    let (lo, b0, hi) = BinGradB::new().solve_levels(&g);
    assert_eq!(lo, -1.0);
    assert_eq!(hi, 2.0);
    assert!((b0 - 0.5).abs() < 1e-6, "b0={b0}");
    // symmetric ±a: threshold 0, levels ±a
    let mut g = vec![-0.75f32; 128];
    g.resize(256, 0.75);
    let (lo, b0, hi) = BinGradB::new().solve_levels(&g);
    assert_eq!((lo, hi), (-0.75, 0.75));
    assert!(b0.abs() < 1e-7, "b0={b0}");
}

#[test]
fn bingrad_pb_two_point_solves_b1_at_the_atom() {
    // Eq. (15) on equal-mass ±a: b₁·(1/2) = (1/2)·a ⇒ b₁ = a, exactly.
    for a in [0.5f32, 1.0, 3.25] {
        let mut g = vec![-a; 128];
        g.resize(256, a);
        let b1 = BinGradPb::solve_b1(&g);
        assert!((b1 - a).abs() <= a * 1e-6, "a={a}: b1={b1}");
    }
}

#[test]
fn bingrad_pb_uniform_is_sqrt2_minus_1() {
    // Uniform[−1,1]: b/2 = (1−b²)/4 ⇒ b² + 2b − 1 = 0 ⇒ b = √2 − 1.
    let g = grid(-1.0, 1.0);
    let b1 = BinGradPb::solve_b1(&g);
    let expect = std::f32::consts::SQRT_2 - 1.0;
    assert!((b1 - expect).abs() < GRID_TOL, "b1={b1} vs √2−1={expect}");
}
