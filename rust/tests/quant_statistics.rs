//! Statistical unbiasedness suite — the paper's Assumption 1 split,
//! verified empirically rather than desk-checked.
//!
//! The unbiased schemes (ORQ, QSGD, TernGrad, Linear) promise
//! `E[Q(v)] = v` for every in-range element under random rounding
//! (Eq. 7). With N independent rounding draws the empirical mean is
//! within `z·sqrt(Var[Q(v)]/N)` of v with per-element failure
//! probability `2·Φ(−z)`; the per-draw variance has the closed form
//! `Var[Q(v)] = (v − b_lo)(b_hi − v)` (the Eq. 9 integrand) computed
//! from the scheme's *actual* (deterministic) level table, so the bound
//! is exact rather than heuristic. We use z = 6 (≈ 2·10⁻⁹ two-sided per
//! element, ~10⁻⁵ across the whole suite — and the fixed seeds pin the
//! outcome to a single deterministic draw anyway) plus a 10⁻⁶ absolute
//! slack for f32 accumulation; the biased schemes' deviations exceed
//! this bound by an order of magnitude, so the split stays sharp.
//!
//! The biased schemes (BinGrad-pb, BinGrad-b, signSGD) must be *flagged*
//! (`is_unbiased() == false`) and demonstrably violate the same bound —
//! BinGrad-b and signSGD deterministically (their error never averages
//! out), BinGrad-pb exactly on its clamped tail (|v| ≥ b₁) while staying
//! unbiased strictly inside (−b₁, b₁).

use orq::quant::bingrad::BinGradPb;
use orq::quant::{self, Quantizer};
use orq::testutil::{sample, GradDist};
use orq::tensor::rng::Rng;

const DRAWS: usize = 600;
const Z: f64 = 6.0;
const BUCKET: usize = 256;

fn bucket(dist: GradDist, seed: u64) -> Vec<f32> {
    let mut rng = Rng::stream(4242, seed);
    sample(dist, BUCKET, 1.0, &mut rng)
}

/// Empirical `E[Q(g)]` over `DRAWS` independent rounding streams, plus
/// the (draw-invariant) level table the scheme solved for this bucket.
fn empirical_mean(q: &dyn Quantizer, g: &[f32]) -> (Vec<f64>, Vec<f32>) {
    let mut acc = vec![0.0f64; g.len()];
    let mut levels = Vec::new();
    for t in 0..DRAWS {
        let qb = q.quantize_bucket(g, &mut Rng::stream(90_000, t as u64));
        if t == 0 {
            levels = qb.levels.clone();
        } else {
            assert_eq!(levels, qb.levels, "level solving must be RNG-independent");
        }
        for (a, &i) in acc.iter_mut().zip(&qb.indices) {
            *a += qb.levels[i as usize] as f64;
        }
    }
    for a in acc.iter_mut() {
        *a /= DRAWS as f64;
    }
    (acc, levels)
}

/// `z·sqrt(Var[Q(v)]/N) + ε` for one element against the solved levels.
fn clt_bound(levels: &[f32], v: f32) -> f64 {
    let s = levels.len();
    let lower = levels.partition_point(|&b| b <= v).saturating_sub(1).min(s - 2);
    let b_lo = levels[lower] as f64;
    let b_hi = levels[lower + 1] as f64;
    let vd = (v as f64).clamp(b_lo, b_hi);
    let var = (vd - b_lo) * (b_hi - vd);
    Z * (var / DRAWS as f64).sqrt() + 1e-6
}

/// Fraction of elements whose empirical mean violates its CLT bound.
fn violation_fraction(g: &[f32], mean: &[f64], levels: &[f32]) -> f64 {
    let bad = g
        .iter()
        .zip(mean)
        .filter(|(&v, &m)| (m - v as f64).abs() > clt_bound(levels, v))
        .count();
    bad as f64 / g.len() as f64
}

#[test]
fn unbiased_schemes_pass_the_confidence_bound() {
    for method in ["orq-5", "qsgd-5", "terngrad", "linear-5"] {
        let q = quant::from_name(method).unwrap();
        assert!(q.is_unbiased(), "{method} must be flagged unbiased");
        for (di, dist) in [GradDist::Gaussian, GradDist::Uniform, GradDist::Bimodal]
            .into_iter()
            .enumerate()
        {
            let g = bucket(dist, di as u64);
            let (mean, levels) = empirical_mean(q.as_ref(), &g);
            for (i, (&v, &m)) in g.iter().zip(&mean).enumerate() {
                let tol = clt_bound(&levels, v);
                assert!(
                    (m - v as f64).abs() <= tol,
                    "{method}/{dist:?}: E[Q(g)][{i}]={m} vs g[{i}]={v} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn biased_schemes_are_flagged_and_fail_the_bound() {
    for method in ["bingrad-b", "signsgd"] {
        let q = quant::from_name(method).unwrap();
        assert!(!q.is_unbiased(), "{method} must be flagged biased");
        let g = bucket(GradDist::Gaussian, 7);
        let (mean, levels) = empirical_mean(q.as_ref(), &g);
        let frac = violation_fraction(&g, &mean, &levels);
        assert!(
            frac > 0.5,
            "{method}: only {frac:.2} of elements violate the unbiased bound — \
             a biased scheme's error must not average out"
        );
    }
    assert!(!quant::from_name("bingrad-pb").unwrap().is_unbiased());
}

/// BinGrad-pb is *partially* biased: unbiased random rounding strictly
/// inside (−b₁, b₁), deterministic clamping (hence bias) outside.
#[test]
fn bingrad_pb_bias_is_exactly_the_clamped_tail() {
    let q = quant::from_name("bingrad-pb").unwrap();
    let g = bucket(GradDist::Gaussian, 11);
    let b1 = BinGradPb::solve_b1(&g);
    assert!(b1 > 0.0);
    let (mean, levels) = empirical_mean(q.as_ref(), &g);
    let mut interior = 0usize;
    let mut clamped_biased = 0usize;
    let mut clamped_total = 0usize;
    for (&v, &m) in g.iter().zip(&mean) {
        let tol = clt_bound(&levels, v);
        if v.abs() < b1 * 0.999 {
            // interior: must pass the unbiased bound
            assert!(
                (m - v as f64).abs() <= tol,
                "interior element v={v} biased: E={m} (b1={b1}, tol={tol})"
            );
            interior += 1;
        } else if v.abs() > b1 * 1.02 {
            // clamped tail: E[Q(v)] = ±b₁ exactly, so any element a few
            // bound-widths past b₁ must violate
            clamped_total += 1;
            if (m - v as f64).abs() > tol {
                clamped_biased += 1;
            }
            assert!(
                (m.abs() - b1 as f64).abs() < 1e-6,
                "clamped element v={v} must map to ±b1={b1}, got {m}"
            );
        }
    }
    assert!(interior > 50, "gaussian bucket should have interior mass (got {interior})");
    assert!(clamped_total > 10, "gaussian bucket should have tail mass (got {clamped_total})");
    assert!(
        clamped_biased as f64 >= 0.8 * clamped_total as f64,
        "clamped tail must be biased: {clamped_biased}/{clamped_total}"
    );
}

/// The whole paper split, via the trait flags: Table-order methods
/// partition exactly into {unbiased random-rounding} ∪ {biased}.
#[test]
fn paper_method_bias_split() {
    let unbiased = ["fp", "terngrad", "orq-3", "qsgd-5", "orq-5", "linear-5", "qsgd-9", "orq-9"];
    let biased = ["bingrad-pb", "bingrad-b", "signsgd"];
    for m in unbiased {
        assert!(quant::from_name(m).unwrap().is_unbiased(), "{m}");
    }
    for m in biased {
        assert!(!quant::from_name(m).unwrap().is_unbiased(), "{m}");
    }
}
