//! Failure injection: malformed wire bytes, adversarial configs, dead
//! peers and degenerate training shapes must produce clean errors —
//! never panics, never deadlocks, never silent corruption.

use orq::codec::{self, Packing};
use orq::comm::link::{Link, LinkMap};
use orq::comm::{build_topology, ExchangeConfig, GradCodec, Topology, WireSpec};
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::quant::bucket::{BucketQuantizer, QuantizedGrad};
use orq::quant::{self};
use orq::tensor::rng::Rng;

/// Fuzz the decoder with random single-byte corruptions of valid
/// messages: every outcome must be Ok (harmless flip, e.g. inside a level
/// float) or Err — never a panic, and Ok results must keep the element
/// count.
#[test]
fn decoder_survives_byte_corruption() {
    let mut rng = Rng::seed_from(1);
    let mut g = vec![0.0f32; 3000];
    rng.fill_gaussian(&mut g, 0.01);
    let q = quant::from_name("orq-5").unwrap();
    let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut rng);
    for packing in [Packing::Fixed, Packing::BaseS] {
        let clean = codec::encode(&qg, "orq-5", packing);
        for trial in 0..400 {
            let mut bytes = clean.clone();
            let pos = rng.below(bytes.len() as u64) as usize;
            let bit = 1u8 << rng.below(8);
            bytes[pos] ^= bit;
            match codec::decode(&bytes) {
                Ok(dec) => {
                    // element count must never silently change
                    assert!(
                        dec.len() == 3000,
                        "trial {trial}: corrupted length {}",
                        dec.len()
                    );
                }
                Err(_) => {} // clean rejection is fine
            }
        }
    }
}

/// Truncation at every prefix length: must be Err (or Ok only for the
/// full message).
#[test]
fn decoder_survives_truncation() {
    let mut rng = Rng::seed_from(2);
    let mut g = vec![0.0f32; 700];
    rng.fill_gaussian(&mut g, 1.0);
    let q = quant::from_name("terngrad").unwrap();
    let qg = BucketQuantizer::new(256).quantize(&g, q.as_ref(), &mut rng);
    let bytes = codec::encode(&qg, "terngrad", Packing::BaseS);
    for n in 0..bytes.len() {
        assert!(
            codec::decode(&bytes[..n]).is_err(),
            "prefix of {n} bytes must not decode"
        );
    }
    assert!(codec::decode(&bytes).is_ok());
}

/// Random garbage never decodes to Ok with a bogus huge allocation and
/// never panics.
#[test]
fn decoder_survives_garbage() {
    let mut rng = Rng::seed_from(3);
    for _ in 0..500 {
        let len = rng.below(200) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = codec::decode(&bytes); // must not panic
    }
}

/// A header that claims a huge total length against a short payload must
/// error, not OOM or panic.
#[test]
fn decoder_rejects_length_lies() {
    let g = vec![1.0f32; 64];
    let mut bytes = codec::encode_fp(&g);
    // total u64 lives at offset 12..20
    bytes[12..20].copy_from_slice(&(u32::MAX as u64).to_le_bytes());
    assert!(codec::decode(&bytes).is_err());
}

fn tiny_ds(classes: usize) -> ClassDataset {
    ClassDataset::generate(DatasetSpec {
        in_dim: 8,
        classes,
        train_n: 128,
        test_n: 64,
        margin: 3.0,
        noise: 0.5,
        label_noise: 0.0,
        seed: 4,
    })
}

#[test]
fn trainer_degenerate_shapes() {
    let ds = tiny_ds(8);
    // steps = 1, eval_every larger than steps, bucket larger than params
    let cfg = TrainConfig {
        model: "mlp:8-16-8".into(),
        method: "orq-3".into(),
        workers: 2,
        batch: 4,
        steps: 1,
        eval_every: 100,
        bucket_size: 1 << 20,
        lr_decay_steps: vec![],
        ..TrainConfig::default()
    };
    let factory = native_backend_factory(&cfg.model).unwrap();
    let out = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
    assert_eq!(out.series.steps.len(), 1);
    // final eval still recorded
    assert!(!out.series.evals.is_empty());
}

#[test]
fn trainer_bucket_size_one() {
    // d=1: every element its own bucket — worst-case overhead but must
    // still be numerically exact for 2-level schemes (each bucket is a
    // constant).
    let ds = tiny_ds(8);
    let cfg = TrainConfig {
        model: "mlp:8-16-8".into(),
        method: "bingrad-b".into(),
        workers: 1,
        batch: 8,
        steps: 3,
        eval_every: 0,
        bucket_size: 1,
        lr_decay_steps: vec![],
        ..TrainConfig::default()
    };
    let factory = native_backend_factory(&cfg.model).unwrap();
    let out = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
    // single-element buckets quantize exactly -> zero quantization error
    assert!(
        out.summary.mean_quant_rel_mse < 1e-9,
        "d=1 must be lossless, got {}",
        out.summary.mean_quant_rel_mse
    );
}

#[test]
fn trainer_rejects_unknown_method_and_model() {
    let ds = tiny_ds(8);
    let mut cfg = TrainConfig {
        model: "mlp:8-16-8".into(),
        method: "definitely-not-a-method".into(),
        workers: 1,
        batch: 8,
        steps: 1,
        ..TrainConfig::default()
    };
    let factory = native_backend_factory(&cfg.model).unwrap();
    assert!(Trainer::new(cfg.clone(), &ds).unwrap().run(factory).is_err());
    cfg.method = "fp".into();
    assert!(native_backend_factory("not-a-model").is_err());
    assert!(native_backend_factory("mlp:64").is_err()); // single dim
    assert!(native_backend_factory("mlp:a-b").is_err()); // non-numeric
}

/// Star-shaped topologies multiplex every worker onto one uplink
/// channel, so a dead peer is detected once the last end is gone: drop
/// all the worker ends before any exchange and the coordinator's gather
/// must return `Err` — not panic, not block forever.
#[test]
fn dead_workers_error_cleanly_on_star_topologies() {
    let sp = WireSpec { seed: 11, ..WireSpec::new("terngrad", 256) };
    for cfg in [
        ExchangeConfig::flat(Topology::Ps, Link::ten_gbps()),
        ExchangeConfig::hier(2, LinkMap::uniform(Link::ten_gbps())),
    ] {
        let (mut coll, ends) = build_topology(&cfg, 4, &sp).unwrap();
        drop(ends); // every worker dies before contributing
        let mut mean = Vec::new();
        assert!(
            coll.round(&mut mean).is_err(),
            "{:?}: dead workers must surface as Err on the coordinator",
            cfg.topology
        );
    }
}

/// The ring and the sharded PS wire peers with dedicated channels, so a
/// SINGLE dead worker cascades: every survivor sees its hop / frame
/// channel close and gets `Err` from `exchange` (a panic there would
/// poison the whole node), and the coordinator reports the dead round
/// as `Err` too.
#[test]
fn one_dead_peer_cascades_as_errors_on_ring_and_sharded_ps() {
    let sp = WireSpec { seed: 12, ..WireSpec::new("terngrad", 256) };
    let mut rng = Rng::seed_from(13);
    let gs: Vec<Vec<f32>> = (0..4)
        .map(|_| {
            let mut g = vec![0.0f32; 2048];
            rng.fill_gaussian(&mut g, 0.01);
            g
        })
        .collect();
    for cfg in [
        ExchangeConfig::flat(Topology::Ring, Link::ten_gbps()),
        ExchangeConfig::sharded(2, 0, Link::ten_gbps()),
    ] {
        let (mut coll, mut ends) = build_topology(&cfg, 4, &sp).unwrap();
        drop(ends.remove(0)); // worker 0 dies before its first exchange
        let res = std::thread::scope(|scope| {
            for (i, mut wx) in ends.into_iter().enumerate() {
                let w = i + 1;
                let g: &[f32] = &gs[w];
                let sp = sp.clone();
                scope.spawn(move || {
                    let mut gc = GradCodec::new(&sp).unwrap();
                    let mut rng = Rng::stream(sp.seed, 2_000 + w as u64);
                    let mut qg = QuantizedGrad::default();
                    let mut msg = Vec::new();
                    gc.encode_into(g, &mut rng, &mut qg, &mut msg);
                    let mut mean = Vec::new();
                    assert!(
                        wx.exchange(&mut msg, &mut mean).is_err(),
                        "survivor {w} must see the dead peer as Err"
                    );
                });
            }
            let mut mean = Vec::new();
            let res = coll.round(&mut mean);
            // Drop before the scope joins so any survivor still blocked
            // on a coordinator channel unblocks (the drop-before-join
            // teardown convention from `run_rounds`).
            drop(coll);
            res
        });
        assert!(
            res.is_err(),
            "{:?}: dead peer must surface as Err on the coordinator",
            cfg.topology
        );
    }
}

#[test]
fn quantizers_survive_adversarial_buckets() {
    // NaN-free but nasty inputs: all-zero, single element, constant,
    // max-magnitude floats, denormals.
    let nasty: Vec<Vec<f32>> = vec![
        vec![0.0; 97],
        vec![42.0],
        vec![-1e30, 1e30],
        vec![f32::MIN_POSITIVE; 33],
        vec![1e-40; 8], // subnormal
        (0..64).map(|i| if i % 2 == 0 { 3.4e37 } else { -3.4e37 }).collect(),
    ];
    let mut rng = Rng::seed_from(5);
    for g in &nasty {
        for name in quant::paper_methods() {
            if name == "fp" {
                continue;
            }
            let q = quant::from_name(name).unwrap();
            let qb = q.quantize_bucket(g, &mut rng);
            assert_eq!(qb.indices.len(), g.len(), "{name}");
            assert!(qb.levels.iter().all(|v| v.is_finite()), "{name} on {g:?}");
            // roundtrip through the codec too
            let qg = BucketQuantizer::new(64).quantize(g, q.as_ref(), &mut rng);
            let bytes = codec::encode(&qg, name, Packing::BaseS);
            let dec = codec::decode(&bytes).unwrap();
            assert_eq!(dec.len(), g.len(), "{name}");
        }
    }
}
