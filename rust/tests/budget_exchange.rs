//! End-to-end byte-budget properties: with `byte_budget` set, every
//! worker's per-round uplink — headers, frames and in-band width tables
//! included — must stay within the budget on every topology, at every
//! thread count, with and without error feedback, while the hop
//! decoders read the widths from the frames themselves (a guessed
//! width would fail the decode and the run). Without a budget the wire
//! bytes must match the fixed-width closed form exactly.

use orq::codec::{wire_size, wire_size_widths, Packing};
use orq::comm::{budget_frame_overhead, Topology};
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::model::Backend;
use orq::quant::budget::min_message_bytes;

const MODEL: &str = "mlp:32-64-64-16";
const METHOD: &str = "orq-5";
const S_MAX: usize = 5;
const BUCKET: usize = 256;

fn ds() -> ClassDataset {
    ClassDataset::generate(DatasetSpec {
        in_dim: 32,
        classes: 16,
        train_n: 512,
        test_n: 128,
        margin: 3.0,
        noise: 1.0,
        label_noise: 0.02,
        seed: 33,
    })
}

fn cfg(topology: Topology) -> TrainConfig {
    let (workers, groups, shards) = match topology {
        Topology::Ps => (2, 1, 1),
        Topology::Ring => (3, 1, 1),
        Topology::Hier => (4, 2, 1),
        Topology::ShardedPs => (2, 1, 2),
    };
    TrainConfig {
        model: MODEL.into(),
        dataset: "test".into(),
        method: METHOD.into(),
        workers,
        groups,
        shards,
        batch: 32,
        steps: 8,
        lr: 0.05,
        lr_decay_steps: vec![],
        bucket_size: BUCKET,
        seed: 11,
        eval_every: 0,
        topology,
        ..TrainConfig::default()
    }
}

fn param_count() -> usize {
    native_backend_factory(MODEL).unwrap()(0).param_count()
}

/// A budget ~60% of the way from the all-width-2 floor to the full
/// fixed-width cost, plus the topology's exact frame/header overhead —
/// always accepted by the trainer, always forcing a real allocation.
fn mid_budget(c: &TrainConfig, sections: Option<usize>) -> u64 {
    let n = param_count();
    let nb = n.div_ceil(c.bucket_size);
    let full =
        wire_size_widths(n, c.bucket_size, &vec![S_MAX as u8; nb], Packing::BaseS, METHOD);
    let floor = min_message_bytes(n, c.bucket_size, Packing::BaseS, METHOD);
    let overhead =
        budget_frame_overhead(c.topology, c.workers, c.groups, c.shards, sections, METHOD);
    (overhead + floor + (full - floor) * 3 / 5) as u64
}

/// Full-gradient uplink streams per round: every worker sends (at most)
/// one budgeted gradient's worth of uplink traffic; on hier the group
/// leaders additionally uplink the group mean to the root.
fn uplink_streams(c: &TrainConfig) -> u64 {
    match c.topology {
        Topology::Hier => (c.workers + c.groups) as u64,
        _ => c.workers as u64,
    }
}

fn assert_budget_held(c: TrainConfig, data: &ClassDataset, label: &str) {
    let b = c.byte_budget.expect("budget set");
    let streams = uplink_streams(&c);
    let factory = native_backend_factory(&c.model).unwrap();
    let out = Trainer::new(c, data).unwrap().run(factory).unwrap();
    for m in &out.series.steps {
        assert!(m.wire_bytes_up > 0, "{label} step {}: no uplink bytes", m.step);
        assert!(
            m.wire_bytes_up <= streams * b,
            "{label} step {}: uplink {} exceeds {streams} streams x budget {b}",
            m.step,
            m.wire_bytes_up
        );
    }
    assert!(out.series.final_loss().is_finite(), "{label}: loss diverged");
}

/// The budget cap holds on every topology x thread count x error
/// feedback: per-step uplink bytes (headers and width tables included)
/// never exceed streams x budget.
#[test]
fn budget_bounds_uplink_on_every_topology() {
    let data = ds();
    for topology in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
        for threads in [1usize, 2] {
            for ef in [false, true] {
                let mut c = cfg(topology);
                c.threads = threads;
                c.error_feedback = ef;
                c.byte_budget = Some(mid_budget(&c, None));
                let label = format!("{topology:?} threads={threads} ef={ef}");
                assert_budget_held(c, &data, &label);
            }
        }
    }
}

/// The cap composes with section streaming (frames and per-section
/// sub-table headers all count against the budget) and with the
/// coarse-to-fine schedule (which only ever spends less).
#[test]
fn budget_composes_with_streamed_sections_and_schedule() {
    let data = ds();
    for topology in [Topology::Ps, Topology::Ring, Topology::ShardedPs] {
        let mut c = cfg(topology);
        c.threads = 2;
        c.overlap = true;
        c.stream_sections = true;
        c.sections = Some(2);
        c.byte_budget = Some(mid_budget(&c, Some(2)));
        c.budget_schedule = Some("coarse-to-fine".into());
        let label = format!("{topology:?} streamed");
        assert_budget_held(c, &data, &label);
    }
}

/// Without a budget the uplink is the legacy fixed-width message — no
/// width table, byte-exact against the closed-form wire size.
#[test]
fn no_budget_is_fixed_width() {
    let data = ds();
    let c = cfg(Topology::Ps);
    let per_msg = wire_size(param_count(), BUCKET, S_MAX, Packing::BaseS, METHOD) as u64;
    let workers = c.workers as u64;
    let factory = native_backend_factory(&c.model).unwrap();
    let out = Trainer::new(c, &data).unwrap().run(factory).unwrap();
    for m in &out.series.steps {
        assert_eq!(
            m.wire_bytes_up,
            workers * per_msg,
            "step {}: fixed-width uplink must match the closed form",
            m.step
        );
    }
}

/// A budget at (or above) the full fixed-width cost plus the table
/// bytes upgrades every bucket to s_max — spending is capped by the
/// budget yet loses nothing to the fixed-width run's volume.
#[test]
fn generous_budget_saturates_at_full_width() {
    let data = ds();
    let mut c = cfg(Topology::Ps);
    let n = param_count();
    let nb = n.div_ceil(BUCKET);
    let full = wire_size_widths(n, BUCKET, &vec![S_MAX as u8; nb], Packing::BaseS, METHOD);
    c.byte_budget = Some(2 * full as u64);
    let workers = c.workers as u64;
    let factory = native_backend_factory(&c.model).unwrap();
    let out = Trainer::new(c, &data).unwrap().run(factory).unwrap();
    for m in &out.series.steps {
        assert_eq!(
            m.wire_bytes_up,
            workers * full as u64,
            "step {}: a generous budget must saturate every bucket at s_max",
            m.step
        );
    }
}
