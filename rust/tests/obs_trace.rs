//! Observability integration tests: tracing must be invisible in
//! results (bit-identical params and wire bytes on every topology,
//! thread count and error-feedback setting), the exported artifact must
//! be well-formed Chrome trace JSON with one row per worker / shard /
//! pool thread, and the metrics artifact's model-drift section must
//! hold the repo's <1% model-vs-simulator invariant on every topology.

use orq::comm::Topology;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer, TrainOutput};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::obs::{chrome_trace_json, metrics_json, validate_spans, TraceLevel};
use orq::util::json::Json;

fn ds(in_dim: usize, classes: usize) -> ClassDataset {
    ClassDataset::generate(DatasetSpec {
        in_dim,
        classes,
        train_n: 512,
        test_n: 128,
        margin: 3.0,
        noise: 1.0,
        label_noise: 0.02,
        seed: 31,
    })
}

/// Small but real config: every topology below reshapes it.
fn cfg(topology: Topology) -> TrainConfig {
    TrainConfig {
        model: "mlp:16-32-8".into(),
        dataset: "test".into(),
        method: "terngrad".into(),
        workers: 2,
        batch: 32,
        steps: 20,
        lr: 0.05,
        eval_every: 0,
        bucket_size: 64,
        seed: 9,
        topology,
        groups: 1,
        shards: 1,
        ..TrainConfig::default()
    }
}

fn shape(mut c: TrainConfig, topology: Topology) -> TrainConfig {
    match topology {
        Topology::Hier => {
            c.workers = 4;
            c.groups = 2;
        }
        Topology::ShardedPs => {
            c.shards = 2;
        }
        _ => {}
    }
    c
}

fn run(c: TrainConfig, data: &ClassDataset) -> TrainOutput {
    let factory = native_backend_factory(&c.model).unwrap();
    Trainer::new(c, data).unwrap().run(factory).unwrap()
}

/// Tracing must be invisible in results: parameters and wire bytes are
/// bit-identical with the recorder off vs at `fine`, across every
/// topology × thread count × error-feedback setting.
#[test]
fn tracing_is_bit_identical() {
    let data = ds(16, 8);
    for topology in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
        for threads in [1usize, 2] {
            for ef in [false, true] {
                let mut base = shape(cfg(topology), topology);
                base.threads = threads;
                base.error_feedback = ef;
                let mut traced = base.clone();
                traced.trace_level = TraceLevel::Fine;
                let off = run(base, &data);
                let on = run(traced, &data);
                let tag = format!("{topology} threads={threads} ef={ef}");
                assert!(off.obs.is_none(), "{tag}: untraced run carried events");
                let obs = on.obs.as_ref().unwrap_or_else(|| panic!("{tag}: no obs"));
                assert!(!obs.events.is_empty(), "{tag}: traced run recorded nothing");
                validate_spans(&obs.events).unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert_eq!(
                    off.comm.wire_bytes, on.comm.wire_bytes,
                    "{tag}: tracing changed the wire bytes"
                );
                assert_eq!(
                    off.comm.wire_bytes_up, on.comm.wire_bytes_up,
                    "{tag}: tracing changed the uplink bytes"
                );
                let a: Vec<u32> = off.params.iter().map(|p| p.to_bits()).collect();
                let b: Vec<u32> = on.params.iter().map(|p| p.to_bits()).collect();
                assert_eq!(a, b, "{tag}: tracing changed the trained parameters");
            }
        }
    }
}

/// The acceptance scenario: a 4-worker sharded-PS *streamed* run traced
/// at `fine` exports valid Chrome trace JSON with distinct worker,
/// shard and pool rows, well-nested spans, and a matching metrics
/// artifact.
#[test]
fn sharded_streamed_trace_exports_chrome_json() {
    let data = ds(16, 8);
    let mut c = shape(cfg(Topology::ShardedPs), Topology::ShardedPs);
    c.workers = 4;
    c.method = "orq-3".into();
    c.threads = 2;
    c.overlap = true;
    c.stream_sections = true;
    c.steps = 8;
    c.trace_level = TraceLevel::Fine;
    let out = run(c, &data);
    let obs = out.obs.as_ref().expect("traced run must carry events");
    validate_spans(&obs.events).unwrap();

    // distinct rows for all four workers, both shards and the pool
    let mut worker_tids = std::collections::BTreeSet::new();
    let mut shard_tids = std::collections::BTreeSet::new();
    let mut pool_tids = std::collections::BTreeSet::new();
    for e in &obs.events {
        match e.track.kind() {
            "worker" => {
                worker_tids.insert(e.track.tid());
            }
            "shard" => {
                shard_tids.insert(e.track.tid());
            }
            "pool" => {
                pool_tids.insert(e.track.tid());
            }
            _ => {}
        }
    }
    assert_eq!(worker_tids.len(), 4, "one row per worker");
    assert_eq!(shard_tids.len(), 2, "one row per server shard");
    assert!(!pool_tids.is_empty(), "pool threads must appear at fine level");

    // the artifact round-trips through the repo's own JSON parser and
    // keeps the Chrome required keys on every row
    let dumped = chrome_trace_json(&obs.events).dump();
    let j = Json::parse(&dumped).unwrap();
    assert_eq!(j.req("schema").unwrap().as_str(), Some(orq::obs::TRACE_SCHEMA));
    let rows = j.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(rows.len() > obs.events.len(), "metadata rows + events");
    for r in rows {
        for key in ["name", "ph", "pid", "tid"] {
            assert!(r.get(key).is_some(), "missing {key} in {}", r.dump());
        }
    }

    // metrics artifact: schema, one round per step, registry totals
    // agreeing with the run's own accounting
    let m = Json::parse(&metrics_json(&out.series, &obs.registry).dump()).unwrap();
    assert_eq!(m.req("schema").unwrap().as_str(), Some(orq::obs::METRICS_SCHEMA));
    assert_eq!(m.req("rounds").unwrap().as_arr().unwrap().len(), 8);
    let reg = m.req("registry").unwrap();
    assert_eq!(reg.req("rounds").unwrap().as_f64(), Some(8.0));
    assert_eq!(reg.req("workers").unwrap().as_f64(), Some(4.0));
    assert_eq!(
        reg.req("wire_bytes_total").unwrap().as_f64(),
        Some(out.comm.wire_bytes as f64),
        "registry wire total must match CommStats"
    );
}

/// `round` level is a strict subset of `fine`: same identical results,
/// fewer events (no collective-interior hops or pool counters).
#[test]
fn round_level_records_less_than_fine() {
    let data = ds(16, 8);
    let mut fine = shape(cfg(Topology::Ps), Topology::Ps);
    fine.trace_level = TraceLevel::Fine;
    let mut round = fine.clone();
    round.trace_level = TraceLevel::Round;
    let f = run(fine, &data);
    let r = run(round, &data);
    let (fe, re) = (f.obs.unwrap().events, r.obs.unwrap().events);
    assert!(!re.is_empty(), "round level must still record phase spans");
    assert!(
        re.len() < fe.len(),
        "round ({}) must record fewer events than fine ({})",
        re.len(),
        fe.len()
    );
    validate_spans(&re).unwrap();
    let a: Vec<u32> = f.params.iter().map(|p| p.to_bits()).collect();
    let b: Vec<u32> = r.params.iter().map(|p| p.to_bits()).collect();
    assert_eq!(a, b, "trace level must not change training");
}

/// The model-drift section must report < 1% on every topology: the
/// measured simulated communication time tracks the closed-form models
/// round by round. Buckets divide the layers evenly here so the ring's
/// chunk model sees no ragged tail.
#[test]
fn model_drift_below_one_percent_everywhere() {
    let data = ds(256, 8);
    for topology in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
        let mut c = shape(cfg(topology), topology);
        c.model = "mlp:256-256-8".into();
        c.bucket_size = 512;
        c.steps = 6;
        if topology == Topology::Hier {
            c.workers = 2; // 2 groups of 1: leader star, no intra ring
        }
        c.trace_level = TraceLevel::Round;
        let out = run(c, &data);
        let obs = out.obs.as_ref().unwrap();
        let m = metrics_json(&out.series, &obs.registry);
        let drift = m.req("model_drift").unwrap();
        let max_err = drift.req("max_rel_err").unwrap().as_f64().unwrap();
        assert!(
            max_err < 0.01,
            "{topology}: model drift {max_err:.4} ≥ 1% (measured {} vs model {})",
            drift.req("total_measured_s").unwrap().as_f64().unwrap(),
            drift.req("total_model_s").unwrap().as_f64().unwrap()
        );
    }
}
