//! Differential suite for the word-level codec kernels (PR 3).
//!
//! The fixed-width packers and the base-s radix decoder were rewritten
//! as branchless word-at-a-time kernels (monomorphic paths for bits ∈
//! {1, 2, 4, 8}, reciprocal multiplication instead of `%`/`/`). The wire
//! format is frozen, so everything here is byte-for-byte:
//!
//! * word kernels vs the retained scalar references, for all widths
//!   1..=8 and radices (incl. s = 255), across odd lengths, word/group
//!   boundaries, tail buckets and non-empty output prefixes;
//! * full wire messages vs an independent scalar reconstruction of the
//!   header + payload layout;
//! * the parallel bucket pipeline vs its serial reference, end to end
//!   through `run_once` (thread-count invariance of the decoded mean);
//! * malformed wire bytes (truncated header/payload, bad scheme name,
//!   length lies) must return `Err` from every decode entry point —
//!   never panic.

use orq::codec::{self, bitpack, DecodeScratch, Packing};
use orq::comm::{run_once, ExchangeConfig, Topology, WireSpec};
use orq::comm::link::Link;
use orq::quant::bucket::{BucketQuantizer, QuantizedGrad};
use orq::quant::from_name;
use orq::tensor::rng::Rng;

fn rand_indices(n: usize, s: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| rng.below(s as u64) as u8).collect()
}

fn sample_grad(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| rng.gaussian_f32()).collect()
}

const LENGTHS: [usize; 14] = [0, 1, 2, 3, 5, 7, 8, 9, 19, 20, 27, 40, 63, 1000];

/// In-test scalar reference for base-s packing (the pre-PR loop,
/// implemented independently of `bitpack`).
fn pack_base_s_reference(indices: &[u8], s: usize) -> Vec<u8> {
    let g = bitpack::digits_per_word(s);
    let mut out = Vec::new();
    for chunk in indices.chunks(g) {
        let mut word: u64 = 0;
        for &d in chunk.iter().rev() {
            word = word * s as u64 + d as u64;
        }
        out.extend_from_slice(&word.to_le_bytes());
    }
    out
}

#[test]
fn fixed_word_kernels_byte_identical_to_scalar() {
    for bits in 1..=8u32 {
        let s = 1usize << bits;
        for n in LENGTHS {
            let idx = rand_indices(n, s, bits as u64 * 7919 + n as u64);
            for prefix in [0usize, 1, 3, 8] {
                let mut word = vec![0xC3u8; prefix];
                let mut scalar = vec![0xC3u8; prefix];
                bitpack::pack_fixed_into(&idx, bits, &mut word);
                bitpack::pack_fixed_scalar_into(&idx, bits, &mut scalar);
                assert_eq!(word, scalar, "pack bits={bits} n={n} prefix={prefix}");
                let payload = &word[prefix..];
                let mut a = vec![0xEEu8; 5]; // stale contents must be cleared
                let mut b = Vec::new();
                bitpack::unpack_fixed_into(payload, n, bits, &mut a).unwrap();
                bitpack::unpack_fixed_scalar_into(payload, n, bits, &mut b).unwrap();
                assert_eq!(a, b, "unpack bits={bits} n={n}");
                assert_eq!(a, idx, "roundtrip bits={bits} n={n}");
            }
        }
    }
}

#[test]
fn base_s_kernels_byte_identical_to_scalar() {
    for s in [2usize, 3, 5, 9, 17, 255] {
        let radix = bitpack::Radix::new(s);
        for n in LENGTHS {
            let idx = rand_indices(n, s, s as u64 * 104_729 + n as u64);
            let reference = pack_base_s_reference(&idx, s);
            for prefix in [0usize, 2] {
                let mut packed = vec![0x11u8; prefix];
                radix.pack_into(&idx, &mut packed);
                assert_eq!(&packed[..prefix], vec![0x11u8; prefix].as_slice());
                assert_eq!(&packed[prefix..], reference.as_slice(), "pack s={s} n={n}");
            }
            let mut recip = vec![7u8; 3];
            let mut scalar = Vec::new();
            radix.unpack_into(&reference, n, &mut recip).unwrap();
            bitpack::unpack_base_s_scalar_into(&reference, n, s, &mut scalar).unwrap();
            assert_eq!(recip, scalar, "unpack s={s} n={n}");
            assert_eq!(recip, idx, "roundtrip s={s} n={n}");
        }
    }
}

/// Rebuild whole wire messages with the scalar kernels and an
/// independent header writer; `codec::encode` must match byte-for-byte
/// (the wire format is frozen across the kernel rewrite).
#[test]
fn encoded_messages_match_scalar_reconstruction() {
    let bits_for = |s: usize| -> u32 { (usize::BITS - (s - 1).leading_zeros()).max(1) };
    for (n, d) in [(1500usize, 512usize), (1000, 128), (130, 64), (64, 64)] {
        let g = sample_grad(n, n as u64 + 1);
        for scheme in ["terngrad", "orq-5", "qsgd-9", "bingrad-b", "linear-9"] {
            let q = from_name(scheme).unwrap();
            let qg = BucketQuantizer::new(d).quantize(&g, q.as_ref(), &mut Rng::seed_from(2));
            let s = q.num_levels();
            for packing in [Packing::Fixed, Packing::BaseS] {
                // independent reconstruction of the documented layout
                let mut want = Vec::new();
                want.extend_from_slice(&0x3151_524Fu32.to_le_bytes()); // magic
                want.push(1); // version
                want.push(if packing == Packing::BaseS { 2 } else { 0 }); // flags
                want.push(s as u8);
                want.push(scheme.len() as u8);
                want.extend_from_slice(&(d as u32).to_le_bytes());
                want.extend_from_slice(&(n as u64).to_le_bytes());
                want.extend_from_slice(scheme.as_bytes());
                for b in &qg.buckets {
                    for lv in &b.levels {
                        want.extend_from_slice(&lv.to_le_bytes());
                    }
                    match packing {
                        Packing::Fixed => {
                            bitpack::pack_fixed_scalar_into(&b.indices, bits_for(s), &mut want)
                        }
                        Packing::BaseS => {
                            want.extend_from_slice(&pack_base_s_reference(&b.indices, s))
                        }
                    }
                }
                let got = codec::encode(&qg, scheme, packing);
                assert_eq!(got, want, "{scheme} {packing:?} n={n} d={d}");
                // and it still decodes to the same values
                let dec = codec::decode(&got).unwrap();
                assert_eq!(dec.to_flat(), qg.dequantize(), "{scheme} {packing:?}");
            }
        }
    }
}

/// `decode_slice_into` (the parallel shard decode) must agree with the
/// whole-message decode on every bucket-aligned range, including ragged
/// tails.
#[test]
fn slice_decode_matches_flat_decode() {
    let g = sample_grad(1300, 9); // d=256 → 6 buckets, ragged tail of 20
    let q = from_name("orq-5").unwrap();
    let qg = BucketQuantizer::new(256).quantize(&g, q.as_ref(), &mut Rng::seed_from(3));
    let mut scratch = DecodeScratch::default();
    for packing in [Packing::Fixed, Packing::BaseS] {
        let bytes = codec::encode(&qg, "orq-5", packing);
        let mut full = Vec::new();
        codec::decode_flat_into(&bytes, &mut full, &mut scratch).unwrap();
        for (e0, e1) in [(0usize, 256usize), (256, 1024), (1024, 1300), (0, 1300), (1300, 1300)] {
            let mut out = vec![0.0f32; e1 - e0];
            codec::decode_slice_into(&bytes, e0, e1, &mut out, &mut scratch).unwrap();
            assert_eq!(out, &full[e0..e1], "{packing:?} {e0}..{e1}");
        }
        // misaligned or out-of-range cuts and wrong buffer sizes error
        let mut out = vec![0.0f32; 100];
        assert!(codec::decode_slice_into(&bytes, 100, 200, &mut out, &mut scratch).is_err());
        let mut out = vec![0.0f32; 10];
        assert!(codec::decode_slice_into(&bytes, 0, 256, &mut out, &mut scratch).is_err());
        let mut out = Vec::new();
        assert!(codec::decode_slice_into(&bytes, 1300, 1400, &mut out, &mut scratch).is_err());
    }
}

/// Malformed wire bytes must surface as `Err` from every decode entry
/// point — truncations at every byte, header field lies, bad scheme
/// names — never panic.
#[test]
fn malformed_wire_bytes_error_not_panic() {
    let g = sample_grad(300, 4);
    let q = from_name("orq-5").unwrap();
    let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut Rng::seed_from(5));
    let mut scratch = DecodeScratch::default();
    let mut flat = Vec::new();
    for packing in [Packing::Fixed, Packing::BaseS] {
        let bytes = codec::encode(&qg, "orq-5", packing);
        // every strict prefix fails: truncated header, truncated level
        // table, truncated packed payload
        for cut in 0..bytes.len() {
            let prefix = &bytes[..cut];
            assert!(codec::decode(prefix).is_err(), "{packing:?} prefix {cut}");
            assert!(
                codec::decode_flat_into(prefix, &mut flat, &mut scratch).is_err(),
                "{packing:?} flat prefix {cut}"
            );
            assert!(codec::peek_shape(prefix).is_err(), "{packing:?} peek prefix {cut}");
        }
        // bad scheme byte: non-utf8 name (header is 20 bytes, then name)
        let mut bad = bytes.clone();
        bad[20] = 0xFF;
        assert!(codec::decode(&bad).is_err(), "{packing:?} bad scheme byte");
        // header length lies: corrupt the bucket-size field (offset 8..12)
        let mut lie = bytes.clone();
        lie[8..12].copy_from_slice(&(!0u32).to_le_bytes());
        assert!(codec::decode(&lie).is_err(), "{packing:?} bucket lie");
        // ... and the total-count field (offset 12..20)
        let mut lie = bytes.clone();
        lie[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(codec::decode(&lie).is_err(), "{packing:?} total lie");
        // trailing garbage is a length mismatch, not extra data
        let mut long = bytes.clone();
        long.extend_from_slice(&[0; 9]);
        assert!(codec::decode(&long).is_err(), "{packing:?} trailing bytes");
    }
    // FP messages with a zeroed bucket-size field are corruption too —
    // and must error through the *parallel* decode paths as well, never
    // silently produce zeros (regression: the bucket-grid sharding would
    // otherwise degenerate to empty ranges).
    let mut fp = codec::encode_fp(&g);
    fp[8..12].copy_from_slice(&0u32.to_le_bytes());
    assert!(codec::decode(&fp).is_err(), "fp bucket 0");
    assert!(codec::peek_shape(&fp).is_err(), "fp bucket 0 peek");
    let mut pipe = orq::quant::parallel::BucketPipeline::new(4);
    assert!(pipe.decode_flat_into(&fp, &mut flat).is_err(), "fp bucket 0 parallel");
    let mut acc = Vec::new();
    assert!(pipe.decode_reduce_into(&[fp], &mut acc).is_err(), "fp bucket 0 reduce");
}

/// End to end through the real PS topology: the decoded mean must be
/// bit-identical for every parallel thread count (per-bucket RNG streams
/// + order-preserving parallel reduce).
#[test]
fn ps_round_mean_invariant_across_thread_counts() {
    let grads: Vec<Vec<f32>> = (0..3).map(|w| sample_grad(2000, 60 + w)).collect();
    let cfg = ExchangeConfig::flat(Topology::Ps, Link::ten_gbps());
    let mut reference: Option<Vec<f32>> = None;
    for threads in [2usize, 3, 8] {
        let spec = WireSpec { seed: 5, ..WireSpec::new("orq-5", 256) }.with_threads(threads);
        let (mean, stats) = run_once(&cfg, &spec, &grads).unwrap();
        assert_eq!(mean.len(), 2000);
        assert!(stats.wire_bytes > 0);
        match &reference {
            None => reference = Some(mean),
            Some(r) => assert_eq!(&mean, r, "threads={threads}"),
        }
    }
    // the serial legacy path also produces *identical wire accounting*
    // (same message sizes — only the rounding draws differ)
    let serial = WireSpec { seed: 5, ..WireSpec::new("orq-5", 256) };
    let parallel = WireSpec { seed: 5, ..WireSpec::new("orq-5", 256) }.with_threads(4);
    let (_, s_stats) = run_once(&cfg, &serial, &grads).unwrap();
    let (_, p_stats) = run_once(&cfg, &parallel, &grads).unwrap();
    assert_eq!(s_stats.wire_bytes, p_stats.wire_bytes);
    assert_eq!(s_stats.messages, p_stats.messages);
}

/// The reused QuantizedGrad scratch type still round-trips through the
/// new kernels with stale state (regression guard for the `_into` reuse
/// contract under the rewrite).
#[test]
fn stale_scratch_reuse_still_exact() {
    let bq = BucketQuantizer::new(100);
    let q = from_name("terngrad").unwrap();
    let mut qg = QuantizedGrad::default();
    let mut msg = Vec::new();
    let mut scratch = DecodeScratch::default();
    let mut flat = Vec::new();
    for (i, n) in [1000usize, 37, 999, 100].into_iter().enumerate() {
        let g = sample_grad(n, 80 + i as u64);
        bq.quantize_into(&g, q.as_ref(), &mut Rng::seed_from(i as u64), &mut qg);
        codec::encode_into(&qg, "terngrad", Packing::BaseS, &mut msg);
        codec::decode_flat_into(&msg, &mut flat, &mut scratch).unwrap();
        assert_eq!(flat, qg.dequantize(), "n={n}");
    }
}
