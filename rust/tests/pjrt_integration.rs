//! Integration: the PJRT runtime executing the AOT JAX/Pallas artifacts,
//! cross-checked against the pure-Rust native backend.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use orq::coordinator::trainer::Trainer;
use orq::config::TrainConfig;
use orq::data::synth::{Batch, ClassDataset, DatasetSpec};
use orq::model::native::NativeMlp;
use orq::model::Backend;
use orq::runtime::meta::Manifest;
use orq::runtime::{Engine, PjrtBackend};
use orq::tensor::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/meta.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT integration test: run `make artifacts` first");
        None
    }
}

fn random_batch(b: usize, in_dim: usize, classes: usize, seed: u64) -> Batch {
    let mut rng = Rng::seed_from(seed);
    let mut x = vec![0.0f32; b * in_dim];
    rng.fill_gaussian(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|_| rng.below(classes as u64) as i32).collect();
    Batch { x, y, batch: b, in_dim }
}

#[test]
fn pjrt_grad_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir, "mlp_s").expect("load mlp_s");
    let mut native = NativeMlp::mlp_s();
    assert_eq!(pjrt.param_count(), native.param_count());

    // identical params into both backends
    let params = native.init_params(&mut Rng::seed_from(7));
    let batch = random_batch(64, 256, 100, 8);

    let mut g_native = vec![0.0f32; native.param_count()];
    let loss_native = native.loss_grad(&params, &batch, &mut g_native);
    let mut g_pjrt = vec![0.0f32; pjrt.param_count()];
    let loss_pjrt = pjrt.loss_grad(&params, &batch, &mut g_pjrt);

    assert!(
        (loss_native - loss_pjrt).abs() < 1e-3 * loss_native.abs().max(1.0),
        "loss: native {loss_native} vs pjrt {loss_pjrt}"
    );
    // cosine + relative L2 of the full 445k-element gradient
    let cos = orq::tensor::cosine(&g_native, &g_pjrt);
    assert!(cos > 0.9999, "gradient cosine {cos}");
    let num = orq::tensor::norm2(
        &g_native.iter().zip(&g_pjrt).map(|(a, b)| a - b).collect::<Vec<_>>(),
    );
    let den = orq::tensor::norm2(&g_native).max(1e-12);
    assert!(num / den < 2e-3, "relative grad error {}", num / den);
}

#[test]
fn pjrt_logits_match_native_and_padding_works() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir, "mlp_s").expect("load");
    let mut native = NativeMlp::mlp_s();
    let params = native.init_params(&mut Rng::seed_from(3));

    // short batch (< compiled 64) exercises the padding path
    let batch = random_batch(17, 256, 100, 4);
    let lp = pjrt.logits(&params, &batch);
    let ln = native.logits(&params, &batch);
    assert_eq!(lp.len(), 17 * 100);
    let cos = orq::tensor::cosine(&lp, &ln);
    assert!(cos > 0.9999, "logits cosine {cos}");
}

#[test]
fn pjrt_trains_through_full_coordinator() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = PjrtBackend::load(&dir, "mlp_s").expect("load");
    let ds = ClassDataset::generate(DatasetSpec {
        train_n: 2048,
        test_n: 512,
        ..DatasetSpec::cifar100_like(256)
    });
    let cfg = TrainConfig {
        model: "pjrt:mlp_s".into(),
        method: "orq-5".into(),
        workers: 1,
        batch: 64, // must equal the compiled batch
        steps: 30,
        eval_every: 0,
        lr_decay_steps: vec![],
        ..TrainConfig::default()
    };
    let factory = move |_id: usize| Box::new(backend.clone()) as Box<dyn Backend>;
    let out = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
    // 30 steps is enough for the loss to move down from ln(100) ≈ 4.6
    let first = out.series.steps.first().unwrap().train_loss;
    let last = out.summary.final_train_loss;
    assert!(last < first, "loss should descend: {first} -> {last}");
    assert!(out.summary.total_wire_bytes > 0);
}

#[test]
fn lm_grad_loss_near_uniform_entropy() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let model = engine.load_model(&manifest, "transformer_s").expect("load lm");
    let meta = model.meta.clone();
    assert_eq!(meta.classes, 256); // vocab

    let sections = meta.sections.clone();
    let params = orq::model::init::init_flat(&sections, &mut Rng::seed_from(1));
    let mut rng = Rng::seed_from(2);
    let tokens: Vec<i32> = (0..meta.batch * (meta.in_dim + 1))
        .map(|_| rng.below(256) as i32)
        .collect();
    let (loss, grad) = model.lm_grad(&params, &tokens).expect("lm grad");
    let uniform = (256f32).ln();
    assert!(
        (loss - uniform).abs() < 1.5,
        "init loss {loss} should be near ln(256)={uniform}"
    );
    assert_eq!(grad.len(), meta.param_count);
    assert!(grad.iter().all(|v| v.is_finite()));
    let gnorm = orq::tensor::norm2(&grad);
    assert!(gnorm > 0.0 && gnorm < 1e3, "grad norm {gnorm}");
}

#[test]
fn manifest_mismatch_is_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let msg = match PjrtBackend::load(&dir, "not_a_model") {
        Ok(_) => panic!("loading a missing model must fail"),
        Err(e) => e.to_string(),
    };
    assert!(msg.contains("not_a_model"), "{msg}");
}
