//! Negative-path coverage for the config/CLI surface grown in the
//! hierarchical-topology change: every bad combination must come back as
//! a typed `Err`, never a panic — these are exactly the inputs a user
//! typos on the command line or in an experiment file.

use orq::cli::Args;
use orq::comm::link::{Link, LinkMap};
use orq::comm::{build_topology, ExchangeConfig, Topology, WireSpec};
use orq::config::{parse, TrainConfig};

fn args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from)).unwrap()
}

fn cfg_from(toml: &str) -> orq::Result<TrainConfig> {
    TrainConfig::from_map(&parse(toml)?)
}

#[test]
fn unknown_topology_values_error() {
    for bad in ["mesh", "tree", "Hier", "ps2", ""] {
        assert!(Topology::parse(bad).is_err(), "{bad:?}");
    }
    // through the CLI parser
    let a = args("train --topology mesh");
    assert!(a.get_parse::<Topology>("topology").is_err());
    // through a config file
    assert!(cfg_from("[train]\ntopology = \"mesh\"").is_err());
    assert!(cfg_from("[train]\ntopology = 3").is_err());
    // and the valid spellings still parse
    let a = args("train --topology hier --groups 2");
    assert_eq!(a.get_parse::<Topology>("topology").unwrap(), Some(Topology::Hier));
    assert_eq!(a.get_parse::<usize>("groups").unwrap(), Some(2));
}

#[test]
fn groups_must_divide_the_world_size() {
    // config layer
    let bad = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 3");
    assert!(bad.is_err());
    let bad = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 0");
    assert!(bad.is_err());
    let ok = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 4");
    assert!(ok.is_ok());
    // groups is meaningless on flat topologies — error, not silence
    assert!(cfg_from("[train]\nworkers = 4\nbatch = 4\ngroups = 2").is_err());
    assert!(cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"ring\"\ngroups = 2").is_err());
    // comm layer independently enforces the same invariant
    let spec = WireSpec::new("terngrad", 64);
    let links = LinkMap::uniform(Link::ten_gbps());
    assert!(build_topology(&ExchangeConfig::hier(3, links), 4, &spec).is_err());
    assert!(build_topology(&ExchangeConfig::hier(0, links), 4, &spec).is_err());
    assert!(build_topology(&ExchangeConfig::hier(2, links), 4, &spec).is_ok());
}

#[test]
fn quantize_downlink_rejected_only_on_the_ring() {
    // the ring has no broadcast downlink to quantize — actionable error
    let err = cfg_from(
        "[train]\nworkers = 4\nbatch = 4\ntopology = \"ring\"\nquantize_downlink = true",
    )
    .unwrap_err();
    assert!(err.to_string().contains("ring"), "{err}");
    // every broadcast topology accepts it
    assert!(cfg_from("[train]\nworkers = 4\nbatch = 4\nquantize_downlink = true").is_ok());
    assert!(cfg_from(
        "[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 2\n\
         quantize_downlink = true"
    )
    .is_ok());
    assert!(cfg_from(
        "[train]\nworkers = 4\nbatch = 4\ntopology = \"sharded-ps\"\nshards = 2\n\
         quantize_downlink = true"
    )
    .is_ok());
    // comm layer enforces the same line
    let spec = WireSpec::new("terngrad", 64);
    let links = LinkMap::uniform(Link::ten_gbps());
    let hier_q = ExchangeConfig::hier(2, links).with_downlink(true);
    assert!(build_topology(&hier_q, 4, &spec).is_ok());
    let ring_q = ExchangeConfig::flat(Topology::Ring, Link::ten_gbps()).with_downlink(true);
    assert!(build_topology(&ring_q, 4, &spec).is_err());
}

#[test]
fn invalid_link_keys_error_instead_of_panicking() {
    // wrong types
    assert!(cfg_from("[train]\ninter_bandwidth = \"10G\"").is_err());
    assert!(cfg_from("[train]\nintra_latency = true").is_err());
    // non-physical values (these used to be able to reach Link::new's
    // assert; they must be caught at validation)
    assert!(cfg_from("[train]\ninter_bandwidth = 0").is_err());
    assert!(cfg_from("[train]\ninter_bandwidth = -5e9").is_err());
    assert!(cfg_from("[train]\nintra_bandwidth = 0.0").is_err());
    assert!(cfg_from("[train]\nintra_latency = -0.001").is_err());
    assert!(cfg_from("[train]\ninter_latency = -1").is_err());
    assert!(cfg_from("[train]\ninter_latency = nan").is_err());
    assert!(cfg_from("[train]\nintra_bandwidth = inf").is_err());
    // valid heterogeneous settings pass and build the right map
    let c = cfg_from(
        "[train]\nintra_bandwidth = 100e9\nintra_latency = 1e-6\n\
         inter_bandwidth = 1e9\ninter_latency = 0.02",
    )
    .unwrap();
    let lm = c.link_map();
    assert_eq!(lm.intra.bandwidth_bps, 100e9);
    assert_eq!(lm.inter.latency_s, 0.02);
}

#[test]
fn sharded_ps_knobs_rejected_with_actionable_errors() {
    // shards = 0 / negative / absurd counts
    let sharded = "[train]\nworkers = 2\nbatch = 64\ntopology = \"sharded-ps\"\n";
    for bad in ["shards = 0", "shards = -3", "shards = 100000"] {
        let err = cfg_from(&format!("{sharded}{bad}")).unwrap_err();
        assert!(err.to_string().contains("shards"), "{bad}: {err}");
    }
    // staleness < 0 (wraps through the i64 → usize cast) and absurd windows
    for bad in ["staleness = -1", "staleness = 100000"] {
        let err = cfg_from(&format!("{sharded}{bad}")).unwrap_err();
        assert!(err.to_string().contains("staleness"), "{bad}: {err}");
    }
    // staleness on a synchronous topology names the fix
    for topo in ["ps", "ring", "hier"] {
        let toml = format!(
            "[train]\nworkers = 4\nbatch = 4\ntopology = \"{topo}\"\nstaleness = 1{}",
            if topo == "hier" { "\ngroups = 2" } else { "" }
        );
        let err = cfg_from(&toml).unwrap_err();
        assert!(err.to_string().contains("sharded-ps"), "{topo}: {err}");
    }
    // shards on a non-sharded topology is an error, not silence
    assert!(cfg_from("[train]\nworkers = 2\nbatch = 64\nshards = 2").is_err());
    // valid sharded configs pass
    let ok = cfg_from(&format!("{sharded}shards = 2\nstaleness = 3")).unwrap();
    assert_eq!((ok.shards, ok.staleness), (2, 3));
    // comm layer independently enforces the same invariants
    let spec = WireSpec::new("terngrad", 64);
    let link = Link::ten_gbps();
    assert!(build_topology(&ExchangeConfig::sharded(0, 0, link), 2, &spec).is_err());
    let mut c = ExchangeConfig::flat(Topology::Ps, link);
    c.staleness = 1;
    assert!(build_topology(&c, 2, &spec).is_err());
    // more shards than the gradient has buckets: rejected at the first
    // exchange with an actionable message (trainer pre-checks too)
    let grads = vec![vec![0.5f32; 128]; 2]; // 2 buckets at d = 64
    let err =
        orq::comm::run_once(&ExchangeConfig::sharded(3, 0, link), &spec, &grads).unwrap_err();
    assert!(err.to_string().contains("bucket count"), "{err}");
    // CLI spellings parse
    let a = args("train --topology sharded-ps --shards 4 --staleness 2");
    assert_eq!(a.get_parse::<Topology>("topology").unwrap(), Some(Topology::ShardedPs));
    assert_eq!(a.get_parse::<usize>("shards").unwrap(), Some(4));
    assert_eq!(a.get_parse::<usize>("staleness").unwrap(), Some(2));
}

#[test]
fn error_feedback_rejected_where_it_cannot_compensate() {
    // fp has no quantization error
    let err = cfg_from("[train]\nworkers = 2\nbatch = 64\nerror_feedback = true").unwrap_err();
    assert!(err.to_string().contains("error_feedback"), "{err}");
    // ring/hier requantize per hop — each hop position now carries its
    // own residual, so the flag is accepted on every topology
    for topo in ["ring", "hier"] {
        let toml = format!(
            "[train]\nworkers = 4\nbatch = 4\nmethod = \"terngrad\"\n\
             topology = \"{topo}\"\nerror_feedback = true{}",
            if topo == "hier" { "\ngroups = 2" } else { "" }
        );
        assert!(cfg_from(&toml).is_ok(), "{topo}");
    }
    // the parallel codec composes with EF since the pipeline grew a
    // residual path (PR 5) — previously rejected, now accepted
    assert!(cfg_from(
        "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n\
         threads = 0\nerror_feedback = true"
    )
    .is_ok());
    // wrong value type
    assert!(cfg_from("[train]\nerror_feedback = 1").is_err());
    // the valid spelling passes on both PS paths
    assert!(cfg_from(
        "[train]\nworkers = 2\nbatch = 64\nmethod = \"bingrad-b\"\nerror_feedback = true"
    )
    .is_ok());
}

/// `lr_decay_steps` used to accept negative entries by wrapping them
/// through the i64 → usize cast into astronomically large step numbers
/// (silently disabling the decay). Negatives and absurd magnitudes must
/// both come back as typed errors now.
#[test]
fn lr_decay_steps_reject_negative_and_absurd_entries() {
    let base = "[train]\nworkers = 2\nbatch = 64\n";
    for bad in [
        "lr_decay_steps = [-1]",
        "lr_decay_steps = [100, -200]",
        "lr_decay_steps = [9223372036854775807]",
        "lr_decay_steps = [200000000]",
    ] {
        let err = cfg_from(&format!("{base}{bad}")).unwrap_err();
        assert!(err.to_string().contains("lr_decay_steps"), "{bad}: {err}");
    }
    // wrong element / value types stay errors too
    assert!(cfg_from(&format!("{base}lr_decay_steps = [true]")).is_err());
    assert!(cfg_from(&format!("{base}lr_decay_steps = \"80,120\"")).is_err());
    // valid schedules (empty, unsorted, duplicated) still pass
    let ok = cfg_from(&format!("{base}lr_decay_steps = [120, 80, 80]")).unwrap();
    assert_eq!(ok.lr_decay_steps, vec![120, 80, 80]);
    assert!(cfg_from(&format!("{base}lr_decay_steps = []")).is_ok());
}

/// The downlink flag's CLI spelling: a bare `--quantize-downlink` flag,
/// guarded by the train allowlist.
#[test]
fn quantize_downlink_cli_spelling_parses() {
    let a = args("train --method terngrad --quantize-downlink");
    assert!(a.flag("quantize-downlink"));
    assert!(a.check_known(&["method", "quantize-downlink"]).is_ok());
    let a = args("train --quantize-downlinkk");
    assert!(a.check_known(&["quantize-downlink"]).is_err());
}

#[test]
fn pool_key_validates_and_cli_spelling_parses() {
    // wrong value types are errors, not silent defaults
    assert!(cfg_from("[train]\npool = 1").is_err());
    assert!(cfg_from("[train]\npool = \"pooled\"").is_err());
    // both spellings pass through the config layer
    assert!(!cfg_from("[train]\nworkers = 2\nbatch = 64\npool = false").unwrap().pool);
    assert!(cfg_from("[train]\nworkers = 2\nbatch = 64\npool = true").unwrap().pool);
    // CLI: --pool takes a bool; garbage is a parse error
    let a = args("train --pool false");
    assert_eq!(a.get_parse::<bool>("pool").unwrap(), Some(false));
    let a = args("train --pool maybe");
    assert!(a.get_parse::<bool>("pool").is_err());
}

/// `sections` is an overlap knob: set without `--overlap` (or the
/// streaming flag that implies it) it would silently do nothing, so the
/// config layer rejects the combination with the fix spelled out.
#[test]
fn sections_without_overlap_rejected_with_actionable_error() {
    let base = "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n";
    let err = cfg_from(&format!("{base}sections = 2")).unwrap_err();
    assert!(err.to_string().contains("silently ignored"), "{err}");
    assert!(err.to_string().contains("--overlap"), "{err}");
    // the fix the message names works, through either spelling
    assert!(cfg_from(&format!("{base}sections = 2\noverlap = true")).is_ok());
    assert!(cfg_from(&format!("{base}sections = 2\nstream_sections = true")).is_ok());
    // CLI: --sections without --overlap hits the same validate wall
    let a = args("train --method terngrad --sections 2");
    assert_eq!(a.get_parse::<usize>("sections").unwrap(), Some(2));
    let mut cfg = orq::config::TrainConfig {
        workers: 2,
        batch: 64,
        method: "terngrad".into(),
        ..Default::default()
    };
    cfg.sections = a.get_parse::<usize>("sections").unwrap();
    let err = cfg.validate().unwrap_err();
    assert!(err.to_string().contains("--overlap"), "{err}");
}

/// The streaming flag's CLI spelling and its config-layer contract:
/// `stream_sections` implies `overlap`, needs a synchronous exchange,
/// and the broken direct construction (streaming without overlap) is
/// rejected rather than silently un-streamed.
#[test]
fn stream_sections_cli_and_config_contract() {
    // bare flag, guarded by the train allowlist
    let a = args("train --method terngrad --stream-sections");
    assert!(a.flag("stream-sections"));
    assert!(a.check_known(&["method", "stream-sections"]).is_ok());
    let a = args("train --stream-sectionss");
    assert!(a.check_known(&["stream-sections"]).is_err());
    // config spelling implies overlap
    let c = cfg_from(
        "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\nstream_sections = true",
    )
    .unwrap();
    assert!(c.stream_sections && c.overlap);
    // a staleness window would reorder section frames across rounds —
    // streaming is synchronous-only, and the message says so
    let err = cfg_from(
        "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n\
         topology = \"sharded-ps\"\nshards = 2\nstaleness = 1\nstream_sections = true",
    )
    .unwrap_err();
    assert!(err.to_string().contains("synchronous"), "{err}");
    // direct construction that breaks the implication is a typed error
    let mut c = orq::config::TrainConfig {
        workers: 2,
        batch: 64,
        method: "terngrad".into(),
        ..Default::default()
    };
    c.stream_sections = true;
    c.overlap = false;
    assert!(c.validate().is_err());
}

#[test]
fn cli_parser_rejects_malformed_input() {
    // bare operand after the subcommand
    assert!(Args::parse(["train".into(), "loose".into()]).is_err());
    // empty option name
    assert!(Args::parse(["train".into(), "--".into(), "x".into()]).is_err());
    // unknown option against the train command's allowlist
    let a = args("train --topologyy hier");
    assert!(a.check_known(&["topology", "groups"]).is_err());
    // unparsable numbers surface as errors
    let a = args("train --groups two");
    assert!(a.get_parse::<usize>("groups").is_err());
    let a = args("train --inter-bandwidth fast");
    assert!(a.get_parse::<f64>("inter-bandwidth").is_err());
}
