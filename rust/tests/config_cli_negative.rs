//! Negative-path coverage for the config/CLI surface grown in the
//! hierarchical-topology change: every bad combination must come back as
//! a typed `Err`, never a panic — these are exactly the inputs a user
//! typos on the command line or in an experiment file.

use orq::cli::Args;
use orq::comm::link::{Link, LinkMap};
use orq::comm::{build_topology, ExchangeConfig, Topology, WireSpec};
use orq::config::{parse, TrainConfig};

fn args(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from)).unwrap()
}

fn cfg_from(toml: &str) -> orq::Result<TrainConfig> {
    TrainConfig::from_map(&parse(toml)?)
}

#[test]
fn unknown_topology_values_error() {
    for bad in ["mesh", "tree", "Hier", "ps2", ""] {
        assert!(Topology::parse(bad).is_err(), "{bad:?}");
    }
    // through the CLI parser
    let a = args("train --topology mesh");
    assert!(a.get_parse::<Topology>("topology").is_err());
    // through a config file
    assert!(cfg_from("[train]\ntopology = \"mesh\"").is_err());
    assert!(cfg_from("[train]\ntopology = 3").is_err());
    // and the valid spellings still parse
    let a = args("train --topology hier --groups 2");
    assert_eq!(a.get_parse::<Topology>("topology").unwrap(), Some(Topology::Hier));
    assert_eq!(a.get_parse::<usize>("groups").unwrap(), Some(2));
}

#[test]
fn groups_must_divide_the_world_size() {
    // config layer
    let bad = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 3");
    assert!(bad.is_err());
    let bad = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 0");
    assert!(bad.is_err());
    let ok = cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"hier\"\ngroups = 4");
    assert!(ok.is_ok());
    // groups is meaningless on flat topologies — error, not silence
    assert!(cfg_from("[train]\nworkers = 4\nbatch = 4\ngroups = 2").is_err());
    assert!(cfg_from("[train]\nworkers = 4\nbatch = 4\ntopology = \"ring\"\ngroups = 2").is_err());
    // comm layer independently enforces the same invariant
    let spec = WireSpec::new("terngrad", 64);
    let links = LinkMap::uniform(Link::ten_gbps());
    assert!(build_topology(&ExchangeConfig::hier(3, links), 4, &spec).is_err());
    assert!(build_topology(&ExchangeConfig::hier(0, links), 4, &spec).is_err());
    assert!(build_topology(&ExchangeConfig::hier(2, links), 4, &spec).is_ok());
}

#[test]
fn quantize_downlink_is_ps_only() {
    for topo in ["ring", "hier"] {
        let toml = format!(
            "[train]\nworkers = 4\nbatch = 4\ntopology = \"{topo}\"\nquantize_downlink = true{}",
            if topo == "hier" { "\ngroups = 2" } else { "" }
        );
        assert!(cfg_from(&toml).is_err(), "{topo}");
    }
    let ok = cfg_from("[train]\nworkers = 4\nbatch = 4\nquantize_downlink = true");
    assert!(ok.is_ok());
    // comm layer
    let spec = WireSpec::new("terngrad", 64);
    let links = LinkMap::uniform(Link::ten_gbps());
    let hier_q = ExchangeConfig::hier(2, links).with_downlink(true);
    assert!(build_topology(&hier_q, 4, &spec).is_err());
    let ring_q = ExchangeConfig::flat(Topology::Ring, Link::ten_gbps()).with_downlink(true);
    assert!(build_topology(&ring_q, 4, &spec).is_err());
}

#[test]
fn invalid_link_keys_error_instead_of_panicking() {
    // wrong types
    assert!(cfg_from("[train]\ninter_bandwidth = \"10G\"").is_err());
    assert!(cfg_from("[train]\nintra_latency = true").is_err());
    // non-physical values (these used to be able to reach Link::new's
    // assert; they must be caught at validation)
    assert!(cfg_from("[train]\ninter_bandwidth = 0").is_err());
    assert!(cfg_from("[train]\ninter_bandwidth = -5e9").is_err());
    assert!(cfg_from("[train]\nintra_bandwidth = 0.0").is_err());
    assert!(cfg_from("[train]\nintra_latency = -0.001").is_err());
    assert!(cfg_from("[train]\ninter_latency = -1").is_err());
    assert!(cfg_from("[train]\ninter_latency = nan").is_err());
    assert!(cfg_from("[train]\nintra_bandwidth = inf").is_err());
    // valid heterogeneous settings pass and build the right map
    let c = cfg_from(
        "[train]\nintra_bandwidth = 100e9\nintra_latency = 1e-6\n\
         inter_bandwidth = 1e9\ninter_latency = 0.02",
    )
    .unwrap();
    let lm = c.link_map();
    assert_eq!(lm.intra.bandwidth_bps, 100e9);
    assert_eq!(lm.inter.latency_s, 0.02);
}

#[test]
fn cli_parser_rejects_malformed_input() {
    // bare operand after the subcommand
    assert!(Args::parse(["train".into(), "loose".into()]).is_err());
    // empty option name
    assert!(Args::parse(["train".into(), "--".into(), "x".into()]).is_err());
    // unknown option against the train command's allowlist
    let a = args("train --topologyy hier");
    assert!(a.check_known(&["topology", "groups"]).is_err());
    // unparsable numbers surface as errors
    let a = args("train --groups two");
    assert!(a.get_parse::<usize>("groups").is_err());
    let a = args("train --inter-bandwidth fast");
    assert!(a.get_parse::<f64>("inter-bandwidth").is_err());
}
