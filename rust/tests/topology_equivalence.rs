//! Topology equivalence: the parameter-server star and the ring
//! all-reduce are two transports for the SAME exchange semantics — the
//! mean of the decoded uploads. Swept over the gradient-distribution
//! families (the proptest role in this offline build):
//!
//! * `fp` is lossless on both, so the decoded means must agree (up to
//!   f32 summation order: PS sums worker-major in f64, the ring folds
//!   chunk partial sums hop by hop);
//! * every ring node must decode the bit-identical mean — the invariant
//!   that keeps parameter replicas in sync without parameter traffic;
//! * wire bytes must match the closed-form `codec::wire_size` accounting
//!   exactly, per topology;
//! * the ring's simulated critical path must agree with the closed-form
//!   `ring::allreduce_time` model up to per-chunk header overhead.

use orq::codec::{wire_size, Packing};
use orq::comm::link::Link;
use orq::comm::{build_topology, ring, run_once, Topology, WireSpec};
use orq::testutil::{sample, ALL_DISTS};
use orq::tensor::rng::Rng;

fn spec(method: &str, bucket: usize) -> WireSpec {
    WireSpec { seed: 5, ..WireSpec::new(method, bucket) }
}

fn grads(n: usize, workers: usize, dist_seed: u64) -> Vec<Vec<f32>> {
    let dist = ALL_DISTS[(dist_seed as usize) % ALL_DISTS.len()];
    let mut rng = Rng::stream(900 + dist_seed, dist_seed);
    (0..workers).map(|_| sample(dist, n, 1.0, &mut rng)).collect()
}

/// Exact mean in f64 (the semantics both topologies approximate).
fn exact_mean(gs: &[Vec<f32>]) -> Vec<f32> {
    let n = gs[0].len();
    let inv = 1.0 / gs.len() as f64;
    (0..n)
        .map(|i| (gs.iter().map(|g| g[i] as f64).sum::<f64>() * inv) as f32)
        .collect()
}

#[test]
fn fp_means_agree_across_topologies() {
    let link = Link::ten_gbps();
    for dist_seed in 0..ALL_DISTS.len() as u64 {
        for workers in [1usize, 2, 3, 5] {
            let gs = grads(1536, workers, dist_seed);
            let sp = spec("fp", 256);
            let (ps_mean, _) = run_once(Topology::Ps, link, &sp, false, &gs).unwrap();
            let (ring_mean, _) = run_once(Topology::Ring, link, &sp, false, &gs).unwrap();
            assert_eq!(ps_mean.len(), 1536);
            assert_eq!(ring_mean.len(), 1536);
            let exact = exact_mean(&gs);
            for (i, ((p, r), e)) in ps_mean.iter().zip(&ring_mean).zip(&exact).enumerate() {
                let tol = 1e-5f32 * (1.0 + e.abs());
                assert!(
                    (p - e).abs() <= tol,
                    "dist {dist_seed} L={workers} ps[{i}]={p} exact={e}"
                );
                assert!(
                    (r - e).abs() <= tol,
                    "dist {dist_seed} L={workers} ring[{i}]={r} exact={e}"
                );
            }
        }
    }
}

/// Every ring node must apply the bit-identical decoded mean — quantized
/// schemes included (all-gather forwards final encoded chunks verbatim).
#[test]
fn ring_mean_bit_identical_on_every_node() {
    let link = Link::ten_gbps();
    for method in ["fp", "terngrad", "orq-5"] {
        let workers = 4;
        let gs = grads(2048, workers, 1);
        let sp = spec(method, 256);
        let (mut coll, ends) = build_topology(Topology::Ring, workers, link, &sp, false).unwrap();
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();
        let mut coord_mean = Vec::new();
        std::thread::scope(|scope| {
            for (w, mut wx) in ends.into_iter().enumerate() {
                let g: &[f32] = &gs[w];
                let sp = sp.clone();
                let tx = tx.clone();
                scope.spawn(move || {
                    let gc = orq::comm::GradCodec::new(&sp).unwrap();
                    let mut rng = Rng::stream(sp.seed, 2_000 + w as u64);
                    let mut qg = orq::quant::bucket::QuantizedGrad::default();
                    let mut msg = Vec::new();
                    gc.encode_into(g, &mut rng, &mut qg, &mut msg);
                    let mut mean = Vec::new();
                    wx.exchange(&mut msg, &mut mean).unwrap();
                    tx.send((w, mean)).unwrap();
                });
            }
            coll.round(&mut coord_mean).unwrap();
        });
        drop(tx);
        let mut means: Vec<(usize, Vec<f32>)> = rx.iter().collect();
        means.sort_by_key(|(w, _)| *w);
        assert_eq!(means.len(), workers, "{method}");
        for (w, m) in &means {
            assert_eq!(m, &means[0].1, "{method}: node {w} diverged from node 0");
        }
        assert_eq!(coord_mean, means[0].1, "{method}: coordinator mean diverged");
    }
}

#[test]
fn wire_bytes_match_codec_accounting_exactly() {
    let link = Link::ten_gbps();
    // n = L·d·k keeps every ring chunk equal-sized and non-empty, so the
    // closed-form per-chunk sizes apply verbatim.
    let workers = 4;
    let d = 128;
    let n = workers * d * 3; // 12 buckets → 3 per chunk
    for (method, s) in [("terngrad", 3usize), ("orq-5", 5), ("fp", 0)] {
        let gs = grads(n, workers, 2);
        let sp = spec(method, d);
        // PS: L quantized uplinks + 1 FP broadcast.
        let (_, ps) = run_once(Topology::Ps, link, &sp, false, &gs).unwrap();
        let up = wire_size(n, d, s, Packing::BaseS, method) as u64;
        let down = wire_size(n, n.max(1), 0, Packing::BaseS, "fp") as u64;
        assert_eq!(ps.wire_bytes, workers as u64 * up + down, "{method} ps bytes");
        assert_eq!(ps.messages, workers as u64 + 1, "{method} ps messages");
        // Ring: every chunk crosses 2(L−1) edges, each message an
        // independently-headered chunk of n/L elements.
        let (_, rg) = run_once(Topology::Ring, link, &sp, false, &gs).unwrap();
        let chunk_msg = wire_size(n / workers, d, s, Packing::BaseS, method) as u64;
        let hops = 2 * (workers as u64 - 1);
        assert_eq!(rg.wire_bytes, hops * workers as u64 * chunk_msg, "{method} ring bytes");
        assert_eq!(rg.messages, hops * workers as u64, "{method} ring messages");
    }
}

#[test]
fn ring_sim_time_matches_model_up_to_headers() {
    let link = Link::ten_gbps();
    let workers = 8;
    let d = 512;
    let n = workers * d * 32; // 131072 elements, equal chunks
    let gs = grads(n, workers, 3);
    let sp = spec("fp", d);
    let (_, rg) = run_once(Topology::Ring, link, &sp, false, &gs).unwrap();
    // Exact prediction: 2(L−1) steps, every node ships an equal fp chunk
    // message, so the per-step max equals any single transfer.
    let chunk_msg = wire_size(n / workers, d, 0, Packing::BaseS, "fp");
    let exact = 2.0 * (workers - 1) as f64 * link.transfer_time(chunk_msg);
    assert!((rg.sim_time_s - exact).abs() < 1e-12, "measured {} vs exact {exact}", rg.sim_time_s);
    // The closed-form model ignores the 22-byte per-message header, so it
    // is a strict but tight lower bound at this scale.
    let model = ring::allreduce_time(&link, workers, n * 4);
    assert!(rg.sim_time_s > model, "headers make measured > model");
    assert!(rg.sim_time_s < model * 1.01, "within 1%: {} vs {model}", rg.sim_time_s);
}

/// Quantized ring exchange: per-hop requantization is lossy, but the
/// decoded mean must stay a faithful direction estimate of the exact
/// mean, on every distribution family.
#[test]
fn quantized_ring_mean_tracks_exact_mean() {
    let link = Link::ten_gbps();
    for dist_seed in 0..ALL_DISTS.len() as u64 {
        let workers = 4;
        let gs = grads(4096, workers, dist_seed);
        let exact = exact_mean(&gs);
        // ORQ's distribution-adaptive levels keep the estimate faithful
        // even on the heavy-tailed families (the paper's selling point).
        let sp = spec("orq-5", 512);
        let (ring_mean, _) = run_once(Topology::Ring, link, &sp, false, &gs).unwrap();
        let cos = orq::tensor::cosine(&ring_mean, &exact);
        assert!(cos > 0.25, "dist {dist_seed}: ring mean decorrelated, cosine={cos}");
        let (ps_mean, _) = run_once(Topology::Ps, link, &sp, false, &gs).unwrap();
        let cos_ps = orq::tensor::cosine(&ps_mean, &exact);
        assert!(cos_ps > 0.25, "dist {dist_seed}: ps cosine={cos_ps}");
    }
}

/// Ragged case: n not divisible by L·d still covers every element —
/// uneven (and possibly empty) chunks must round-trip.
#[test]
fn ring_handles_ragged_and_empty_chunks() {
    let link = Link::ten_gbps();
    for (n, workers, d) in [(1000usize, 3usize, 128usize), (100, 6, 64), (5, 4, 2), (1, 3, 4)] {
        let gs = grads(n, workers, 4);
        let sp = spec("fp", d);
        let (ring_mean, _) = run_once(Topology::Ring, link, &sp, false, &gs).unwrap();
        let exact = exact_mean(&gs);
        assert_eq!(ring_mean.len(), n, "n={n} L={workers} d={d}");
        for (i, (r, e)) in ring_mean.iter().zip(&exact).enumerate() {
            assert!(
                (r - e).abs() <= 1e-5 * (1.0 + e.abs()),
                "n={n} L={workers} d={d} i={i}"
            );
        }
    }
}
