//! Topology equivalence: the parameter-server star, the ring all-reduce
//! and the hierarchical two-level collective are three transports for the
//! SAME exchange semantics — the mean of the decoded uploads. Swept over
//! the gradient-distribution families (the proptest role in this offline
//! build):
//!
//! * `fp` is lossless on all three, so the decoded means must agree (up
//!   to f32 summation order: PS sums worker-major in f64, the ring folds
//!   chunk partial sums hop by hop, the hierarchy folds within groups
//!   then across groups in f64);
//! * every node must decode the bit-identical mean — the invariant that
//!   keeps parameter replicas in sync without parameter traffic (the
//!   ring forwards final encoded chunks verbatim; the hierarchy
//!   multicasts one message — FP by default, requantized once at the
//!   root under `quantize_downlink` — and the aggregation point always
//!   decodes its own bytes);
//! * per-hop error feedback and quantized downlinks change *what* is
//!   transmitted, never the every-node-same-bytes property, and the EF
//!   residuals must measurably cancel requantization bias over rounds;
//! * wire bytes must match the closed-form `codec::wire_size` accounting
//!   exactly — *per edge class* for the hierarchy (intra-group ring and
//!   gather traffic vs inter-group leader-star traffic);
//! * simulated critical-path times must agree with the closed-form
//!   models (`ring::allreduce_time`, `hier::hier_time`) up to per-chunk
//!   header overhead.

use orq::codec::{wire_size, Packing};
use orq::comm::link::{Link, LinkMap};
use orq::comm::{
    build_topology, hier, ring, run_once, run_rounds, shard, ExchangeConfig, PoolMode, Topology,
    WireSpec,
};
use orq::quant::pool::PoolHandle;
use orq::testutil::{sample, ALL_DISTS};
use orq::tensor::rng::Rng;

fn spec(method: &str, bucket: usize) -> WireSpec {
    WireSpec { seed: 5, ..WireSpec::new(method, bucket) }
}

fn grads(n: usize, workers: usize, dist_seed: u64) -> Vec<Vec<f32>> {
    let dist = ALL_DISTS[(dist_seed as usize) % ALL_DISTS.len()];
    let mut rng = Rng::stream(900 + dist_seed, dist_seed);
    (0..workers).map(|_| sample(dist, n, 1.0, &mut rng)).collect()
}

fn flat(topology: Topology) -> ExchangeConfig {
    ExchangeConfig::flat(topology, Link::ten_gbps())
}

fn hier_cfg(groups: usize) -> ExchangeConfig {
    ExchangeConfig::hier(groups, LinkMap::uniform(Link::ten_gbps()))
}

fn sharded_cfg(shards: usize, staleness: usize) -> ExchangeConfig {
    ExchangeConfig::sharded(shards, staleness, Link::ten_gbps())
}

/// Exact mean in f64 (the semantics all topologies approximate).
fn exact_mean(gs: &[Vec<f32>]) -> Vec<f32> {
    let n = gs[0].len();
    let inv = 1.0 / gs.len() as f64;
    (0..n)
        .map(|i| (gs.iter().map(|g| g[i] as f64).sum::<f64>() * inv) as f32)
        .collect()
}

/// Divisors of `w` — the legal `groups` values for a hier run.
fn divisors(w: usize) -> Vec<usize> {
    (1..=w).filter(|g| w % g == 0).collect()
}

#[test]
fn fp_means_agree_across_topologies() {
    for dist_seed in 0..ALL_DISTS.len() as u64 {
        for workers in [1usize, 2, 3, 5] {
            let gs = grads(1536, workers, dist_seed);
            let sp = spec("fp", 256);
            let (ps_mean, _) = run_once(&flat(Topology::Ps), &sp, &gs).unwrap();
            let (ring_mean, _) = run_once(&flat(Topology::Ring), &sp, &gs).unwrap();
            assert_eq!(ps_mean.len(), 1536);
            assert_eq!(ring_mean.len(), 1536);
            let exact = exact_mean(&gs);
            for (i, ((p, r), e)) in ps_mean.iter().zip(&ring_mean).zip(&exact).enumerate() {
                let tol = 1e-5f32 * (1.0 + e.abs());
                assert!(
                    (p - e).abs() <= tol,
                    "dist {dist_seed} L={workers} ps[{i}]={p} exact={e}"
                );
                assert!(
                    (r - e).abs() <= tol,
                    "dist {dist_seed} L={workers} ring[{i}]={r} exact={e}"
                );
            }
            // every legal grouping of the hierarchy agrees too
            for groups in divisors(workers) {
                let (h_mean, _) = run_once(&hier_cfg(groups), &sp, &gs).unwrap();
                assert_eq!(h_mean.len(), 1536);
                for (i, (h, e)) in h_mean.iter().zip(&exact).enumerate() {
                    let tol = 1e-5f32 * (1.0 + e.abs());
                    assert!(
                        (h - e).abs() <= tol,
                        "dist {dist_seed} L={workers} G={groups} hier[{i}]={h} exact={e}"
                    );
                }
            }
        }
    }
}

/// Every node of a topology must apply the bit-identical decoded mean —
/// quantized schemes included. The ring forwards final encoded chunks
/// verbatim; the hierarchy multicasts a single FP message down the tree.
fn assert_mean_bit_identical(cfg: &ExchangeConfig, workers: usize, method: &str) {
    let gs = grads(2048, workers, 1);
    let sp = spec(method, 256);
    let (mut coll, ends) = build_topology(cfg, workers, &sp).unwrap();
    let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<f32>)>();
    let mut coord_mean = Vec::new();
    std::thread::scope(|scope| {
        for (w, mut wx) in ends.into_iter().enumerate() {
            let g: &[f32] = &gs[w];
            let sp = sp.clone();
            let tx = tx.clone();
            scope.spawn(move || {
                let mut gc = orq::comm::GradCodec::new(&sp).unwrap();
                let mut rng = Rng::stream(sp.seed, 2_000 + w as u64);
                let mut qg = orq::quant::bucket::QuantizedGrad::default();
                let mut msg = Vec::new();
                gc.encode_into(g, &mut rng, &mut qg, &mut msg);
                let mut mean = Vec::new();
                wx.exchange(&mut msg, &mut mean).unwrap();
                tx.send((w, mean)).unwrap();
            });
        }
        coll.round(&mut coord_mean).unwrap();
    });
    drop(tx);
    let mut means: Vec<(usize, Vec<f32>)> = rx.iter().collect();
    means.sort_by_key(|(w, _)| *w);
    assert_eq!(means.len(), workers, "{method} {:?}", cfg.topology);
    for (w, m) in &means {
        assert_eq!(
            m, &means[0].1,
            "{method} {:?}: node {w} diverged from node 0",
            cfg.topology
        );
    }
    assert_eq!(
        coord_mean, means[0].1,
        "{method} {:?}: coordinator mean diverged",
        cfg.topology
    );
}

#[test]
fn ring_mean_bit_identical_on_every_node() {
    for method in ["fp", "terngrad", "orq-5"] {
        assert_mean_bit_identical(&flat(Topology::Ring), 4, method);
    }
}

#[test]
fn hier_mean_bit_identical_on_every_node() {
    for method in ["fp", "terngrad", "orq-5"] {
        // leaders, members and the root across several groupings
        assert_mean_bit_identical(&hier_cfg(2), 4, method);
        assert_mean_bit_identical(&hier_cfg(3), 6, method);
        assert_mean_bit_identical(&hier_cfg(1), 4, method);
        assert_mean_bit_identical(&hier_cfg(4), 4, method);
    }
}

#[test]
fn wire_bytes_match_codec_accounting_exactly() {
    // n = L·d·k keeps every ring chunk equal-sized and non-empty, so the
    // closed-form per-chunk sizes apply verbatim.
    let workers = 4;
    let d = 128;
    let n = workers * d * 3; // 12 buckets → 3 per chunk
    for (method, s) in [("terngrad", 3usize), ("orq-5", 5), ("fp", 0)] {
        let gs = grads(n, workers, 2);
        let sp = spec(method, d);
        // PS: L quantized uplinks + 1 FP broadcast, all on inter edges.
        let (_, ps) = run_once(&flat(Topology::Ps), &sp, &gs).unwrap();
        let up = wire_size(n, d, s, Packing::BaseS, method) as u64;
        let down = wire_size(n, n.max(1), 0, Packing::BaseS, "fp") as u64;
        assert_eq!(ps.wire_bytes, workers as u64 * up + down, "{method} ps bytes");
        assert_eq!(ps.messages, workers as u64 + 1, "{method} ps messages");
        assert_eq!(ps.wire_bytes_intra, 0, "{method} ps intra");
        assert_eq!(ps.wire_bytes_inter, ps.wire_bytes, "{method} ps inter");
        // Ring: every chunk crosses 2(L−1) edges, each message an
        // independently-headered chunk of n/L elements.
        let (_, rg) = run_once(&flat(Topology::Ring), &sp, &gs).unwrap();
        let chunk_msg = wire_size(n / workers, d, s, Packing::BaseS, method) as u64;
        let hops = 2 * (workers as u64 - 1);
        assert_eq!(rg.wire_bytes, hops * workers as u64 * chunk_msg, "{method} ring bytes");
        assert_eq!(rg.messages, hops * workers as u64, "{method} ring messages");
        assert_eq!(rg.wire_bytes_intra, 0, "{method} ring intra");
    }
}

/// Hierarchy byte accounting per edge class: intra = in-group ring hops +
/// chunk gather + leader multicast, inter = leader uplinks + root
/// multicast, every message an independently headered chunk/gradient.
#[test]
fn hier_wire_bytes_match_codec_accounting_per_edge_class() {
    let workers = 4usize;
    let groups = 2usize;
    let m = workers / groups;
    let d = 128;
    let n = m * d * 3; // equal in-group chunks of n/m elements
    for (method, s) in [("terngrad", 3usize), ("orq-5", 5), ("fp", 0)] {
        let gs = grads(n, workers, 2);
        let sp = spec(method, d);
        let (_, st) = run_once(&hier_cfg(groups), &sp, &gs).unwrap();
        let chunk_msg = wire_size(n / m, d, s, Packing::BaseS, method) as u64;
        let grad_msg = wire_size(n, d, s, Packing::BaseS, method) as u64;
        let fp_msg = wire_size(n, n.max(1), 0, Packing::BaseS, "fp") as u64;
        // intra: L·(m−1) reduce-scatter hops + (L−G) gather messages of
        // one chunk each, plus G leader multicasts of the FP mean
        // (counted once per group, the PS broadcast convention).
        let intra = (workers * (m - 1) + (workers - groups)) as u64 * chunk_msg
            + groups as u64 * fp_msg;
        // inter: G−1 requantized group sums up + 1 root multicast down.
        let inter = (groups as u64 - 1) * grad_msg + fp_msg;
        assert_eq!(st.wire_bytes_intra, intra, "{method} hier intra bytes");
        assert_eq!(st.wire_bytes_inter, inter, "{method} hier inter bytes");
        assert_eq!(st.wire_bytes, intra + inter, "{method} hier total");
        let msgs = (workers * (m - 1) + (workers - groups) + groups + groups) as u64;
        assert_eq!(st.messages, msgs, "{method} hier messages");
    }
    // groups == workers degenerates to a leader star: the uplinks are the
    // workers' ORIGINAL encoded gradients (no extra requantization), and
    // nothing crosses an intra edge.
    let gs = grads(n, workers, 3);
    let sp = spec("terngrad", d);
    let (_, st) = run_once(&hier_cfg(workers), &sp, &gs).unwrap();
    let grad_msg = wire_size(n, d, 3, Packing::BaseS, "terngrad") as u64;
    let fp_msg = wire_size(n, n.max(1), 0, Packing::BaseS, "fp") as u64;
    assert_eq!(st.wire_bytes_intra, 0);
    assert_eq!(st.wire_bytes_inter, (workers as u64 - 1) * grad_msg + fp_msg);
}

#[test]
fn ring_sim_time_matches_model_up_to_headers() {
    let link = Link::ten_gbps();
    let workers = 8;
    let d = 512;
    let n = workers * d * 32; // 131072 elements, equal chunks
    let gs = grads(n, workers, 3);
    let sp = spec("fp", d);
    let (_, rg) = run_once(&flat(Topology::Ring), &sp, &gs).unwrap();
    // Exact prediction: 2(L−1) steps, every node ships an equal fp chunk
    // message, so the per-step max equals any single transfer.
    let chunk_msg = wire_size(n / workers, d, 0, Packing::BaseS, "fp");
    let exact = 2.0 * (workers - 1) as f64 * link.transfer_time(chunk_msg);
    assert!((rg.sim_time_s - exact).abs() < 1e-12, "measured {} vs exact {exact}", rg.sim_time_s);
    // The closed-form model ignores the 22-byte per-message header, so it
    // is a strict but tight lower bound at this scale.
    let model = ring::allreduce_time(&link, workers, n * 4);
    assert!(rg.sim_time_s > model, "headers make measured > model");
    assert!(rg.sim_time_s < model * 1.01, "within 1%: {} vs {model}", rg.sim_time_s);
}

/// Hierarchy critical path on a heterogeneous link map: the measured time
/// must equal the exact per-step prediction, and track the closed-form
/// `hier::hier_time` model up to per-chunk header overhead.
#[test]
fn hier_sim_time_matches_model_up_to_headers() {
    let links = LinkMap::new(Link::new(100e9, 1e-6), Link::new(1e9, 0.005));
    let workers = 4usize;
    let groups = 2usize;
    let m = workers / groups;
    let d = 512;
    let n = m * d * 16; // 16384 elements, equal in-group chunks
    let gs = grads(n, workers, 4);
    let sp = spec("fp", d);
    let cfg = ExchangeConfig::hier(groups, links);
    let (_, st) = run_once(&cfg, &sp, &gs).unwrap();
    let chunk_msg = wire_size(n / m, d, 0, Packing::BaseS, "fp");
    let fp_msg = wire_size(n, n.max(1), 0, Packing::BaseS, "fp");
    // Steps: (m−1) reduce-scatter + 1 gather (intra, chunk each), leader
    // uplink (inter, full fp gradient), root multicast (inter, fp mean),
    // leader multicast (intra, fp mean).
    let exact = m as f64 * links.intra.transfer_time(chunk_msg)
        + links.inter.transfer_time(fp_msg)
        + links.inter.transfer_time(fp_msg)
        + links.intra.transfer_time(fp_msg);
    assert!(
        (st.sim_time_s - exact).abs() < 1e-12,
        "measured {} vs exact {exact}",
        st.sim_time_s
    );
    // Closed form ignores the 22-byte headers: strict, tight lower bound.
    let model = hier::hier_time(&links, workers, groups, n * 4, n * 4);
    assert!(st.sim_time_s > model, "headers make measured > model");
    assert!(st.sim_time_s < model * 1.01, "within 1%: {} vs {model}", st.sim_time_s);
}

/// Quantized exchange: per-hop/leader requantization is lossy, but the
/// decoded mean must stay a faithful direction estimate of the exact
/// mean, on every distribution family and every topology.
#[test]
fn quantized_mean_tracks_exact_mean() {
    for dist_seed in 0..ALL_DISTS.len() as u64 {
        let workers = 4;
        let gs = grads(4096, workers, dist_seed);
        let exact = exact_mean(&gs);
        // ORQ's distribution-adaptive levels keep the estimate faithful
        // even on the heavy-tailed families (the paper's selling point).
        let sp = spec("orq-5", 512);
        let (ring_mean, _) = run_once(&flat(Topology::Ring), &sp, &gs).unwrap();
        let cos = orq::tensor::cosine(&ring_mean, &exact);
        assert!(cos > 0.25, "dist {dist_seed}: ring mean decorrelated, cosine={cos}");
        let (ps_mean, _) = run_once(&flat(Topology::Ps), &sp, &gs).unwrap();
        let cos_ps = orq::tensor::cosine(&ps_mean, &exact);
        assert!(cos_ps > 0.25, "dist {dist_seed}: ps cosine={cos_ps}");
        let (h_mean, _) = run_once(&hier_cfg(2), &sp, &gs).unwrap();
        let cos_h = orq::tensor::cosine(&h_mean, &exact);
        assert!(cos_h > 0.25, "dist {dist_seed}: hier cosine={cos_h}");
    }
}

/// Ragged case: n not divisible by L·d (or m·d) still covers every
/// element — uneven (and possibly empty) chunks must round-trip.
#[test]
fn ring_and_hier_handle_ragged_and_empty_chunks() {
    for (n, workers, d) in [(1000usize, 3usize, 128usize), (100, 6, 64), (5, 4, 2), (1, 3, 4)] {
        let gs = grads(n, workers, 4);
        let sp = spec("fp", d);
        let (ring_mean, _) = run_once(&flat(Topology::Ring), &sp, &gs).unwrap();
        let exact = exact_mean(&gs);
        assert_eq!(ring_mean.len(), n, "n={n} L={workers} d={d}");
        for (i, (r, e)) in ring_mean.iter().zip(&exact).enumerate() {
            assert!(
                (r - e).abs() <= 1e-5 * (1.0 + e.abs()),
                "n={n} L={workers} d={d} i={i}"
            );
        }
        for groups in divisors(workers) {
            let (h_mean, _) = run_once(&hier_cfg(groups), &sp, &gs).unwrap();
            assert_eq!(h_mean.len(), n, "hier n={n} L={workers} G={groups} d={d}");
            for (i, (h, e)) in h_mean.iter().zip(&exact).enumerate() {
                assert!(
                    (h - e).abs() <= 1e-5 * (1.0 + e.abs()),
                    "hier n={n} L={workers} G={groups} d={d} i={i}"
                );
            }
        }
    }
}

/// Acceptance criterion of the sharded subsystem: with S = 1, K = 0 the
/// sharded parameter server decodes a mean *bit-identical* to the flat
/// PS, for every scheme family — the frames wrap the same codec
/// payloads, the shard reduces in the same worker order and f64
/// accumulation, and the FP downlink is lossless.
#[test]
fn sharded_ps_s1_k0_bit_identical_to_ps() {
    for method in ["orq-5", "linear-9", "bingrad-b", "fp"] {
        for workers in [1usize, 2, 5] {
            let gs = grads(2048, workers, 3);
            let sp = spec(method, 256);
            let (ps_mean, _) = run_once(&flat(Topology::Ps), &sp, &gs).unwrap();
            let (sh_mean, _) = run_once(&sharded_cfg(1, 0), &sp, &gs).unwrap();
            assert_eq!(ps_mean, sh_mean, "{method} L={workers}");
        }
    }
}

/// Shard-count invariance at K = 0: the bucket grid can be cut into any
/// number of shards (including ones that leave ragged chunk sizes)
/// without changing a single bit of the decoded mean — per-element f64
/// accumulation order is worker order regardless of the partition.
#[test]
fn sharded_mean_invariant_across_shard_counts() {
    for method in ["orq-5", "terngrad", "fp"] {
        let gs = grads(2048, 3, 1); // d = 256 → 8 buckets
        let sp = spec(method, 256);
        let (reference, _) = run_once(&sharded_cfg(1, 0), &sp, &gs).unwrap();
        for shards in [2usize, 4, 7] {
            let (mean, _) = run_once(&sharded_cfg(shards, 0), &sp, &gs).unwrap();
            assert_eq!(mean, reference, "{method} S={shards}");
        }
    }
}

/// Every node of the sharded topology (workers and coordinator) decodes
/// the bit-identical mean — the replica-sync invariant, like ps/ring/hier.
#[test]
fn sharded_mean_bit_identical_on_every_node() {
    for method in ["fp", "terngrad", "orq-5"] {
        assert_mean_bit_identical(&sharded_cfg(2, 0), 4, method);
        assert_mean_bit_identical(&sharded_cfg(4, 0), 3, method);
    }
}

/// Sharded-ps byte accounting: L·S framed chunk uploads + S framed FP
/// mean broadcasts per round, every message an independently headered
/// codec payload wrapped in a `FRAME_HEADER_BYTES` versioned frame, all
/// on inter-class edges.
#[test]
fn sharded_wire_bytes_match_codec_accounting_exactly() {
    let workers = 4usize;
    let shards = 2usize;
    let d = 128usize;
    let n = shards * d * 3; // equal chunks of n/S elements
    for (method, s) in [("terngrad", 3usize), ("orq-5", 5), ("fp", 0)] {
        let gs = grads(n, workers, 2);
        let sp = spec(method, d);
        let (_, st) = run_once(&sharded_cfg(shards, 0), &sp, &gs).unwrap();
        let chunk = n / shards;
        let up = (shard::FRAME_HEADER_BYTES + wire_size(chunk, d, s, Packing::BaseS, method))
            as u64;
        let down = (shard::FRAME_HEADER_BYTES
            + wire_size(chunk, chunk.max(1), 0, Packing::BaseS, "fp")) as u64;
        let want = (workers * shards) as u64 * up + shards as u64 * down;
        assert_eq!(st.wire_bytes, want, "{method} sharded bytes");
        assert_eq!(st.messages, (workers * shards + shards) as u64, "{method} messages");
        assert_eq!(st.wire_bytes_intra, 0, "{method} intra");
        assert_eq!(st.wire_bytes_inter, st.wire_bytes, "{method} inter");
    }
}

/// Synchronous sharded critical path: measured time equals the exact
/// per-frame prediction and exceeds the closed-form `shard::sharded_time`
/// model by only the per-chunk header overhead.
#[test]
fn sharded_sim_time_matches_model_up_to_headers() {
    let link = Link::ten_gbps();
    let workers = 3usize;
    let shards = 4usize;
    let d = 256usize;
    let n = shards * d * 8; // 8192 elements, equal chunks
    let gs = grads(n, workers, 3);
    let sp = spec("fp", d);
    let (_, st) = run_once(&sharded_cfg(shards, 0), &sp, &gs).unwrap();
    let chunk = n / shards;
    let up_msg = shard::FRAME_HEADER_BYTES + wire_size(chunk, d, 0, Packing::BaseS, "fp");
    let down_msg =
        shard::FRAME_HEADER_BYTES + wire_size(chunk, chunk.max(1), 0, Packing::BaseS, "fp");
    // Equal chunks: the slowest shard's star equals any shard's star.
    let exact = link.transfer_time(up_msg) + link.transfer_time(down_msg);
    assert!(
        (st.sim_time_s - exact).abs() < 1e-12,
        "measured {} vs exact {exact}",
        st.sim_time_s
    );
    // Closed form ignores the 22 + 22 byte headers: strict lower bound.
    let model = shard::sharded_time(&link, workers, shards, n * 4, n * 4);
    assert!(st.sim_time_s > model, "headers make measured > model");
    assert!(st.sim_time_s < model * 1.01, "within 1%: {} vs {model}", st.sim_time_s);
}

/// The bounded-staleness property and the round pipeline, end to end
/// over several rounds: no applied model version is ever older than
/// `round − K` (the coordinator histogram pins the exact ages), the
/// first K rounds apply zeros, every later round applies exactly the
/// round-`t − K` synchronous mean, and the async critical path tracks
/// `shard::async_time` up to header overhead.
#[test]
fn sharded_async_staleness_bound_and_pipelined_means() {
    let rounds = 6usize;
    let workers = 3usize;
    let k = 2usize;
    let n = 8192usize;
    let sp = spec("fp", 256);
    let cfg = sharded_cfg(2, k);
    let (mut coll, ends) = build_topology(&cfg, workers, &sp).unwrap();
    let gset = |w: usize, r: usize| -> Vec<f32> {
        let mut rng = Rng::stream(700 + w as u64, r as u64);
        sample(ALL_DISTS[0], n, 1.0, &mut rng)
    };
    let mut means = Vec::new();
    std::thread::scope(|scope| {
        for (w, mut wx) in ends.into_iter().enumerate() {
            let sp = sp.clone();
            let gset = &gset;
            scope.spawn(move || {
                let mut gc = orq::comm::GradCodec::new(&sp).unwrap();
                let mut rng = Rng::stream(sp.seed, 2_000 + w as u64);
                let mut qg = orq::quant::bucket::QuantizedGrad::default();
                let mut msg = Vec::new();
                let mut mean = Vec::new();
                for r in 0..rounds {
                    let g = gset(w, r);
                    gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
                    // exchange() verifies the frame's round field: any
                    // version older than r − K errors instead of applying
                    wx.exchange(&mut msg, &mut mean).unwrap();
                    assert_eq!(mean.len(), n, "worker {w} round {r}");
                    if r < k {
                        assert!(
                            mean.iter().all(|&v| v == 0.0),
                            "worker {w}: cold rounds apply the zero mean"
                        );
                    }
                }
            });
        }
        for _ in 0..rounds {
            let mut m = Vec::new();
            coll.round(&mut m).unwrap();
            means.push(m);
        }
    });
    let st = coll.stats();
    assert_eq!(st.staleness.max_age as usize, k, "staleness bound");
    assert_eq!(st.staleness.cold_rounds as usize, k);
    assert_eq!(st.staleness.rounds as usize, rounds);
    assert_eq!(st.staleness.hist[k] as usize, rounds - k);
    for (t, mean) in means.iter().enumerate() {
        if t < k {
            assert!(mean.iter().all(|&v| v == 0.0), "round {t}");
        } else {
            // the pipelined round applies the round-(t − K) synchronous
            // mean, bit for bit
            let gs: Vec<Vec<f32>> = (0..workers).map(|w| gset(w, t - k)).collect();
            let (want, _) = run_once(&sharded_cfg(2, 0), &sp, &gs).unwrap();
            assert_eq!(mean, &want, "round {t}");
        }
    }
    // Async critical path: bandwidth paid in full, latency per window
    // (zero on this link); headers make measured a hair above the model.
    let model = shard::async_time(&Link::ten_gbps(), workers, 2, rounds, k, n * 4, n * 4);
    assert!(st.sim_time_s > model, "{} vs {model}", st.sim_time_s);
    assert!(st.sim_time_s < model * 1.01, "within 1%: {} vs {model}", st.sim_time_s);
}

/// On a slow-inter/fast-intra cluster the hierarchy must put strictly
/// fewer bytes on the slow edges than either flat topology, beat the
/// ring outright on simulated round time, and stay within noise of the
/// idealized-multicast PS star (whose max-of-L-uplinks time model is a
/// lower bound no aggregation tree can undercut — the hierarchy matches
/// it while shipping L−G fewer gradients across the slow boundary).
#[test]
fn hier_localizes_traffic_onto_fast_links() {
    let links = LinkMap::new(Link::new(100e9, 0.0), Link::new(1e9, 0.010));
    let workers = 8usize;
    let d = 512;
    let n = workers * d * 8;
    let gs = grads(n, workers, 5);
    let sp = spec("terngrad", d);
    let ps = ExchangeConfig { links, ..ExchangeConfig::flat(Topology::Ps, Link::ten_gbps()) };
    let ring = ExchangeConfig { links, ..ExchangeConfig::flat(Topology::Ring, Link::ten_gbps()) };
    let (_, ps_st) = run_once(&ps, &sp, &gs).unwrap();
    let (_, ring_st) = run_once(&ring, &sp, &gs).unwrap();
    let (_, h_st) = run_once(&ExchangeConfig::hier(2, links), &sp, &gs).unwrap();
    assert!(
        h_st.wire_bytes_inter < ps_st.wire_bytes_inter
            && h_st.wire_bytes_inter < ring_st.wire_bytes_inter,
        "hier inter bytes {} should undercut ps {} and ring {}",
        h_st.wire_bytes_inter,
        ps_st.wire_bytes_inter,
        ring_st.wire_bytes_inter
    );
    assert!(h_st.wire_bytes_intra > 0, "in-group traffic must ride the fast edges");
    assert!(
        h_st.sim_time_s < ring_st.sim_time_s,
        "hier {} should beat the latency-bound ring {} on a slow-inter cluster",
        h_st.sim_time_s,
        ring_st.sim_time_s
    );
    assert!(
        h_st.sim_time_s < ps_st.sim_time_s * 1.05,
        "hier {} should stay within noise of the idealized ps star {}",
        h_st.sim_time_s,
        ps_st.sim_time_s
    );
}

/// PR 5 pool invariance: a multi-round drive must decode bit-identical
/// means whether the codec shards run on the persistent pool (its own,
/// or one shared across codecs and shard servers) or on the legacy
/// per-round scoped threads, for every codec thread count — the pool is
/// pure execution, never semantics. Covers the flat PS, the sharded PS,
/// and the async sharded PS (warm staleness rounds included).
#[test]
fn pooled_multi_round_means_bit_identical_across_modes_and_threads() {
    let rounds = 3usize;
    let gs = grads(2048, 3, 2); // d = 256 → 8 buckets
    let cfgs = [flat(Topology::Ps), sharded_cfg(2, 0), sharded_cfg(2, 1)];
    for (ci, cfg) in cfgs.iter().enumerate() {
        for method in ["orq-5", "terngrad"] {
            // reference: scoped-thread execution, 2 codec threads
            let scoped = spec(method, 256).with_threads(2).with_pool_mode(PoolMode::Scoped);
            let (want, want_st) = run_rounds(cfg, &scoped, &gs, rounds).unwrap();
            for threads in [2usize, 3] {
                // pooled default (run-local pool)
                let pooled = spec(method, 256).with_threads(threads);
                let (got, got_st) = run_rounds(cfg, &pooled, &gs, rounds).unwrap();
                assert_eq!(got, want, "{method} cfg#{ci} pooled threads={threads}");
                assert_eq!(got_st.wire_bytes, want_st.wire_bytes, "{method} cfg#{ci}");
                // explicitly shared pool, reused across two full drives:
                // cross-call arena/thread reuse must be invisible too
                let handle = PoolHandle::new(threads);
                let sh = spec(method, 256)
                    .with_threads(threads)
                    .with_pool_mode(PoolMode::Shared(handle.clone()));
                let (first, _) = run_rounds(cfg, &sh, &gs, rounds).unwrap();
                let (second, _) = run_rounds(cfg, &sh, &gs, rounds).unwrap();
                assert_eq!(first, want, "{method} cfg#{ci} shared threads={threads}");
                assert_eq!(second, want, "{method} cfg#{ci} shared drive 2");
            }
        }
    }
}

/// The serial legacy path (`threads = 1`) must stay bit-identical under
/// the pooled driver: pooling moves the run_rounds worker loops and the
/// sharded reduce loops onto pool threads, but the wire bytes and means
/// are the PR 4 scoped-driver ones, S = 1, K = 0 ≡ flat PS included.
#[test]
fn pooled_driver_keeps_serial_path_bit_identical() {
    let rounds = 3usize;
    let gs = grads(1536, 2, 4);
    for method in ["orq-5", "bingrad-b", "fp"] {
        let scoped = spec(method, 256).with_pool_mode(PoolMode::Scoped);
        let pooled = spec(method, 256); // threads = 1, PoolMode::Pooled
        let (want, want_st) = run_rounds(&flat(Topology::Ps), &scoped, &gs, rounds).unwrap();
        let (got, got_st) = run_rounds(&flat(Topology::Ps), &pooled, &gs, rounds).unwrap();
        assert_eq!(got, want, "{method} serial pooled vs scoped");
        assert_eq!(got_st.wire_bytes, want_st.wire_bytes);
        let (sh, _) = run_rounds(&sharded_cfg(1, 0), &pooled, &gs, rounds).unwrap();
        assert_eq!(sh, want, "{method} sharded S=1 K=0 pooled ≡ flat PS");
    }
}

/// Quantized downlinks keep the replica-sync invariant: the aggregation
/// point (PS server, hier root, each sharded-ps shard) encodes the mean
/// ONCE and decodes its own bytes, so every node — coordinator included
/// — still applies the bit-identical mean, with and without the
/// server-side downlink residual.
#[test]
fn quantized_downlink_mean_bit_identical_on_every_node() {
    for method in ["terngrad", "orq-5"] {
        for ef in [false, true] {
            let dl = |cfg: ExchangeConfig| cfg.with_downlink(true).with_error_feedback(ef);
            assert_mean_bit_identical(&dl(flat(Topology::Ps)), 4, method);
            assert_mean_bit_identical(&dl(hier_cfg(2)), 4, method);
            assert_mean_bit_identical(&dl(hier_cfg(3)), 6, method);
            assert_mean_bit_identical(&dl(sharded_cfg(2, 0)), 4, method);
        }
    }
}

/// Per-hop error feedback keeps the invariant on the decentralized
/// paths too: residuals change the transmitted signal round over round,
/// never the every-node-sees-the-same-bytes property.
#[test]
fn error_feedback_mean_bit_identical_on_every_node() {
    for method in ["bingrad-b", "orq-5"] {
        assert_mean_bit_identical(&flat(Topology::Ring).with_error_feedback(true), 4, method);
        assert_mean_bit_identical(&hier_cfg(2).with_error_feedback(true), 4, method);
        assert_mean_bit_identical(&hier_cfg(1).with_error_feedback(true), 4, method);
    }
}

/// Downlink byte accounting under `quantize_downlink`, exact to the
/// byte: the broadcast component shrinks to the quantized wire size
/// while the uplink component is untouched — on the PS star, per edge
/// class on the hierarchy, and per versioned frame on the sharded PS.
#[test]
fn quantized_downlink_bytes_match_codec_accounting_exactly() {
    let workers = 4usize;
    let d = 128usize;
    for (method, s) in [("terngrad", 3usize), ("orq-5", 5)] {
        // PS star: L quantized uplinks, one quantized broadcast.
        let n = workers * d * 3;
        let gs = grads(n, workers, 2);
        let sp = spec(method, d);
        let (_, q) = run_once(&flat(Topology::Ps).with_downlink(true), &sp, &gs).unwrap();
        let (_, fp) = run_once(&flat(Topology::Ps), &sp, &gs).unwrap();
        let up = wire_size(n, d, s, Packing::BaseS, method) as u64;
        let fp_down = wire_size(n, n.max(1), 0, Packing::BaseS, "fp") as u64;
        assert_eq!(q.wire_bytes_up, workers as u64 * up, "{method} ps up");
        assert_eq!(q.wire_bytes_down, up, "{method} ps down is one quantized mean");
        assert_eq!(fp.wire_bytes_up, q.wire_bytes_up, "{method} ps uplink untouched");
        assert_eq!(fp.wire_bytes_down, fp_down, "{method} ps fp down");
        assert!(q.wire_bytes_down < fp.wire_bytes_down, "{method} ps downlink must shrink");

        // Hierarchy: the root's single encoded mean rides every
        // multicast edge verbatim (G leader multicasts intra, 1 root
        // multicast inter), replacing the FP message wholesale.
        let groups = 2usize;
        let m = workers / groups;
        let n = m * d * 3;
        let gs = grads(n, workers, 2);
        let (_, hq) = run_once(&hier_cfg(groups).with_downlink(true), &sp, &gs).unwrap();
        let chunk_msg = wire_size(n / m, d, s, Packing::BaseS, method) as u64;
        let grad_msg = wire_size(n, d, s, Packing::BaseS, method) as u64;
        let intra = (workers * (m - 1) + (workers - groups)) as u64 * chunk_msg
            + groups as u64 * grad_msg;
        let inter = (groups as u64 - 1) * grad_msg + grad_msg;
        assert_eq!(hq.wire_bytes_intra, intra, "{method} hier intra");
        assert_eq!(hq.wire_bytes_inter, inter, "{method} hier inter");
        assert_eq!(
            hq.wire_bytes_down,
            (groups as u64 + 1) * grad_msg,
            "{method} hier down = G leader multicasts + root multicast"
        );
        let (_, hfp) = run_once(&hier_cfg(groups), &sp, &gs).unwrap();
        assert_eq!(hfp.wire_bytes_up, hq.wire_bytes_up, "{method} hier uplink untouched");
        assert!(hq.wire_bytes_down < hfp.wire_bytes_down, "{method} hier downlink shrinks");
        assert!(
            hq.wire_bytes_inter < hfp.wire_bytes_inter,
            "{method} hier slow-edge bytes shrink"
        );

        // Sharded PS: each shard's mean frame wraps a quantized chunk.
        let shards = 2usize;
        let n = shards * d * 3;
        let gs = grads(n, workers, 2);
        let (_, sq) = run_once(&sharded_cfg(shards, 0).with_downlink(true), &sp, &gs).unwrap();
        let chunk = n / shards;
        let up_frame =
            (shard::FRAME_HEADER_BYTES + wire_size(chunk, d, s, Packing::BaseS, method)) as u64;
        let down_frame = up_frame; // same codec, same chunk grid
        assert_eq!(sq.wire_bytes_up, (workers * shards) as u64 * up_frame, "{method} sharded up");
        assert_eq!(sq.wire_bytes_down, shards as u64 * down_frame, "{method} sharded down");
        let (_, sfp) = run_once(&sharded_cfg(shards, 0), &sp, &gs).unwrap();
        assert_eq!(sfp.wire_bytes_up, sq.wire_bytes_up, "{method} sharded uplink untouched");
        assert!(sq.wire_bytes_down < sfp.wire_bytes_down, "{method} sharded downlink shrinks");
    }
}

/// Extended closed-form models with quantized downlinks: feed
/// `hier_time`/`sharded_time` the actual quantized wire sizes and the
/// measured simulated round must sit within 1% above them (per-message
/// headers are the only gap the models ignore).
#[test]
fn quantized_downlink_sim_time_matches_models() {
    // Hierarchy on a heterogeneous map.
    let links = LinkMap::new(Link::new(100e9, 1e-6), Link::new(1e9, 0.005));
    let workers = 4usize;
    let groups = 2usize;
    let m = workers / groups;
    let d = 512usize;
    let n = m * d * 16;
    let gs = grads(n, workers, 4);
    let sp = spec("terngrad", d);
    let cfg = ExchangeConfig::hier(groups, links).with_downlink(true);
    let (_, st) = run_once(&cfg, &sp, &gs).unwrap();
    let quant = wire_size(n, d, 3, Packing::BaseS, "terngrad");
    let model = hier::hier_time(&links, workers, groups, quant, quant);
    assert!(st.sim_time_s > model, "headers make measured > model");
    assert!(st.sim_time_s < model * 1.01, "within 1%: {} vs {model}", st.sim_time_s);

    // Sharded PS on the homogeneous testbed link. Chunks are large
    // enough that the two 22-byte frame headers the model ignores stay
    // far inside the 1% envelope at ~2 bits/element.
    let link = Link::ten_gbps();
    let workers = 3usize;
    let shards = 4usize;
    let n = shards * d * 64;
    let gs = grads(n, workers, 3);
    let (_, st) = run_once(&sharded_cfg(shards, 0).with_downlink(true), &sp, &gs).unwrap();
    let chunk = wire_size(n / shards, d, 3, Packing::BaseS, "terngrad");
    let model = shard::sharded_time(&link, workers, shards, shards * chunk, shards * chunk);
    assert!(st.sim_time_s > model, "headers make measured > model");
    assert!(st.sim_time_s < model * 1.01, "within 1%: {} vs {model}", st.sim_time_s);
}

/// The EF payoff, measured: push the SAME gradients every round and
/// compare the running average of the decoded means against the exact
/// mean. Memoryless requantization of partial sums leaves a bias floor
/// on the biased BinGrad-b; per-hop residuals (ring hop positions,
/// hierarchy edges) cancel it over rounds, so the EF average must land
/// strictly closer. Seeded and deterministic.
#[test]
fn per_hop_error_feedback_beats_memoryless_on_biased_scheme() {
    let workers = 4usize;
    let rounds = 12usize;
    let n = 4096usize;
    let gs = grads(n, workers, 0);
    let exact = exact_mean(&gs);
    let avg_err = |cfg: &ExchangeConfig| -> f64 {
        let sp = spec("bingrad-b", 256);
        let (mut coll, ends) = build_topology(cfg, workers, &sp).unwrap();
        let mut sum = vec![0.0f64; n];
        std::thread::scope(|scope| {
            for (w, mut wx) in ends.into_iter().enumerate() {
                let g: &[f32] = &gs[w];
                let sp = sp.clone();
                scope.spawn(move || {
                    let mut gc = orq::comm::GradCodec::new(&sp).unwrap();
                    let mut rng = Rng::stream(sp.seed, 2_000 + w as u64);
                    let mut qg = orq::quant::bucket::QuantizedGrad::default();
                    let mut msg = Vec::new();
                    let mut mean = Vec::new();
                    for _ in 0..rounds {
                        // memoryless uplink in BOTH runs — the toggle
                        // under test is the topology-internal residuals
                        gc.encode_into(g, &mut rng, &mut qg, &mut msg);
                        wx.exchange(&mut msg, &mut mean).unwrap();
                    }
                });
            }
            let mut m = Vec::new();
            for _ in 0..rounds {
                coll.round(&mut m).unwrap();
                for (acc, v) in sum.iter_mut().zip(&m) {
                    *acc += *v as f64;
                }
            }
            drop(coll);
        });
        let inv = 1.0 / rounds as f64;
        exact
            .iter()
            .zip(&sum)
            .map(|(e, s)| {
                let diff = *e as f64 - s * inv;
                diff * diff
            })
            .sum::<f64>()
            .sqrt()
    };
    let ring_plain = avg_err(&flat(Topology::Ring));
    let ring_ef = avg_err(&flat(Topology::Ring).with_error_feedback(true));
    assert!(
        ring_ef < ring_plain,
        "ring: EF average error {ring_ef} must beat memoryless {ring_plain}"
    );
    let hier_plain = avg_err(&hier_cfg(2));
    let hier_ef = avg_err(&hier_cfg(2).with_error_feedback(true));
    assert!(
        hier_ef < hier_plain,
        "hier: EF average error {hier_ef} must beat memoryless {hier_plain}"
    );
}

/// `threads = 0` (auto-size) resolves deterministically under sharding:
/// two identical async sharded drives decode identical means, and both
/// match an explicit-thread-count run at the resolved value.
#[test]
fn auto_thread_count_deterministic_under_shards() {
    let rounds = 4usize;
    let gs = grads(2048, 3, 6);
    let cfg = sharded_cfg(2, 1);
    let auto = spec("orq-5", 256).with_threads(0);
    let (a, _) = run_rounds(&cfg, &auto, &gs, rounds).unwrap();
    let (b, _) = run_rounds(&cfg, &auto, &gs, rounds).unwrap();
    assert_eq!(a, b, "auto-sized sharded runs must be reproducible");
    let resolved = orq::quant::pool::auto_threads().min(256);
    let explicit = spec("orq-5", 256).with_threads(resolved);
    let (c, _) = run_rounds(&cfg, &explicit, &gs, rounds).unwrap();
    assert_eq!(a, c, "auto must equal the explicitly resolved count");
}
