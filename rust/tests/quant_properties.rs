//! Property tests for the quantizer invariants (DESIGN.md §6), swept over
//! six gradient-distribution families × seeds × level counts — the
//! proptest role in this offline build.

use orq::codec::{self, Packing};
use orq::quant::bucket::BucketQuantizer;
use orq::quant::error::expected_rr_mse;
use orq::quant::linear::LinearQuantizer;
use orq::quant::orq::{condition_residual, OrqQuantizer};
use orq::quant::qsgd::QsgdQuantizer;
use orq::quant::{self, Quantizer};
use orq::tensor::rng::Rng;
use orq::tensor::stats::SliceStats;
use orq::testutil::{sample, ALL_DISTS};

const BUCKET: usize = 1024;

/// Every scheme, every distribution: structural invariants hold.
#[test]
fn prop_structural_invariants() {
    for dist in ALL_DISTS {
        for seed in 0..4u64 {
            let mut rng = Rng::stream(seed, dist as u64);
            let g = sample(dist, BUCKET, 0.01, &mut rng);
            for name in quant::paper_methods() {
                if name == "fp" {
                    continue;
                }
                let q = quant::from_name(name).unwrap();
                let qb = q.quantize_bucket(&g, &mut rng);
                assert_eq!(qb.indices.len(), g.len(), "{name} {dist:?}");
                assert_eq!(qb.levels.len(), q.num_levels(), "{name}");
                assert!(
                    qb.levels.windows(2).all(|w| w[0] <= w[1]),
                    "{name} {dist:?}: levels sorted"
                );
                assert!(
                    qb.indices.iter().all(|&i| (i as usize) < qb.levels.len()),
                    "{name} {dist:?}: index range"
                );
                assert!(
                    qb.levels.iter().all(|v| v.is_finite()),
                    "{name} {dist:?}: finite levels"
                );
            }
        }
    }
}

/// The headline theorem property: ORQ's expected random-rounding MSE is
/// ≤ QSGD's and Linear's at every level count, on EVERY distribution.
#[test]
fn prop_orq_is_optimal_among_random_rounding() {
    for dist in ALL_DISTS {
        for seed in 0..3u64 {
            let mut rng = Rng::stream(100 + seed, dist as u64);
            let g = sample(dist, 4096, 1.0, &mut rng);
            let mut sorted = g.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let m = SliceStats::compute(&g).max_abs();
            for s in [3usize, 5, 9] {
                let orq_lv = OrqQuantizer::new(s).levels_for(&g);
                let e_orq = expected_rr_mse(&sorted, &orq_lv);
                let e_qsgd = expected_rr_mse(&sorted, &QsgdQuantizer::grid(s, m));
                let e_lin =
                    expected_rr_mse(&sorted, &LinearQuantizer::quantile_levels(&sorted, s));
                assert!(
                    e_orq <= e_qsgd * 1.001,
                    "{dist:?} s={s}: orq {e_orq} > qsgd {e_qsgd}"
                );
                assert!(
                    e_orq <= e_lin * 1.001,
                    "{dist:?} s={s}: orq {e_orq} > linear {e_lin}"
                );
            }
        }
    }
}

/// More levels never hurt ORQ (monotone improvement in s).
#[test]
fn prop_orq_monotone_in_levels() {
    for dist in ALL_DISTS {
        let mut rng = Rng::stream(200, dist as u64);
        let g = sample(dist, 4096, 1.0, &mut rng);
        let mut sorted = g.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let e3 = expected_rr_mse(&sorted, &OrqQuantizer::new(3).levels_for(&g));
        let e5 = expected_rr_mse(&sorted, &OrqQuantizer::new(5).levels_for(&g));
        let e9 = expected_rr_mse(&sorted, &OrqQuantizer::new(9).levels_for(&g));
        assert!(e5 <= e3 * 1.01, "{dist:?}: e5={e5} e3={e3}");
        assert!(e9 <= e5 * 1.01, "{dist:?}: e9={e9} e5={e5}");
    }
}

/// Unbiasedness (Assumption 1): for every random-rounding scheme, the
/// exact per-element expectation over the rounding randomness equals v
/// for v inside the level span (BinGrad-pb clamps outside by design).
#[test]
fn prop_unbiased_expectation_exact() {
    for dist in ALL_DISTS {
        let mut rng = Rng::stream(300, dist as u64);
        let g = sample(dist, 512, 0.1, &mut rng);
        for name in ["terngrad", "qsgd-5", "linear-9", "orq-3", "orq-9"] {
            let q = quant::from_name(name).unwrap();
            assert!(q.is_unbiased(), "{name} claims unbiased");
            let qb = q.quantize_bucket(&g, &mut rng);
            let lv = &qb.levels;
            let (lo, hi) = (lv[0], *lv.last().unwrap());
            for &v in &g {
                if v < lo || v > hi {
                    continue;
                }
                // bracket + exact expectation
                let k = lv.partition_point(|&b| b <= v).saturating_sub(1).min(lv.len() - 2);
                let (a, b) = (lv[k], lv[k + 1]);
                let e = if b > a {
                    let p = ((v - a) / (b - a)).clamp(0.0, 1.0);
                    a as f64 * (1.0 - p as f64) + b as f64 * p as f64
                } else {
                    a as f64
                };
                assert!(
                    (e - v as f64).abs() < 1e-5 * (1.0 + v.abs() as f64),
                    "{name} {dist:?}: E[Q({v})]={e}"
                );
            }
        }
    }
}

/// Empirical unbiasedness of the actual sampler (Monte Carlo).
#[test]
fn prop_sampler_unbiased_monte_carlo() {
    let mut rng = Rng::seed_from(400);
    let g = sample(orq::testutil::GradDist::Gaussian, 64, 1.0, &mut rng);
    for name in ["terngrad", "orq-5", "qsgd-9"] {
        let q = quant::from_name(name).unwrap();
        let n = 3000;
        let mut acc = vec![0.0f64; g.len()];
        for t in 0..n {
            let qb = q.quantize_bucket(&g, &mut Rng::seed_from(500 + t));
            for (a, d) in acc.iter_mut().zip(qb.dequantize()) {
                *a += d as f64;
            }
        }
        let lv = q.quantize_bucket(&g, &mut Rng::seed_from(0)).levels;
        let (lo, hi) = (lv[0] as f64, *lv.last().unwrap() as f64);
        let max_w = lv.windows(2).map(|w| (w[1] - w[0]) as f64).fold(0.0, f64::max);
        for (a, &v) in acc.iter().zip(&g) {
            let vd = v as f64;
            if vd <= lo || vd >= hi {
                continue;
            }
            let mean = a / n as f64;
            let tol = 4.0 * max_w / (n as f64).sqrt() + 1e-4;
            assert!((mean - vd).abs() < tol, "{name}: E[Q({v})]≈{mean}");
        }
    }
}

/// Greedy-then-refined ORQ satisfies the Eq. (12) stationarity condition.
///
/// Sparse is excluded: a 95% point mass at zero makes the empirical count
/// |{b ≤ v ≤ r}| discontinuous in b, so the residual cannot reach zero at
/// any b adjacent to the atom (the condition needs subgradient treatment
/// there; MSE optimality itself still holds — see
/// `prop_orq_is_optimal_among_random_rounding`, which includes Sparse).
#[test]
fn prop_refined_orq_satisfies_condition() {
    for dist in ALL_DISTS.into_iter().filter(|d| *d != orq::testutil::GradDist::Sparse) {
        let mut rng = Rng::stream(600, dist as u64);
        let g = sample(dist, 4096, 1.0, &mut rng);
        let mut sorted = g;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lv = OrqQuantizer::with_refinement(9, 40).levels_for(&sorted);
        for (k, r) in condition_residual(&sorted, &lv).iter().enumerate() {
            assert!(*r < 0.02, "{dist:?} level {k}: residual {r}");
        }
    }
}

/// Codec roundtrip is lossless for every scheme × distribution × ragged
/// length × packing.
#[test]
fn prop_codec_roundtrip_lossless() {
    for dist in ALL_DISTS {
        let mut rng = Rng::stream(700, dist as u64);
        for &n in &[1usize, 511, 512, 513, 5000] {
            let g = sample(dist, n, 0.01, &mut rng);
            for name in ["terngrad", "orq-5", "qsgd-9", "bingrad-b", "signsgd"] {
                let q = quant::from_name(name).unwrap();
                let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut rng);
                for packing in [Packing::Fixed, Packing::BaseS] {
                    let bytes = codec::encode(&qg, name, packing);
                    let dec = codec::decode(&bytes).unwrap();
                    assert_eq!(
                        dec.to_flat(),
                        qg.dequantize(),
                        "{name} {dist:?} n={n} {packing:?}"
                    );
                }
            }
        }
    }
}

/// Clipping never increases the bucket's max-abs and bounds the range.
#[test]
fn prop_clipping_contracts_range() {
    for dist in ALL_DISTS {
        let mut rng = Rng::stream(800, dist as u64);
        let g = sample(dist, 2048, 1.0, &mut rng);
        let mut clipped = g.clone();
        let thr = orq::quant::clip::clip_sigma_inplace(&mut clipped, 2.5);
        let before = SliceStats::compute(&g).max_abs();
        let after = SliceStats::compute(&clipped).max_abs();
        assert!(after <= before + 1e-6, "{dist:?}");
        if thr > 0.0 {
            assert!(after <= thr + 1e-6, "{dist:?}: {after} > {thr}");
        }
    }
}

/// BinGrad-b has the lowest realized MSE of all 1-bit schemes (its
/// optimality claim), on every distribution family.
#[test]
fn prop_bingrad_b_best_one_bit() {
    for dist in ALL_DISTS {
        let mut rng = Rng::stream(900, dist as u64);
        let g = sample(dist, 8192, 1.0, &mut rng);
        let mse_of = |name: &str| {
            let q = quant::from_name(name).unwrap();
            let qb = q.quantize_bucket(&g, &mut Rng::seed_from(1));
            orq::tensor::mse(&g, &qb.dequantize())
        };
        let b = mse_of("bingrad-b");
        let pb = mse_of("bingrad-pb");
        let sign = mse_of("signsgd");
        assert!(b <= pb * 1.02, "{dist:?}: b={b} pb={pb}");
        assert!(b <= sign * 1.02, "{dist:?}: b={b} signsgd={sign}");
    }
}
