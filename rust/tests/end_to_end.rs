//! End-to-end coordinator tests: the paper's qualitative orderings must
//! emerge from full training runs on the synthetic substrate.

use orq::comm::Topology;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};

fn ds() -> ClassDataset {
    ClassDataset::generate(DatasetSpec {
        in_dim: 32,
        classes: 16,
        train_n: 2048,
        test_n: 1024,
        margin: 3.0,
        noise: 1.0,
        label_noise: 0.02,
        seed: 77,
    })
}

fn cfg(method: &str) -> TrainConfig {
    TrainConfig {
        model: "mlp:32-64-64-16".into(),
        dataset: "test".into(),
        method: method.into(),
        workers: 1,
        batch: 64,
        steps: 250,
        lr: 0.08,
        momentum: 0.9,
        weight_decay: 1e-4,
        lr_decay_steps: vec![150, 210],
        lr_decay: 0.1,
        warmup_steps: 0,
        bucket_size: 512,
        clip_factor: None,
        seed: 5,
        eval_every: 0,
        quantize_downlink: false,
        topology: Topology::Ps,
        groups: 1,
        shards: 1,
        staleness: 0,
        error_feedback: false,
        threads: 1,
        pool: true,
        overlap: false,
        sections: None,
        stream_sections: false,
        byte_budget: None,
        budget_schedule: None,
        trace_level: orq::obs::TraceLevel::Off,
        links: orq::config::LinkConfig::default(),
    }
}

fn run(method: &str) -> (f64, f64) {
    let data = ds();
    let c = cfg(method);
    let factory = native_backend_factory(&c.model).unwrap();
    let out = Trainer::new(c, &data).unwrap().run(factory).unwrap();
    (out.summary.test_top1, out.summary.mean_quant_rel_mse)
}

/// Table 2's qualitative ordering at s=9: FP ≥ ORQ-9 ≥ Linear-9 on
/// accuracy, with ORQ-9 close to FP.
#[test]
fn ordering_fp_orq_linear() {
    let (acc_fp, _) = run("fp");
    let (acc_orq, mse_orq) = run("orq-9");
    let (acc_lin, mse_lin) = run("linear-9");
    assert!(acc_fp > 0.75, "fp acc {acc_fp}");
    // ORQ within a few points of FP
    assert!(acc_orq > acc_fp - 0.08, "orq {acc_orq} vs fp {acc_fp}");
    // ORQ's quantization error strictly below Linear's (Fig 2 ordering)
    assert!(mse_orq < mse_lin, "mse orq {mse_orq} vs linear {mse_lin}");
    // and Linear shouldn't beat ORQ on accuracy by any real margin
    assert!(acc_orq > acc_lin - 0.02, "orq {acc_orq} vs linear {acc_lin}");
}

/// Fig 2's quantization-error ordering at equal s: ORQ < QSGD.
#[test]
fn quant_error_ordering_orq_vs_qsgd() {
    let (_, mse_orq3) = run("orq-3");
    let (_, mse_tern) = run("terngrad");
    assert!(
        mse_orq3 < mse_tern,
        "orq-3 rel-mse {mse_orq3} should beat terngrad {mse_tern}"
    );
    let (_, mse_orq9) = run("orq-9");
    let (_, mse_qsgd9) = run("qsgd-9");
    assert!(
        mse_orq9 < mse_qsgd9,
        "orq-9 rel-mse {mse_orq9} should beat qsgd-9 {mse_qsgd9}"
    );
}

/// More levels → higher accuracy for ORQ (Table 5's compression trend).
#[test]
fn more_levels_more_accuracy() {
    let (a3, m3) = run("orq-3");
    let (a9, m9) = run("orq-9");
    assert!(m9 < m3, "rel-mse must shrink with levels: {m9} vs {m3}");
    assert!(a9 > a3 - 0.03, "acc should not degrade with more levels: {a9} vs {a3}");
}

/// Distributed run (4 workers) preserves learning and the variance
/// averaging effect: gradient averaging across workers must not hurt.
#[test]
fn four_workers_learn() {
    let data = ds();
    let mut c = cfg("terngrad");
    c.workers = 4;
    c.batch = 64; // 16 per worker
    let factory = native_backend_factory(&c.model).unwrap();
    let out = Trainer::new(c, &data).unwrap().run(factory).unwrap();
    assert!(out.summary.test_top1 > 0.5, "4-worker top1 {}", out.summary.test_top1);
    // all four uplinks accounted每step
    let per_step = &out.series.steps[0];
    assert!(per_step.wire_bytes > 0);
}

/// Clipping helps the 3-level scheme (Table 4 direction): with clip 2.5σ
/// the realized quantization error drops vs no clip.
#[test]
fn clipping_reduces_quant_error() {
    let data = ds();
    let mut c_noclip = cfg("terngrad");
    c_noclip.steps = 120;
    let mut c_clip = c_noclip.clone();
    c_clip.clip_factor = Some(2.5);
    c_clip.warmup_steps = 10;
    let f1 = native_backend_factory(&c_noclip.model).unwrap();
    let f2 = native_backend_factory(&c_clip.model).unwrap();
    let no = Trainer::new(c_noclip, &data).unwrap().run(f1).unwrap();
    let yes = Trainer::new(c_clip, &data).unwrap().run(f2).unwrap();
    assert!(
        yes.summary.mean_quant_rel_mse < no.summary.mean_quant_rel_mse,
        "clip {} vs noclip {}",
        yes.summary.mean_quant_rel_mse,
        no.summary.mean_quant_rel_mse
    );
}
