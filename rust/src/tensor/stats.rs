//! Single-pass statistics over flat slices — the Rust mirror of the
//! Pallas `bucket_stats` kernel (one sweep produces all moments).

/// Moments of one bucket/slice, computed in a single pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SliceStats {
    pub n: usize,
    pub min: f32,
    pub max: f32,
    pub sum: f64,
    pub sumsq: f64,
    pub l1: f64,
}

impl SliceStats {
    /// One pass over the data: min/max/Σ/Σ²/Σ|·| — mirrors
    /// `python/compile/kernels/quant_stats.py`.
    pub fn compute(xs: &[f32]) -> SliceStats {
        let mut s = SliceStats {
            n: xs.len(),
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            sum: 0.0,
            sumsq: 0.0,
            l1: 0.0,
        };
        for &v in xs {
            s.min = s.min.min(v);
            s.max = s.max.max(v);
            let vd = v as f64;
            s.sum += vd;
            s.sumsq += vd * vd;
            s.l1 += vd.abs();
        }
        if xs.is_empty() {
            s.min = 0.0;
            s.max = 0.0;
        }
        s
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sumsq / self.n as f64 - m * m).max(0.0)
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Largest absolute value.
    pub fn max_abs(&self) -> f32 {
        self.min.abs().max(self.max.abs())
    }
}

/// Running mean/var accumulator (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1); 0 for fewer than 2 samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile of a *sorted* slice with linear interpolation, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_stats_basic() {
        let s = SliceStats::compute(&[-1.0, 2.0, -3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, -3.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.sum, 2.0);
        assert_eq!(s.l1, 10.0);
        assert_eq!(s.max_abs(), 4.0);
        assert!((s.mean() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn slice_stats_var_matches_two_pass() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 31 % 97) as f32) / 10.0).collect();
        let s = SliceStats::compute(&xs);
        let m = xs.iter().map(|v| *v as f64).sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|v| (*v as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((s.var() - var).abs() < 1e-9, "{} vs {}", s.var(), var);
    }

    #[test]
    fn slice_stats_empty() {
        let s = SliceStats::compute(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.min, 0.0);
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 50.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 100.0);
        assert!((percentile_sorted(&xs, 0.995) - 99.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&[], 0.5), 0.0);
        assert_eq!(percentile_sorted(&[7.0], 0.99), 7.0);
    }
}
