//! Flat-tensor substrate: PRNG, running statistics, vector helpers.
//!
//! Everything in the hot path operates on flat `&[f32]` slices — the
//! paper's quantizers are defined on the flattened gradient, so there is
//! deliberately no ndarray machinery here.

pub mod rng;
pub mod stats;

/// `y += alpha * x` (axpy).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `y *= alpha`.
pub fn scale(alpha: f32, y: &mut [f32]) {
    for yi in y.iter_mut() {
        *yi *= alpha;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

/// L1 norm.
pub fn norm1(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs() as f64).sum::<f64>() as f32
}

/// Dot product (f64 accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>() as f32
}

/// Mean squared error between two vectors (f64 accumulation).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x as f64) - (*y as f64);
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Cosine similarity; 0 when either vector is ~zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm2(a) as f64;
    let nb = norm2(b) as f64;
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    dot(a, b) as f64 / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_scale_dot() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [1.5, 2.5, 3.5]);
        assert!((dot(&x, &x) - 14.0).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((norm1(&[-3.0, 4.0]) - 7.0).abs() < 1e-6);
        assert_eq!(norm2(&[]), 0.0);
    }

    #[test]
    fn mse_cosine() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        assert!((mse(&a, &b) - 1.0).abs() < 1e-9);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-9);
        assert_eq!(cosine(&[0.0, 0.0], &a), 0.0);
    }
}
