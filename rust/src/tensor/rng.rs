//! Deterministic PRNG: xoshiro256** seeded through SplitMix64.
//!
//! No `rand` crate offline, so this is the project's randomness substrate.
//! Requirements: fast uniform f32 for the random-rounding hot path,
//! Gaussian sampling for data/init, and cheap independent streams per
//! worker (`Rng::stream`).

/// xoshiro256** state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare: Option<f64>,
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 works (SplitMix64 whitens it).
    pub fn seed_from(seed: u64) -> Self {
        let mut x = seed;
        Rng {
            s: [splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x)],
            spare: None,
        }
    }

    /// An independent stream derived from this seed and a stream id
    /// (used to give every worker its own decorrelated stream).
    pub fn stream(seed: u64, stream: u64) -> Self {
        Rng::seed_from(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1) with 24 bits of mantissa randomness.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1) with 53 bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias < 2^-64, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with N(0, sigma) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32() * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_decorrelated() {
        let mut a = Rng::stream(7, 0);
        let mut b = Rng::stream(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::seed_from(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
        let skew = xs.iter().map(|x| x.powi(3)).sum::<f64>() / n as f64;
        assert!(skew.abs() < 0.05, "skew={skew}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed_from(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
