//! Generative-testing helpers: the gradient-distribution families the
//! property tests sweep (proptest is unavailable offline; these generators
//! + seed loops play its role for the quantizer invariants).

use crate::tensor::rng::Rng;

/// Distribution families seen in real gradients (and adversarial ones).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradDist {
    Gaussian,
    /// Laplace via difference of exponentials — heavier tails.
    Laplace,
    /// N(±1, 0.1) mixture — bimodal.
    Bimodal,
    /// 95% exact zeros + Gaussian spikes — post-ReLU sparsity.
    Sparse,
    Uniform,
    /// Student-t-ish heavy tail (ratio of gaussian to sqrt uniform).
    HeavyTail,
}

pub const ALL_DISTS: [GradDist; 6] = [
    GradDist::Gaussian,
    GradDist::Laplace,
    GradDist::Bimodal,
    GradDist::Sparse,
    GradDist::Uniform,
    GradDist::HeavyTail,
];

/// Sample a bucket of `n` values from the family, scaled by `scale`.
pub fn sample(dist: GradDist, n: usize, scale: f32, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let v = match dist {
                GradDist::Gaussian => rng.gaussian_f32(),
                GradDist::Laplace => {
                    let e1 = -rng.f64().max(1e-12).ln();
                    let e2 = -rng.f64().max(1e-12).ln();
                    (e1 - e2) as f32
                }
                GradDist::Bimodal => {
                    let center = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
                    center + rng.gaussian_f32() * 0.1
                }
                GradDist::Sparse => {
                    if rng.f32() < 0.95 {
                        0.0
                    } else {
                        rng.gaussian_f32() * 3.0
                    }
                }
                GradDist::Uniform => rng.f32() * 2.0 - 1.0,
                GradDist::HeavyTail => {
                    let g = rng.gaussian_f32();
                    let u = rng.f32().max(1e-3);
                    g / u.sqrt()
                }
            };
            v * scale
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats::SliceStats;

    #[test]
    fn all_families_produce_finite_values() {
        let mut rng = Rng::seed_from(1);
        for d in ALL_DISTS {
            let xs = sample(d, 4096, 1.0, &mut rng);
            assert_eq!(xs.len(), 4096);
            assert!(xs.iter().all(|v| v.is_finite()), "{d:?}");
        }
    }

    #[test]
    fn sparse_is_mostly_zero() {
        let mut rng = Rng::seed_from(2);
        let xs = sample(GradDist::Sparse, 10_000, 1.0, &mut rng);
        let zeros = xs.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 9_000, "zeros={zeros}");
    }

    #[test]
    fn heavy_tail_has_outliers() {
        let mut rng = Rng::seed_from(3);
        let xs = sample(GradDist::HeavyTail, 10_000, 1.0, &mut rng);
        let s = SliceStats::compute(&xs);
        assert!(s.max_abs() > 6.0 * s.std() as f32, "tail should dominate σ");
    }

    #[test]
    fn scale_applies() {
        let mut rng = Rng::seed_from(4);
        let xs = sample(GradDist::Gaussian, 10_000, 10.0, &mut rng);
        let s = SliceStats::compute(&xs);
        assert!((s.std() - 10.0).abs() < 0.5, "std={}", s.std());
    }
}
