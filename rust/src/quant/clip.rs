//! TernGrad-style gradient clipping: `clip(v) = sign(v)·min(|v|, c·σ)`
//! applied *before* quantization to shrink the quantization range by
//! removing outliers (paper §5, empirically c = 2.5; Table 4 sweeps
//! c ∈ {1.7, 2.5}).

use crate::tensor::stats::SliceStats;

/// Clip a slice in place to ±c·σ, where σ is the slice's own std.
/// Returns the clip threshold actually used.
pub fn clip_sigma_inplace(g: &mut [f32], c: f32) -> f32 {
    let sigma = SliceStats::compute(g).std() as f32;
    let thr = c * sigma;
    if thr <= 0.0 {
        return 0.0;
    }
    for v in g.iter_mut() {
        if *v > thr {
            *v = thr;
        } else if *v < -thr {
            *v = -thr;
        }
    }
    thr
}

/// Fraction of elements that a threshold of ±c·σ would clip (diagnostic).
pub fn clipped_fraction(g: &[f32], c: f32) -> f64 {
    let sigma = SliceStats::compute(g).std() as f32;
    let thr = c * sigma;
    if thr <= 0.0 || g.is_empty() {
        return 0.0;
    }
    g.iter().filter(|v| v.abs() > thr).count() as f64 / g.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn clips_to_threshold() {
        let mut g = vec![0.1f32, -0.1, 5.0, -5.0, 0.0];
        let thr = clip_sigma_inplace(&mut g, 1.0);
        assert!(thr > 0.0);
        for v in &g {
            assert!(v.abs() <= thr + 1e-6);
        }
        // small values untouched
        assert_eq!(g[0], 0.1);
        assert_eq!(g[1], -0.1);
    }

    #[test]
    fn gaussian_clip_fraction_matches_theory() {
        // P(|N(0,1)| > 2.5) ≈ 0.0124.
        let mut rng = Rng::seed_from(1);
        let g: Vec<f32> = (0..200_000).map(|_| rng.gaussian_f32()).collect();
        let frac = clipped_fraction(&g, 2.5);
        assert!((frac - 0.0124).abs() < 0.002, "frac={frac}");
        // and c=1.7: P ≈ 0.0891
        let frac17 = clipped_fraction(&g, 1.7);
        assert!((frac17 - 0.0891).abs() < 0.005, "frac={frac17}");
    }

    #[test]
    fn zero_slice_noop() {
        let mut g = vec![0.0f32; 8];
        assert_eq!(clip_sigma_inplace(&mut g, 2.5), 0.0);
        assert!(g.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn clipping_shrinks_range_not_center() {
        let mut rng = Rng::seed_from(2);
        let mut g: Vec<f32> = (0..10_000).map(|_| rng.gaussian_f32()).collect();
        g[0] = 50.0; // gross outlier
        let before_max = g.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        clip_sigma_inplace(&mut g, 2.5);
        let after_max = g.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(after_max < before_max / 4.0, "outlier must be removed");
    }
}
