//! Bucketing: the paper's experiments split the flat gradient into
//! fixed-size buckets of length d (default 2048 on CIFAR, 512 on
//! ImageNet) and quantize each independently (§5). The final bucket may
//! be shorter.

use super::{QuantizedBucket, Quantizer};
use crate::quant::clip::clip_sigma_inplace;
use crate::tensor::rng::Rng;

/// A whole-gradient quantization result: one [`QuantizedBucket`] per bucket.
#[derive(Debug, Clone, Default)]
pub struct QuantizedGrad {
    pub bucket_size: usize,
    pub total_len: usize,
    pub buckets: Vec<QuantizedBucket>,
}

impl QuantizedGrad {
    /// Dequantize the full gradient back to a flat vector.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total_len];
        self.dequantize_into(&mut out);
        out
    }

    pub fn dequantize_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.total_len);
        for (b, chunk) in self.buckets.iter().zip(out.chunks_mut(self.bucket_size)) {
            b.dequantize_into(chunk);
        }
    }
}

/// Configuration for whole-gradient quantization.
#[derive(Debug, Clone)]
pub struct BucketQuantizer {
    pub bucket_size: usize,
    /// `Some(c)` applies ±c·σ clipping per bucket before level selection.
    pub clip_factor: Option<f32>,
}

impl BucketQuantizer {
    pub fn new(bucket_size: usize) -> Self {
        assert!(bucket_size > 0);
        BucketQuantizer { bucket_size, clip_factor: None }
    }

    pub fn with_clip(bucket_size: usize, c: f32) -> Self {
        BucketQuantizer { bucket_size, clip_factor: Some(c) }
    }

    pub fn num_buckets(&self, total_len: usize) -> usize {
        total_len.div_ceil(self.bucket_size)
    }

    /// Quantize a full flat gradient bucket-by-bucket.
    pub fn quantize(&self, g: &[f32], q: &dyn Quantizer, rng: &mut Rng) -> QuantizedGrad {
        let mut out = QuantizedGrad::default();
        self.quantize_into(g, q, rng, &mut out);
        out
    }

    /// Quantize into a reused [`QuantizedGrad`] — the exchange hot path.
    /// Per-bucket level/index vectors are recycled across calls, so
    /// steady-state rounds perform no per-bucket allocation. (Clipping,
    /// when enabled, allocates one scratch buffer per *call* and reuses
    /// it across all buckets of that call.)
    pub fn quantize_into(
        &self,
        g: &[f32],
        q: &dyn Quantizer,
        rng: &mut Rng,
        out: &mut QuantizedGrad,
    ) {
        let n = self.num_buckets(g.len());
        out.bucket_size = self.bucket_size;
        out.total_len = g.len();
        out.buckets.truncate(n);
        while out.buckets.len() < n {
            out.buckets.push(super::QuantizedBucket::default());
        }
        let mut scratch: Vec<f32> = Vec::new();
        for (chunk, qb) in g.chunks(self.bucket_size).zip(out.buckets.iter_mut()) {
            match self.clip_factor {
                Some(c) => {
                    scratch.clear();
                    scratch.extend_from_slice(chunk);
                    clip_sigma_inplace(&mut scratch, c);
                    q.quantize_bucket_into(&scratch, rng, qb);
                }
                None => q.quantize_bucket_into(chunk, rng, qb),
            }
        }
    }

    /// Quantize bucket `bi` (`chunk` = its slice of the gradient) with an
    /// independent RNG stream derived from `(round_key, bi)`, applying
    /// the configured clipping through `clip_scratch`. The result depends
    /// only on `(chunk, round_key, bi)` — not on processing order or
    /// thread placement — the invariant the parallel pipeline
    /// ([`crate::quant::parallel`]) and its serial reference share.
    pub fn quantize_bucket_stream(
        &self,
        chunk: &[f32],
        bi: usize,
        q: &dyn Quantizer,
        round_key: u64,
        clip_scratch: &mut Vec<f32>,
        out: &mut QuantizedBucket,
    ) {
        let mut rng = Rng::stream(round_key, bi as u64);
        match self.clip_factor {
            Some(c) => {
                clip_scratch.clear();
                clip_scratch.extend_from_slice(chunk);
                clip_sigma_inplace(clip_scratch, c);
                q.quantize_bucket_into(clip_scratch, &mut rng, out);
            }
            None => q.quantize_bucket_into(chunk, &mut rng, out),
        }
    }

    /// Like [`Self::quantize_into`] but with the per-bucket RNG streams
    /// of [`Self::quantize_bucket_stream`] — the serial reference the
    /// parallel pipeline is differential-tested against (identical wire
    /// bytes for every thread count).
    pub fn quantize_streams_into(
        &self,
        g: &[f32],
        q: &dyn Quantizer,
        round_key: u64,
        out: &mut QuantizedGrad,
    ) {
        let n = self.num_buckets(g.len());
        out.bucket_size = self.bucket_size;
        out.total_len = g.len();
        out.buckets.truncate(n);
        while out.buckets.len() < n {
            out.buckets.push(super::QuantizedBucket::default());
        }
        let mut clip = Vec::new();
        for (bi, (chunk, qb)) in
            g.chunks(self.bucket_size).zip(out.buckets.iter_mut()).enumerate()
        {
            self.quantize_bucket_stream(chunk, bi, q, round_key, &mut clip, qb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::from_name;

    #[test]
    fn bucket_count_and_ragged_tail() {
        let bq = BucketQuantizer::new(100);
        assert_eq!(bq.num_buckets(1000), 10);
        assert_eq!(bq.num_buckets(1001), 11);
        assert_eq!(bq.num_buckets(99), 1);
        assert_eq!(bq.num_buckets(0), 0);
    }

    #[test]
    fn quantize_roundtrip_shape() {
        let mut rng = Rng::seed_from(1);
        let g: Vec<f32> = (0..1000).map(|_| rng.gaussian_f32()).collect();
        let q = from_name("orq-5").unwrap();
        let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut rng);
        assert_eq!(qg.buckets.len(), 8); // ceil(1000/128)
        assert_eq!(qg.buckets.last().unwrap().indices.len(), 1000 - 7 * 128);
        let deq = qg.dequantize();
        assert_eq!(deq.len(), 1000);
    }

    #[test]
    fn per_bucket_levels_differ() {
        // Buckets with different scales must get different level tables —
        // the reason bucketing exists.
        let mut g = vec![0.0f32; 256];
        let mut rng = Rng::seed_from(2);
        for v in g[..128].iter_mut() {
            *v = rng.gaussian_f32() * 0.01;
        }
        for v in g[128..].iter_mut() {
            *v = rng.gaussian_f32() * 10.0;
        }
        let q = from_name("terngrad").unwrap();
        let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut rng);
        let m0 = qg.buckets[0].levels[2];
        let m1 = qg.buckets[1].levels[2];
        assert!(m1 > m0 * 100.0, "scales must separate: {m0} vs {m1}");
    }

    #[test]
    fn clipping_reduces_range() {
        let mut rng = Rng::seed_from(3);
        let mut g: Vec<f32> = (0..2048).map(|_| rng.gaussian_f32()).collect();
        g[7] = 100.0;
        let q = from_name("terngrad").unwrap();
        let unclipped = BucketQuantizer::new(2048).quantize(&g, q.as_ref(), &mut rng);
        let clipped = BucketQuantizer::with_clip(2048, 2.5).quantize(&g, q.as_ref(), &mut rng);
        assert!(clipped.buckets[0].levels[2] < unclipped.buckets[0].levels[2] / 10.0);
    }

    #[test]
    fn clipping_does_not_mutate_input() {
        let g = vec![1.0f32, -50.0, 2.0, 3.0];
        let orig = g.clone();
        let q = from_name("terngrad").unwrap();
        let _ = BucketQuantizer::with_clip(4, 1.0).quantize(&g, q.as_ref(), &mut Rng::seed_from(0));
        assert_eq!(g, orig);
    }

    #[test]
    fn quantize_into_reuses_and_matches() {
        let mut rng = Rng::seed_from(9);
        let g: Vec<f32> = (0..700).map(|_| rng.gaussian_f32()).collect();
        let q = from_name("orq-3").unwrap();
        let bq = BucketQuantizer::new(256);
        let fresh = bq.quantize(&g, q.as_ref(), &mut Rng::seed_from(4));
        // Reused output seeded with stale state from a longer gradient.
        let mut reused = bq.quantize(&vec![1.0f32; 2000], q.as_ref(), &mut Rng::seed_from(0));
        bq.quantize_into(&g, q.as_ref(), &mut Rng::seed_from(4), &mut reused);
        assert_eq!(reused.total_len, 700);
        assert_eq!(reused.buckets.len(), fresh.buckets.len());
        assert_eq!(reused.dequantize(), fresh.dequantize());
    }

    /// Stream quantization is bucket-order independent: quantizing any
    /// single bucket in isolation reproduces its slot in the full run,
    /// and clipping behaves identically to the sequential path.
    #[test]
    fn stream_quantization_is_order_independent() {
        let mut rng = Rng::seed_from(17);
        let g: Vec<f32> = (0..900).map(|_| rng.gaussian_f32()).collect();
        for bq in [BucketQuantizer::new(256), BucketQuantizer::with_clip(256, 2.0)] {
            let q = from_name("orq-5").unwrap();
            let mut full = QuantizedGrad::default();
            bq.quantize_streams_into(&g, q.as_ref(), 99, &mut full);
            assert_eq!(full.buckets.len(), 4);
            // re-derive buckets in reverse order through the per-bucket entry
            let mut clip = Vec::new();
            for bi in (0..4usize).rev() {
                let lo = bi * 256;
                let hi = (lo + 256).min(g.len());
                let mut qb = QuantizedBucket::default();
                bq.quantize_bucket_stream(&g[lo..hi], bi, q.as_ref(), 99, &mut clip, &mut qb);
                assert_eq!(qb, full.buckets[bi], "bucket {bi}");
            }
            // a different round key decorrelates the rounding draws
            let mut other = QuantizedGrad::default();
            bq.quantize_streams_into(&g, q.as_ref(), 100, &mut other);
            assert_ne!(full.buckets[0].indices, other.buckets[0].indices);
        }
    }

    #[test]
    fn empty_gradient() {
        let q = from_name("orq-3").unwrap();
        let qg = BucketQuantizer::new(64).quantize(&[], q.as_ref(), &mut Rng::seed_from(0));
        assert!(qg.buckets.is_empty());
        assert!(qg.dequantize().is_empty());
    }
}
