//! TernGrad (Wen et al., NeurIPS 2017): 3 levels {−m, 0, +m}, m = max|v|.
//!
//! The paper's primary 3-level baseline ("TernGrad-noclip" when run
//! without the 2.5σ clipping of [`crate::quant::clip`]).

use super::{random_round, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;
use crate::tensor::stats::SliceStats;

pub struct TernGradQuantizer;

impl Quantizer for TernGradQuantizer {
    fn name(&self) -> String {
        "terngrad".into()
    }

    fn num_levels(&self) -> usize {
        3
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket) {
        let m = SliceStats::compute(g).max_abs();
        // Degenerate all-zero bucket: keep a tiny symmetric range so the
        // level vector stays strictly sorted (everything maps to level 0).
        let m = if m > 0.0 { m } else { 1.0 };
        out.levels.clear();
        out.levels.extend_from_slice(&[-m, 0.0, m]);
        random_round(g, &out.levels, rng, &mut out.indices);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::mse;

    #[test]
    fn levels_at_max_abs() {
        let g = [0.5f32, -2.0, 1.0, 0.0];
        let qb = TernGradQuantizer.quantize_bucket(&g, &mut Rng::seed_from(1));
        assert_eq!(qb.levels, vec![-2.0, 0.0, 2.0]);
    }

    #[test]
    fn unbiased_in_expectation() {
        let g = vec![0.5f32; 20_000]; // halfway between 0 and max=0.5? max=0.5 -> exact level
        let qb = TernGradQuantizer.quantize_bucket(&g, &mut Rng::seed_from(2));
        // 0.5 == max so it should hit the top level exactly
        assert!(qb.dequantize().iter().all(|&v| v == 0.5));

        // Now a value strictly inside (0, max): mean of dequant ≈ v.
        let mut g2 = vec![0.3f32; 20_000];
        g2.push(1.0); // sets max
        let qb2 = TernGradQuantizer.quantize_bucket(&g2, &mut Rng::seed_from(3));
        let deq = qb2.dequantize();
        let mean = deq[..20_000].iter().map(|&v| v as f64).sum::<f64>() / 20_000.0;
        assert!((mean - 0.3).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zero_bucket_stays_zero() {
        let g = vec![0.0f32; 64];
        let qb = TernGradQuantizer.quantize_bucket(&g, &mut Rng::seed_from(4));
        assert!(qb.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn error_below_range_square() {
        // Quantization error per element is bounded by the bracket width².
        let mut rng = Rng::seed_from(5);
        let g: Vec<f32> = (0..2048).map(|_| rng.gaussian_f32()).collect();
        let qb = TernGradQuantizer.quantize_bucket(&g, &mut rng);
        let err = mse(&g, &qb.dequantize());
        let m = qb.levels[2] as f64;
        assert!(err <= m * m, "err={err} m²={}", m * m);
    }
}
