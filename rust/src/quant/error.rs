//! Quantization-error metrics: the quantities Figures 1-2 track.

use super::bucket::QuantizedGrad;
use crate::tensor::{cosine, mse, norm2};

/// Expected random-rounding MSE of the given levels on a bucket — the
/// objective D of Eq. (9): `E(v − Q(v))² = Σ (v − b_lo)(b_hi − v)` for the
/// bracket of each v (zero outside the level range where clamping applies,
/// which contributes `(v − b_edge)²` instead).
pub fn expected_rr_mse(g: &[f32], levels: &[f32]) -> f64 {
    debug_assert!(levels.len() >= 2);
    if g.is_empty() {
        return 0.0;
    }
    let s = levels.len();
    let mut acc = 0.0f64;
    for &v in g {
        let lower = levels.partition_point(|&b| b <= v).saturating_sub(1).min(s - 2);
        let b_lo = levels[lower] as f64;
        let b_hi = levels[lower + 1] as f64;
        let vd = v as f64;
        if vd < b_lo {
            acc += (vd - b_lo) * (vd - b_lo); // clamped below
        } else if vd > b_hi {
            acc += (vd - b_hi) * (vd - b_hi); // clamped above
        } else {
            acc += (vd - b_lo) * (b_hi - vd); // Eq. (9) integrand
        }
    }
    acc / g.len() as f64
}

/// Realized quantization error of one quantized gradient vs the original:
/// relative MSE `‖Q(G) − G‖² / ‖G‖²` plus cosine similarity.
#[derive(Debug, Clone, Copy)]
pub struct QuantError {
    pub mse: f64,
    pub rel_mse: f64,
    pub cosine: f64,
}

pub fn measure(original: &[f32], quantized: &QuantizedGrad) -> QuantError {
    let mut scratch = Vec::new();
    measure_into(original, quantized, &mut scratch)
}

/// [`measure`] through a reused dequantization scratch (hot path: the
/// trainer calls this every step without allocating the full gradient).
pub fn measure_into(
    original: &[f32],
    quantized: &QuantizedGrad,
    scratch: &mut Vec<f32>,
) -> QuantError {
    scratch.clear();
    scratch.resize(quantized.total_len, 0.0);
    quantized.dequantize_into(scratch);
    measure_flat(original, scratch)
}

/// [`measure`] against an already-dequantized flat gradient (e.g. a
/// decoded wire message — the parallel codec path never materializes a
/// [`QuantizedGrad`], and `decode(encode(g))` equals `dequantize` by
/// construction).
pub fn measure_flat(original: &[f32], dequantized: &[f32]) -> QuantError {
    let m = mse(original, dequantized);
    let n2 = norm2(original) as f64;
    let denom = if n2 > 0.0 { n2 * n2 / original.len().max(1) as f64 } else { 1.0 };
    QuantError { mse: m, rel_mse: m / denom, cosine: cosine(original, dequantized) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bucket::BucketQuantizer;
    use crate::quant::from_name;
    use crate::tensor::rng::Rng;

    #[test]
    fn expected_mse_zero_on_levels() {
        let levels = [-1.0f32, 0.0, 1.0];
        assert_eq!(expected_rr_mse(&[-1.0, 0.0, 1.0], &levels), 0.0);
    }

    #[test]
    fn expected_mse_peak_at_midpoint() {
        let levels = [0.0f32, 1.0];
        // E(v-Q)² at v=0.5 is 0.25 (Bernoulli variance at p=1/2)
        assert!((expected_rr_mse(&[0.5], &levels) - 0.25).abs() < 1e-9);
        // at v=0.25: 0.25*0.75 = 0.1875
        assert!((expected_rr_mse(&[0.25], &levels) - 0.1875).abs() < 1e-9);
    }

    #[test]
    fn expected_mse_clamp_penalty() {
        let levels = [-1.0f32, 1.0];
        assert!((expected_rr_mse(&[3.0], &levels) - 4.0).abs() < 1e-9);
        assert!((expected_rr_mse(&[-2.0], &levels) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn expected_matches_monte_carlo() {
        let mut rng = Rng::seed_from(1);
        let g: Vec<f32> = (0..256).map(|_| rng.gaussian_f32()).collect();
        let q = from_name("qsgd-5").unwrap();
        let bq = BucketQuantizer::new(256);
        // analytic expectation uses the actual per-bucket levels
        let levels = bq.quantize(&g, q.as_ref(), &mut Rng::seed_from(0)).buckets[0]
            .levels
            .clone();
        let analytic = expected_rr_mse(&g, &levels);
        let n = 400;
        let mut acc = 0.0;
        for t in 0..n {
            let qg = bq.quantize(&g, q.as_ref(), &mut Rng::seed_from(100 + t));
            acc += mse(&g, &qg.dequantize());
        }
        let mc = acc / n as f64;
        assert!(
            (mc - analytic).abs() < analytic * 0.15 + 1e-6,
            "MC {mc} vs analytic {analytic}"
        );
    }

    #[test]
    fn measure_perfect_roundtrip() {
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let q = from_name("signsgd").unwrap();
        let qg = BucketQuantizer::new(4).quantize(&g, q.as_ref(), &mut Rng::seed_from(0));
        let e = measure(&g, &qg);
        assert!(e.mse < 1e-12);
        assert!((e.cosine - 1.0).abs() < 1e-9);
    }
}
