//! BinGrad — the paper's two binary (1-bit) quantizers.
//!
//! * **BinGrad-pb** (partially biased, Eq. 14/15): symmetric levels ±b₁
//!   where b₁ solves `b₁ ∫₀^∞ p(v)dv = ∫_{b₁}^∞ v p(v)dv` for zero-mean
//!   symmetric p. Values inside (−b₁, b₁) use unbiased random rounding;
//!   values outside clamp (that clamping is the only bias — hence
//!   "partially biased"). Smaller quantization *range* resilience to
//!   outliers, larger error than BinGrad-b.
//! * **BinGrad-b** (fully biased, Eq. 16/17): deterministic threshold
//!   quantization. Optimal levels for any p are the conditional means:
//!   `b₋₁ = E[v | v < b₀]`, `b₁ = E[v | v ≥ b₀]`, `b₀ = (b₋₁+b₁)/2` — a
//!   1-D 2-means fixed point. Minimum quantization error, some bias: the
//!   bias/variance trade-off of §3.2.

use super::{QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

/// BinGrad-pb: Eq. (15) level solve + Eq. (14) partially biased rounding.
pub struct BinGradPb;

impl BinGradPb {
    pub fn new() -> Self {
        BinGradPb
    }

    /// Solve Eq. (15) on the empirical distribution.
    ///
    /// Discrete LHS(b) = b · |{v ≥ 0}| / N (∫₀^∞ p under symmetry) and
    /// RHS(b) = Σ_{v ≥ b} v / N. LHS is increasing in b, RHS decreasing,
    /// so the minimizer of |LHS − RHS| is found at the crossing with one
    /// sorted pass + suffix sums, then refined by interpolation.
    pub fn solve_b1(g: &[f32]) -> f32 {
        if g.is_empty() {
            return 0.0;
        }
        let n = g.len() as f64;
        let n_pos = g.iter().filter(|&&v| v >= 0.0).count() as f64;
        let p0 = n_pos / n; // ∫₀^∞ p(v) dv
        if p0 == 0.0 {
            // No positive mass: fall back to mean |v| so ±b1 still brackets.
            return (g.iter().map(|v| v.abs() as f64).sum::<f64>() / n) as f32;
        }

        let mut sorted: Vec<f32> = g.to_vec();
        sorted.sort_unstable_by(f32::total_cmp);
        // suffix[i] = Σ sorted[i..] (f64)
        let mut suffix = vec![0.0f64; sorted.len() + 1];
        for i in (0..sorted.len()).rev() {
            suffix[i] = suffix[i + 1] + sorted[i] as f64;
        }

        // f(b) = LHS - RHS = b·p0 − (1/N)·Σ_{v ≥ b} v, increasing in b.
        let f = |b: f64, idx: usize| -> f64 { b * p0 - suffix[idx] / n };
        // Walk candidate b = sorted[i] (only positive candidates matter).
        let mut best = (f64::INFINITY, sorted[sorted.len() - 1] as f64);
        let mut prev: Option<(f64, f64)> = None; // (b, f(b))
        for i in 0..sorted.len() {
            let b = sorted[i] as f64;
            if b < 0.0 {
                continue;
            }
            let fb = f(b, i);
            if fb.abs() < best.0 {
                best = (fb.abs(), b);
            }
            if let Some((pb, pf)) = prev {
                if pf < 0.0 && fb >= 0.0 && fb != pf {
                    // Crossing between pb and b: linear interpolation.
                    let t = -pf / (fb - pf);
                    let bx = pb + t * (b - pb);
                    // Residual at bx (same suffix index as b — piecewise).
                    let fx = f(bx, i);
                    if fx.abs() < best.0 {
                        best = (fx.abs(), bx);
                    }
                }
            }
            prev = Some((b, fb));
        }
        (best.1.max(0.0)) as f32
    }
}

impl Default for BinGradPb {
    fn default() -> Self {
        Self::new()
    }
}

impl Quantizer for BinGradPb {
    fn name(&self) -> String {
        "bingrad-pb".into()
    }

    fn num_levels(&self) -> usize {
        2
    }

    /// Partially biased: unbiased inside (−b₁, b₁), biased clamp outside.
    fn is_unbiased(&self) -> bool {
        false
    }

    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket) {
        let b1 = Self::solve_b1(g);
        let b1 = if b1 > 0.0 { b1 } else { 1e-12 };
        out.levels.clear();
        out.levels.extend_from_slice(&[-b1, b1]);
        // Eq. (14): clamp outside ±b1, random-round inside.
        out.indices.clear();
        out.indices.reserve(g.len());
        let width = 2.0 * b1;
        for &v in g {
            let idx = if v < -b1 {
                0
            } else if v >= b1 {
                1
            } else {
                let p = (v + b1) / width;
                (rng.f32() < p) as u8
            };
            out.indices.push(idx);
        }
    }
}

/// BinGrad-b: Eq. (17) conditional-mean levels + Eq. (16) deterministic
/// threshold quantization.
pub struct BinGradB {
    /// Fixed-point iterations (paper: "can set b₀ to the mean for ease of
    /// implementation" — that is iteration 1; more sweeps reach the exact
    /// 2-means optimum).
    pub iters: usize,
}

impl BinGradB {
    pub fn new() -> Self {
        BinGradB { iters: 8 }
    }

    pub fn with_iters(iters: usize) -> Self {
        BinGradB { iters: iters.max(1) }
    }

    /// Run the Eq. (17) fixed point: returns (b₋₁, b₀, b₁).
    pub fn solve_levels(&self, g: &[f32]) -> (f32, f32, f32) {
        if g.is_empty() {
            return (-1e-12, 0.0, 1e-12);
        }
        let n = g.len() as f64;
        let mean = g.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut b0 = mean;
        let (mut lo, mut hi) = (b0, b0);
        for _ in 0..self.iters {
            let (mut sl, mut nl, mut sh, mut nh) = (0.0f64, 0usize, 0.0f64, 0usize);
            for &v in g {
                if (v as f64) < b0 {
                    sl += v as f64;
                    nl += 1;
                } else {
                    sh += v as f64;
                    nh += 1;
                }
            }
            // One side empty: threshold outside the data — stop moving.
            if nl == 0 || nh == 0 {
                let m = mean;
                lo = m;
                hi = m;
                break;
            }
            lo = sl / nl as f64;
            hi = sh / nh as f64;
            let next = 0.5 * (lo + hi);
            if (next - b0).abs() < 1e-12 {
                b0 = next;
                break;
            }
            b0 = next;
        }
        if hi <= lo {
            hi = lo + (lo.abs() * 1e-6).max(1e-12);
        }
        (lo as f32, b0 as f32, hi as f32)
    }
}

impl Default for BinGradB {
    fn default() -> Self {
        Self::new()
    }
}

impl Quantizer for BinGradB {
    fn name(&self) -> String {
        "bingrad-b".into()
    }

    fn num_levels(&self) -> usize {
        2
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn quantize_bucket_into(&self, g: &[f32], _rng: &mut Rng, out: &mut QuantizedBucket) {
        let (lo, b0, hi) = self.solve_levels(g);
        out.levels.clear();
        out.levels.extend_from_slice(&[lo, hi]);
        out.indices.clear();
        out.indices.extend(g.iter().map(|&v| (v >= b0) as u8));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::mse;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    // ----------------------------------------------------------- pb ---

    #[test]
    fn pb_b1_on_standard_gaussian() {
        // For N(0,1): b₁·(1/2) = ∫_{b₁}^∞ v φ(v) dv = φ(b₁)
        // ⇒ b₁/2 = exp(−b₁²/2)/√(2π) ⇒ b₁ ≈ 0.6466 (numerically).
        let g = gaussian(200_000, 1);
        let b1 = BinGradPb::solve_b1(&g);
        assert!((b1 - 0.6466).abs() < 0.02, "b1={b1}");
    }

    #[test]
    fn pb_monotone_under_scaling() {
        let g = gaussian(50_000, 2);
        let b1 = BinGradPb::solve_b1(&g);
        let g2: Vec<f32> = g.iter().map(|v| v * 3.0).collect();
        let b1_scaled = BinGradPb::solve_b1(&g2);
        assert!((b1_scaled / b1 - 3.0).abs() < 0.05, "scale equivariance");
    }

    #[test]
    fn pb_clamps_outliers() {
        let mut g = vec![0.01f32; 1000];
        g.push(100.0); // outlier
        let q = BinGradPb::new();
        let qb = q.quantize_bucket(&g, &mut Rng::seed_from(3));
        // the outlier is clamped to +b1, which is far below 100
        let b1 = qb.levels[1];
        assert!(b1 < 10.0, "b1 should ignore the outlier, got {b1}");
        assert_eq!(qb.indices[1000], 1);
    }

    #[test]
    fn pb_unbiased_inside_range() {
        // A value inside (−b1, b1) must be unbiased under random rounding.
        let g = gaussian(20_000, 4);
        let q = BinGradPb::new();
        let b1 = BinGradPb::solve_b1(&g);
        let v = b1 * 0.3;
        let probe: Vec<f32> = std::iter::repeat(v).take(20_000).chain(g.iter().copied()).collect();
        let qb = q.quantize_bucket(&probe, &mut Rng::seed_from(5));
        let deq = qb.dequantize();
        let mean = deq[..20_000].iter().map(|&x| x as f64).sum::<f64>() / 20_000.0;
        let b1p = qb.levels[1] as f64;
        assert!((mean - v as f64).abs() < b1p * 0.05, "mean={mean} v={v}");
    }

    // ------------------------------------------------------------ b ---

    #[test]
    fn b_levels_are_conditional_means() {
        let g = gaussian(100_000, 6);
        let (lo, b0, hi) = BinGradB::new().solve_levels(&g);
        // brute-force conditional means at the returned threshold
        let below: Vec<f32> = g.iter().copied().filter(|&v| v < b0).collect();
        let above: Vec<f32> = g.iter().copied().filter(|&v| v >= b0).collect();
        let m_below = below.iter().map(|&v| v as f64).sum::<f64>() / below.len() as f64;
        let m_above = above.iter().map(|&v| v as f64).sum::<f64>() / above.len() as f64;
        assert!((lo as f64 - m_below).abs() < 1e-3, "lo={lo} cond-mean={m_below}");
        assert!((hi as f64 - m_above).abs() < 1e-3, "hi={hi} cond-mean={m_above}");
        assert!((b0 as f64 - 0.5 * (m_below + m_above)).abs() < 1e-3);
    }

    #[test]
    fn b_gaussian_levels_near_pm_0_8() {
        // 2-means on N(0,1): threshold 0, levels ±E[|v|] = ±√(2/π) ≈ ±0.7979.
        let g = gaussian(200_000, 7);
        let (lo, b0, hi) = BinGradB::new().solve_levels(&g);
        assert!(b0.abs() < 0.02, "b0={b0}");
        assert!((hi - 0.7979).abs() < 0.02, "hi={hi}");
        assert!((lo + 0.7979).abs() < 0.02, "lo={lo}");
    }

    #[test]
    fn b_beats_pb_on_quantization_error() {
        // §3.2: BinGrad-b achieves minimum quantization error (variance),
        // BinGrad-pb trades error for reduced bias.
        let g = gaussian(20_000, 8);
        let eb = mse(&g, &BinGradB::new().quantize_bucket(&g, &mut Rng::seed_from(9)).dequantize());
        let epb =
            mse(&g, &BinGradPb::new().quantize_bucket(&g, &mut Rng::seed_from(9)).dequantize());
        assert!(eb < epb, "BinGrad-b {eb} should beat pb {epb}");
    }

    #[test]
    fn b_optimal_vs_brute_force_2means() {
        // On a small bucket, compare against exhaustive threshold search.
        let g = gaussian(512, 10);
        let (lo, _b0, hi) = BinGradB::with_iters(64).solve_levels(&g);
        let ours = {
            let qb = BinGradB::with_iters(64).quantize_bucket(&g, &mut Rng::seed_from(0));
            mse(&g, &qb.dequantize())
        };
        // brute force over every possible split of the sorted bucket
        let mut sorted = g.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut best = f64::INFINITY;
        for split in 1..sorted.len() {
            let (a, b) = sorted.split_at(split);
            let ma = a.iter().map(|&v| v as f64).sum::<f64>() / a.len() as f64;
            let mb = b.iter().map(|&v| v as f64).sum::<f64>() / b.len() as f64;
            let e = (a.iter().map(|&v| (v as f64 - ma).powi(2)).sum::<f64>()
                + b.iter().map(|&v| (v as f64 - mb).powi(2)).sum::<f64>())
                / sorted.len() as f64;
            best = best.min(e);
        }
        assert!(
            ours <= best * 1.05,
            "fixed point {ours} should be near brute-force optimum {best} (lo={lo} hi={hi})"
        );
    }

    #[test]
    fn b_constant_bucket() {
        let g = vec![3.0f32; 64];
        let qb = BinGradB::new().quantize_bucket(&g, &mut Rng::seed_from(0));
        let deq = qb.dequantize();
        for v in deq {
            assert!((v - 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_buckets_do_not_panic() {
        let qb = BinGradB::new().quantize_bucket(&[], &mut Rng::seed_from(0));
        assert!(qb.indices.is_empty());
        let qb = BinGradPb::new().quantize_bucket(&[], &mut Rng::seed_from(0));
        assert!(qb.indices.is_empty());
    }
}
