//! Persistent codec/shard worker pool — long-lived threads fed by
//! channels, replacing the per-round `std::thread::scope` spawns of the
//! parallel bucket pipeline (and hosting the sharded-PS reduce loops).
//!
//! ## Why a pool
//!
//! The scoped pipeline (`super::parallel` in its legacy mode) spawns `k`
//! OS threads *per exchange round* and tears them down at the join. Two
//! costs recur every round: the spawns themselves, and — more subtly —
//! the per-thread level-solver arenas (`super::scratch`), which are
//! `thread_local` and therefore die with the scoped threads, so the
//! sort/prefix buffers of the `orq-S`/`linear-S` solvers re-grow from
//! empty each round. Adaptive schemes re-solve levels every round, which
//! makes that amortized per-round overhead the dominant encode cost on
//! small-to-medium gradients. A pool keeps the threads (and with them
//! their `thread_local` arenas) alive for the whole training run: round 1
//! pays the spawns and the arena growth, steady-state rounds pay neither.
//!
//! ## Execution model
//!
//! [`WorkerPool`] is a *cached* pool: it keeps a registry of idle
//! workers, each parked on its own job channel. Dispatching a task pops
//! an idle worker (no spawn) or, when none is idle, spawns a fresh one
//! that re-registers itself after every job. Capacity therefore adapts
//! to peak demand and is never a deadlock bound — tasks that *block*
//! (exchange worker loops waiting on channel peers, shard reduce loops)
//! always start immediately, exactly like the scoped threads they
//! replace, and nested scopes (a pooled exchange worker driving a pooled
//! codec) cannot starve. [`WorkerPool::threads`] is the *parallelism
//! target* used by components that shard work (`threads == 0` at
//! construction auto-sizes to `std::thread::available_parallelism`,
//! deterministically — the same value every time on a given machine);
//! the live thread count is demand-driven and capped only by the task
//! count.
//!
//! Two entry points:
//!
//! * [`WorkerPool::scope`] — structured, *borrowing* round tasks: the
//!   closure spawns tasks that may borrow caller state (gradient slices,
//!   shard arenas), runs coordinator-side code while they execute, and
//!   the scope does not return until every spawned task has finished.
//!   This mirrors `std::thread::scope`, minus the spawns.
//! * [`WorkerPool::spawn_detached`] — unstructured `'static` services
//!   (the sharded-PS reduce loops): the task owns its channels and exits
//!   when they disconnect; the thread then returns to the idle registry
//!   for reuse.
//!
//! ## Ownership and lifetime of arenas
//!
//! Three kinds of scratch live at three lifetimes:
//!
//! * **Pipeline shard arenas** (`parallel::Shard`: segment buffers,
//!   reusable quantized bucket, clip/decode scratch) are owned by the
//!   [`BucketPipeline`](super::parallel::BucketPipeline) and *borrowed*
//!   by round tasks through [`WorkerPool::scope`] — they persist across
//!   rounds regardless of which pool worker runs which shard.
//! * **Level-solver arenas** (`super::scratch::SortScratch`) are
//!   `thread_local` to the pool workers. Because the workers are
//!   long-lived, these now persist for the whole run; solver output is
//!   independent of arena history (buffers are cleared before use), so
//!   reuse is bit-invisible — the scheme tests pin this down.
//! * **Task-owned state** (shard-server accumulators) moves into
//!   detached tasks and lives exactly as long as the service.
//!
//! ## Shutdown protocol and panic safety
//!
//! Dropping the last [`PoolHandle`] closes the registry, delivers an
//! exit message to every idle worker, and **joins** every thread the
//! pool ever spawned. Busy workers observe the closed registry when
//! their current task ends and exit instead of re-registering. Drop the
//! structures a detached service blocks on (its channels) *before* the
//! last handle — [`super::super::comm::async_ps`] guarantees this by
//! holding a handle clone that drops after the collective's channels.
//!
//! A panicking task is caught on the worker (`catch_unwind`), reported
//! through the scope as an `Err` — never a hang — and the worker thread
//! survives and returns to the idle registry. Lost tasks (a worker dying
//! without reporting, or an OS spawn failure) are detected through the
//! completion channel disconnecting and also surface as `Err`.
//!
//! ## Soundness of the borrowing scope
//!
//! [`PoolScope::spawn`] erases the task's borrow lifetime to send it
//! through the `'static` job channels (the one `unsafe` in this module).
//! This is sound for the same reason `std::thread::scope` is: a drain
//! guard inside [`WorkerPool::scope`] blocks — on the normal path *and*
//! during unwinding — until every spawned task has either completed
//! (reported on the completion channel) or been destroyed unrun (its
//! completion sender dropped, observed as a disconnect), so no task can
//! touch its borrows after `scope` returns. The scope's environment
//! lifetime is invariant, which prevents spawning tasks that borrow
//! state created *inside* the scope closure (such state would die before
//! the drain).

use std::cell::Cell;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// Deterministic auto-size for `threads == 0`: the machine's available
/// parallelism (1 if undetectable). Resolved once per call site, never
/// re-sampled mid-run, so sharded (`--shards`) and flat drivers that
/// resolve it independently agree.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One unit of pool work plus its completion reporter. `done` carries
/// `true` for a clean finish, `false` for a caught panic; dropping a job
/// unrun drops the sender, which the drain guard observes as a lost task.
struct Job {
    task: Box<dyn FnOnce() + Send + 'static>,
    done: Sender<bool>,
    /// Dispatch wall stamp (µs, recorder clock); 0 when tracing is off.
    /// The worker reports `now − enqueued_us` as its queue-wait counter.
    enqueued_us: u64,
}

/// Message to a parked worker.
enum Msg {
    Job(Job),
    Exit,
}

/// Shared pool state: the idle-worker stack and every join handle.
struct Registry {
    idle: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
    /// Set by `Drop`; busy workers exit instead of re-registering.
    closed: bool,
    /// Total threads ever spawned (amortization diagnostics and tests).
    spawned: usize,
}

/// The persistent worker pool. Construct through [`PoolHandle::new`] so
/// the pool can be shared across codecs, collectives and drivers.
pub struct WorkerPool {
    threads: usize,
    registry: Arc<Mutex<Registry>>,
    /// Trace recorder cloned into every worker thread: queue-wait
    /// counters and task-run spans land on `Track::Pool(index)`, where
    /// `index` is the thread's spawn ordinal (the `orq-pool-{index}`
    /// name). Defaults to off — one relaxed atomic load per job.
    recorder: crate::obs::TraceRecorder,
}

/// Lock helper: the registry holds no user invariants a panicked task
/// could have broken (tasks never run under the lock), so a poisoned
/// mutex is safe to recover.
fn lock(reg: &Mutex<Registry>) -> MutexGuard<'_, Registry> {
    reg.lock().unwrap_or_else(|p| p.into_inner())
}

fn worker_loop(
    rx: Receiver<Msg>,
    my_tx: Sender<Msg>,
    registry: Arc<Mutex<Registry>>,
    recorder: crate::obs::TraceRecorder,
    index: u16,
) {
    let track = crate::obs::Track::Pool(index);
    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return,
        };
        match msg {
            Msg::Exit => return,
            Msg::Job(Job { task, done, enqueued_us }) => {
                let fine = recorder.is_fine();
                if fine {
                    if enqueued_us > 0 {
                        let waited = recorder.now_us().saturating_sub(enqueued_us);
                        recorder.counter(track, "queue_wait_us", waited as f64);
                    }
                    recorder.begin(track, "pool_task");
                }
                let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
                if fine {
                    recorder.end(track, "pool_task");
                }
                // Re-register BEFORE reporting completion: when a scope's
                // drain returns, every worker it used is already back in
                // the idle registry, so the caller's next round
                // deterministically reuses threads instead of racing the
                // re-registration and spawning extras.
                let exit = {
                    let mut reg = lock(&registry);
                    if reg.closed {
                        true
                    } else {
                        reg.idle.push(my_tx.clone());
                        false
                    }
                };
                let _ = done.send(ok);
                if exit {
                    return;
                }
            }
        }
    }
}

impl WorkerPool {
    /// `threads == 0` auto-sizes (see [`auto_threads`]); the value is the
    /// sharding *target* reported by [`Self::threads`], capped at 256
    /// like the pipeline's. No threads are spawned until work arrives.
    pub fn new(threads: usize) -> WorkerPool {
        Self::with_recorder(threads, crate::obs::TraceRecorder::off())
    }

    /// Like [`Self::new`], with a trace recorder the workers report
    /// queue-wait counters and task-run spans through.
    pub fn with_recorder(threads: usize, recorder: crate::obs::TraceRecorder) -> WorkerPool {
        let t = if threads == 0 { auto_threads() } else { threads };
        WorkerPool {
            threads: t.clamp(1, 256),
            registry: Arc::new(Mutex::new(Registry {
                idle: Vec::new(),
                handles: Vec::new(),
                closed: false,
                spawned: 0,
            })),
            recorder,
        }
    }

    /// The resolved parallelism target (never 0).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total worker threads ever spawned. Steady state: this stops
    /// growing once peak concurrent demand has been seen once.
    pub fn threads_spawned(&self) -> usize {
        lock(&self.registry).spawned
    }

    /// Hand `job` to an idle worker, or spawn a new one. `Err` only if
    /// the OS refuses a needed thread spawn (the job is dropped unrun,
    /// which the caller's drain observes through the done channel).
    fn dispatch(&self, mut job: Job) -> Result<()> {
        if self.recorder.is_fine() {
            job.enqueued_us = self.recorder.now_us();
        }
        loop {
            let idle = {
                let mut reg = lock(&self.registry);
                reg.idle.pop()
            };
            match idle {
                Some(tx) => match tx.send(Msg::Job(job)) {
                    Ok(()) => return Ok(()),
                    // The worker died (it never does in normal operation,
                    // but a lost thread must not lose the job): recover
                    // the job and try the next idle worker or spawn.
                    Err(send_err) => match send_err.0 {
                        Msg::Job(j) => job = j,
                        Msg::Exit => unreachable!("dispatch never sends Exit"),
                    },
                },
                None => {
                    let (tx, rx) = channel::<Msg>();
                    let registry = Arc::clone(&self.registry);
                    let my_tx = tx.clone();
                    let recorder = self.recorder.clone();
                    let mut reg = lock(&self.registry);
                    let name = format!("orq-pool-{}", reg.spawned);
                    let index = reg.spawned.min(u16::MAX as usize) as u16;
                    let handle = std::thread::Builder::new()
                        .name(name)
                        .spawn(move || worker_loop(rx, my_tx, registry, recorder, index))?;
                    reg.spawned += 1;
                    reg.handles.push(handle);
                    drop(reg);
                    tx.send(Msg::Job(job)).map_err(|_| {
                        Error::Comm("pool worker exited before its first job".into())
                    })?;
                    return Ok(());
                }
            }
        }
    }

    /// Run a batch of borrowing tasks to completion: `f` spawns tasks on
    /// the given [`PoolScope`] (they start immediately on pool workers)
    /// and may keep doing caller-side work; when `f` returns, `scope`
    /// blocks until every spawned task has finished. Returns `Err` if any
    /// task panicked or was lost — never hangs on a dead worker.
    pub fn scope<'env, R>(&self, f: impl FnOnce(&PoolScope<'_, 'env>) -> R) -> Result<R> {
        let (done_tx, done_rx) = channel::<bool>();
        let state = ScopeState {
            submitted: Cell::new(0),
            lost: Cell::new(false),
            panicked: Cell::new(false),
        };
        let result = {
            // Declared first ⇒ dropped last: the guard drains after the
            // scope below has released its completion sender, so a
            // disconnect on `done_rx` reliably means "no task left".
            let _guard = DrainGuard { rx: &done_rx, state: &state };
            let scope = PoolScope { pool: self, done_tx, state: &state, _env: PhantomData };
            f(&scope)
        };
        if state.lost.get() {
            Err(Error::Comm("worker pool lost a task (worker died or spawn failed)".into()))
        } else if state.panicked.get() {
            Err(Error::Comm("worker pool task panicked".into()))
        } else {
            Ok(result)
        }
    }

    /// Run a self-contained (`'static`) service on a pool worker — the
    /// sharded-PS reduce loops. Nobody joins the task itself; it must
    /// exit on its own (by observing its channels disconnect) before the
    /// last [`PoolHandle`] drops, or the final join will wait for it.
    pub fn spawn_detached(&self, f: impl FnOnce() + Send + 'static) -> Result<()> {
        let (done_tx, _) = channel::<bool>();
        self.dispatch(Job { task: Box::new(f), done: done_tx, enqueued_us: 0 })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let (idle, handles) = {
            let mut reg = lock(&self.registry);
            reg.closed = true;
            (std::mem::take(&mut reg.idle), std::mem::take(&mut reg.handles))
        };
        for tx in idle {
            let _ = tx.send(Msg::Exit);
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Scope-shared bookkeeping (single-threaded: only the scope closure's
/// thread spawns).
struct ScopeState {
    submitted: Cell<usize>,
    lost: Cell<bool>,
    panicked: Cell<bool>,
}

/// Spawning handle passed to the closure of [`WorkerPool::scope`].
pub struct PoolScope<'pool, 'env> {
    pool: &'pool WorkerPool,
    done_tx: Sender<bool>,
    state: &'pool ScopeState,
    /// Invariant in `'env` (see the module docs' soundness note).
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'env> PoolScope<'_, 'env> {
    /// Spawn one task. It starts immediately on an idle (or fresh) pool
    /// worker and may borrow anything that outlives the `scope` call.
    pub fn spawn(&self, task: impl FnOnce() + Send + 'env) {
        let boxed: Box<dyn FnOnce() + Send + 'env> = Box::new(task);
        // SAFETY: the drain guard in `WorkerPool::scope` blocks (also
        // during unwinding) until this task has run to completion or been
        // destroyed unrun, both of which end its borrows; `'env` is
        // invariant, so it cannot be shrunk to borrow scope-local state.
        let boxed: Box<dyn FnOnce() + Send + 'static> = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + 'env>,
                Box<dyn FnOnce() + Send + 'static>,
            >(boxed)
        };
        match self.pool.dispatch(Job { task: boxed, done: self.done_tx.clone(), enqueued_us: 0 }) {
            Ok(()) => self.state.submitted.set(self.state.submitted.get() + 1),
            Err(_) => self.state.lost.set(true),
        }
    }
}

/// Blocks until every spawned task of one scope has reported (or been
/// destroyed). Runs in `Drop` so a panicking scope closure still drains
/// before its borrows unwind.
struct DrainGuard<'a> {
    rx: &'a Receiver<bool>,
    state: &'a ScopeState,
}

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        let mut remaining = self.state.submitted.get();
        while remaining > 0 {
            match self.rx.recv() {
                Ok(true) => {}
                Ok(false) => self.state.panicked.set(true),
                // All completion senders gone with reports outstanding:
                // some delivered job was destroyed unrun. Its borrows are
                // over (the closure was dropped), so returning is safe —
                // report it as a lost task.
                Err(_) => {
                    self.state.lost.set(true);
                    break;
                }
            }
            remaining -= 1;
        }
    }
}

/// Shared, cloneable handle to a [`WorkerPool`]. The pool shuts down
/// (exit messages + joins) when the last handle drops.
#[derive(Clone)]
pub struct PoolHandle(Arc<WorkerPool>);

impl PoolHandle {
    /// Build a pool behind a shareable handle (`threads == 0` = auto).
    pub fn new(threads: usize) -> PoolHandle {
        PoolHandle(Arc::new(WorkerPool::new(threads)))
    }

    /// Build a traced pool behind a shareable handle.
    pub fn with_recorder(threads: usize, recorder: crate::obs::TraceRecorder) -> PoolHandle {
        PoolHandle(Arc::new(WorkerPool::with_recorder(threads, recorder)))
    }
}

impl std::ops::Deref for PoolHandle {
    type Target = WorkerPool;

    fn deref(&self) -> &WorkerPool {
        &self.0
    }
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PoolHandle(threads = {})", self.0.threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_borrowing_tasks_and_blocks_until_done() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0u64; 8];
        let input: Vec<u64> = (0..8).collect();
        pool.scope(|s| {
            for (o, i) in out.iter_mut().zip(&input) {
                s.spawn(move || *o = i * i);
            }
        })
        .unwrap();
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn workers_are_reused_across_scopes() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..20 {
            pool.scope(|s| {
                for _ in 0..2 {
                    s.spawn(|| {
                        count.fetch_add(1, Ordering::SeqCst);
                    });
                }
            })
            .unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 40);
        // 20 rounds of ≤ 2 concurrent tasks: peak demand bounds spawns,
        // not round count — the amortization the pool exists for.
        assert!(pool.threads_spawned() <= 2, "spawned {}", pool.threads_spawned());
    }

    /// A panicking task must surface as `Err` (not a hang), and the pool
    /// must keep working afterwards.
    #[test]
    fn panicked_task_is_err_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let err = pool
            .scope(|s| {
                s.spawn(|| panic!("injected"));
                s.spawn(|| {});
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // same pool, next round: healthy
        let mut x = 0u32;
        pool.scope(|s| s.spawn(|| x = 7)).unwrap();
        assert_eq!(x, 7);
    }

    /// The scope must drain spawned tasks even when the scope closure
    /// itself panics (the borrows unwind right after).
    #[test]
    fn scope_closure_panic_still_drains_tasks() {
        let pool = WorkerPool::new(1);
        let flag = AtomicUsize::new(0);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.scope(|s| {
                s.spawn(|| {
                    flag.fetch_add(1, Ordering::SeqCst);
                });
                panic!("scope body");
            });
        }));
        assert!(caught.is_err());
        assert_eq!(flag.load(Ordering::SeqCst), 1, "task ran before unwind passed the scope");
    }

    #[test]
    fn blocking_tasks_all_start_nested_scopes_do_not_starve() {
        // More mutually-blocking tasks than any fixed pool size: each
        // task only finishes once every task has started (rendezvous via
        // a channel fan-in), which deadlocks any bounded-queue design.
        let pool = WorkerPool::new(1);
        let n = 6;
        let (tx, rx) = channel::<usize>();
        let barrier = Arc::new(std::sync::Barrier::new(n));
        pool.scope(|s| {
            for i in 0..n {
                let tx = tx.clone();
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    barrier.wait();
                    tx.send(i).unwrap();
                });
            }
            drop(tx);
            let got: Vec<usize> = rx.iter().take(n).collect();
            assert_eq!(got.len(), n);
        })
        .unwrap();
        // nested: a pooled task drives its own scope on the same pool
        let pool_ref = &pool;
        let mut out = [0u32; 4];
        pool_ref
            .scope(|outer| {
                let slots: &mut [u32] = &mut out;
                outer.spawn(move || {
                    pool_ref
                        .scope(|inner| {
                            for (i, slot) in slots.iter_mut().enumerate() {
                                inner.spawn(move || *slot = i as u32 + 1);
                            }
                        })
                        .unwrap();
                });
            })
            .unwrap();
        assert_eq!(out, [1, 2, 3, 4]);
    }

    #[test]
    fn detached_service_runs_and_pool_shuts_down_cleanly() {
        let (tx, rx) = channel::<u32>();
        {
            let pool = PoolHandle::new(2);
            pool.spawn_detached(move || {
                // a miniature shard server: serve until disconnect
                let _ = tx.send(41);
                let _ = tx.send(42);
            })
            .unwrap();
            assert_eq!(rx.recv().unwrap(), 41);
            assert_eq!(rx.recv().unwrap(), 42);
            // handle drop here joins every worker — must not hang
        }
        assert!(rx.recv().is_err(), "service exited with the pool");
    }

    #[test]
    fn auto_sizing_is_deterministic_and_positive() {
        let a = WorkerPool::new(0).threads();
        let b = WorkerPool::new(0).threads();
        assert_eq!(a, b, "auto-size must resolve identically every time");
        assert!(a >= 1);
        assert_eq!(WorkerPool::new(3).threads(), 3);
        assert_eq!(WorkerPool::new(100_000).threads(), 256, "capped");
        assert_eq!(auto_threads(), a);
    }

    #[test]
    fn scope_with_zero_tasks_is_a_no_op() {
        let pool = WorkerPool::new(2);
        let r = pool.scope(|_s| 11).unwrap();
        assert_eq!(r, 11);
        assert_eq!(pool.threads_spawned(), 0, "no work, no threads");
    }
}
