//! Gradient quantization — the paper's contribution and all its baselines.
//!
//! Every scheme implements [`Quantizer`]: given one *bucket* (a fixed-size
//! slice of the flattened gradient, paper §5: d = 512…2048), it places its
//! quantization levels and maps each element to a level index. The codec
//! ([`crate::codec`]) turns `(levels, indices)` into wire bytes.
//!
//! Schemes:
//! * [`fp::FpQuantizer`] — identity (32-bit float, compression ×1);
//! * [`terngrad::TernGradQuantizer`] — 3 evenly spaced levels at ±max|v|
//!   (Wen et al. 2017), random rounding, optional 2.5σ clipping upstream;
//! * [`qsgd::QsgdQuantizer`] — s evenly spaced levels on [−max|v|, max|v|]
//!   (Alistarh et al. 2017 as run in the paper's figures), random rounding;
//! * [`linear::LinearQuantizer`] — s levels at equal-mass CDF quantiles
//!   (the paper's naive baseline), random rounding;
//! * [`orq::OrqQuantizer`] — **ORQ**: optimal levels from Theorem 1 /
//!   Eq. (12) solved by the greedy recursive Algorithm 1, random rounding;
//! * [`bingrad::BinGradPb`] — **BinGrad-pb**: ±b₁ from Eq. (15), random
//!   rounding inside (−b₁, b₁), clamp outside (partially biased);
//! * [`bingrad::BinGradB`] — **BinGrad-b**: deterministic threshold
//!   quantization with conditional-mean levels from Eq. (17) (biased);
//! * [`signsgd::SignSgdQuantizer`] — scaled sign (Eq. 13), deterministic.
//!
//! Schemes implement [`Quantizer::quantize_bucket_into`], which writes
//! into a caller-owned [`QuantizedBucket`] so the per-round exchange path
//! reuses its level/index buffers instead of allocating per bucket; the
//! allocating [`Quantizer::quantize_bucket`] is a convenience wrapper.
//! The sort-based level solvers (`orq-S`, `linear-S`) keep their
//! sort/prefix scratch in per-thread arenas (`scratch`) — no locks, so
//! the parallel bucket pipeline ([`parallel`]) can drive one quantizer
//! from many threads without contention — and steady-state
//! `quantize_bucket_into` calls are allocation-free for every scheme,
//! asserted bit-identical to the allocating reference solvers (and to a
//! mutex-locked replica of the PR 2 path) by the scheme tests.

pub mod bingrad;
pub mod bucket;
pub mod budget;
pub mod clip;
pub mod error;
pub mod error_feedback;
pub mod fp;
pub mod linear;
pub mod orq;
pub mod parallel;
pub mod pool;
pub mod qsgd;
pub(crate) mod scratch;
pub mod signsgd;
pub mod terngrad;

use crate::tensor::rng::Rng;

/// One quantized bucket: sorted `levels` plus a per-element level index.
///
/// Invariants (checked by the property tests):
/// * `levels` is sorted ascending and non-empty for quantizing schemes;
/// * every index is `< levels.len()`;
/// * `indices.len() ==` input bucket length.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuantizedBucket {
    pub levels: Vec<f32>,
    pub indices: Vec<u8>,
}

impl QuantizedBucket {
    /// Reconstruct the dequantized values.
    pub fn dequantize(&self) -> Vec<f32> {
        self.indices.iter().map(|&i| self.levels[i as usize]).collect()
    }

    /// Dequantize into a preallocated slice (hot path).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.indices.len());
        for (o, &i) in out.iter_mut().zip(&self.indices) {
            *o = self.levels[i as usize];
        }
    }
}

/// A gradient quantization scheme operating bucket-by-bucket.
pub trait Quantizer: Send + Sync {
    /// Scheme name as used in configs/CLI (e.g. `"orq"`).
    fn name(&self) -> String;

    /// Number of quantization levels s (0 means full precision).
    fn num_levels(&self) -> usize;

    /// Bits per element on the wire (`ceil(log2(s))`, 32 for FP).
    fn bits_per_element(&self) -> u32 {
        let s = self.num_levels();
        if s == 0 {
            32
        } else {
            (usize::BITS - (s - 1).leading_zeros()).max(1)
        }
    }

    /// Whether `E[Q(v)] = v` holds for in-range v (paper Assumption 1).
    fn is_unbiased(&self) -> bool;

    /// Quantize one bucket into a caller-owned output, reusing its level
    /// and index buffers (the exchange hot path — no per-bucket
    /// allocation once `out` has capacity). `rng` drives random rounding.
    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket);

    /// Quantize one bucket. Allocating convenience wrapper around
    /// [`Quantizer::quantize_bucket_into`].
    fn quantize_bucket(&self, g: &[f32], rng: &mut Rng) -> QuantizedBucket {
        let mut out = QuantizedBucket::default();
        self.quantize_bucket_into(g, rng, &mut out);
        out
    }
}

/// NaN-free view of one gradient value: NaN maps to 0.0 (a corrupted
/// element contributes its unbiased-zero surrogate instead of poisoning
/// level bracketing), ±∞ survive and clamp to the end levels below.
#[inline]
fn sanitize(v: f32) -> f32 {
    if v.is_nan() {
        0.0
    } else {
        v
    }
}

/// Lane-block width of the two-pass [`random_round`] kernel: small
/// enough that the bracket/probability buffers live in L1, wide enough
/// for the compiler to unroll pass 1 into straight-line SIMD.
const ROUND_LANES: usize = 64;

/// Random rounding against sorted levels — Eq. (7) of the paper, the exact
/// mirror of the Pallas kernel in `python/compile/kernels/quantize.py`
/// (and of `ref.stochastic_quantize_ref`): bracket by counting levels ≤ v,
/// round up with probability (v − b_lo)/(b_hi − b_lo), clamp outside.
///
/// For the paper's level counts (s ≤ 16) the loop runs as a *two-pass
/// lane-block kernel*: pass 1 brackets [`ROUND_LANES`] elements at a time
/// and stores `(lower, p)` into fixed stack buffers — no RNG calls, no
/// `Vec` growth, no data-dependent branches inside the block, so the
/// bracketing arithmetic autovectorizes — and pass 2 draws one `rng.f32()`
/// per element *in element order* and applies the branchless select. The
/// probability is computed with the identical float operations and the
/// RNG is consumed in the identical sequence as the retained scalar
/// kernel, so indices are bit-identical to [`random_round_reference`]
/// (differential-tested) and the wire format is unchanged.
///
/// Non-finite input never panics: NaN is treated as 0.0, ±∞ clamp into
/// the extreme brackets (regression-tested; the old binary-search path
/// panicked on NaN via `partial_cmp().unwrap()`).
pub fn random_round(g: &[f32], levels: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
    debug_assert!(levels.len() >= 2);
    debug_assert!(levels.windows(2).all(|w| w[0] <= w[1]));
    out.clear();
    out.reserve(g.len());
    let s = levels.len();
    if s > 16 {
        // Large level tables binary-search; the lane-block restructure
        // buys nothing once bracketing is log-time.
        random_round_search(g, levels, rng, out);
        return;
    }
    let mut lo_buf = [0u8; ROUND_LANES];
    let mut p_buf = [0.0f32; ROUND_LANES];
    for chunk in g.chunks(ROUND_LANES) {
        // Pass 1: bracket + round-up probability, RNG-free. Writing to
        // fixed-width stack buffers (not `out`) keeps the loop free of
        // bounds checks and reallocation, so it vectorizes.
        for (j, &v) in chunk.iter().enumerate() {
            let v = sanitize(v);
            let mut lower = 0usize;
            for &b in &levels[1..] {
                lower += (v >= b) as usize;
            }
            let lower = lower.min(s - 2);
            let b_lo = levels[lower];
            let b_hi = levels[lower + 1];
            let width = b_hi - b_lo;
            let p = if width > 0.0 { ((v - b_lo) / width).clamp(0.0, 1.0) } else { 0.0 };
            lo_buf[j] = lower as u8;
            p_buf[j] = p;
        }
        // Pass 2: one RNG draw per element in element order — the draw
        // sequence is the wire contract — and a branchless select.
        for j in 0..chunk.len() {
            let up = (rng.f32() < p_buf[j]) as u8;
            out.push(lo_buf[j] + up);
        }
    }
}

/// The retained scalar [`random_round`] kernel — one fused
/// bracket+draw+push loop per element, exactly the pre-restructure hot
/// path. Kept as the reference for the rounding differential suite (the
/// codec-kernel convention: every restructured kernel keeps its scalar
/// baseline in-tree) and measured against the two-pass kernel in
/// `BENCH_codec.json`.
pub fn random_round_reference(g: &[f32], levels: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
    debug_assert!(levels.len() >= 2);
    debug_assert!(levels.windows(2).all(|w| w[0] <= w[1]));
    out.clear();
    out.reserve(g.len());
    let s = levels.len();
    if s > 16 {
        random_round_search(g, levels, rng, out);
        return;
    }
    for &v in g {
        let v = sanitize(v);
        let mut lower = 0usize;
        for &b in &levels[1..] {
            lower += (v >= b) as usize;
        }
        lower = lower.min(s - 2);
        let b_lo = levels[lower];
        let b_hi = levels[lower + 1];
        let width = b_hi - b_lo;
        let p = if width > 0.0 { ((v - b_lo) / width).clamp(0.0, 1.0) } else { 0.0 };
        let up = (rng.f32() < p) as usize;
        out.push((lower + up) as u8);
    }
}

/// Binary-search bracketing for large level tables (s > 16) — shared by
/// the two-pass kernel and the scalar reference, so the differential
/// suite covers one implementation, not two copies.
fn random_round_search(g: &[f32], levels: &[f32], rng: &mut Rng, out: &mut Vec<u8>) {
    let s = levels.len();
    for &v in g {
        let v = sanitize(v);
        // lower bracket index in [0, s-2]; partition_point never panics on
        // non-total orders (v is finite here, levels are finite by the
        // scheme invariant) and matches the counting loop above exactly.
        let lower = levels.partition_point(|&b| b <= v).saturating_sub(1).min(s - 2);
        let b_lo = levels[lower];
        let b_hi = levels[lower + 1];
        let width = b_hi - b_lo;
        let p = if width > 0.0 {
            ((v - b_lo) / width).clamp(0.0, 1.0)
        } else {
            0.0
        };
        let up = (rng.f32() < p) as usize;
        out.push((lower + up) as u8);
    }
}

/// Deterministic nearest-level rounding (used by tests and BinGrad-b's
/// threshold special case is equivalent for s=2). Same non-finite policy
/// as [`random_round`]: NaN → 0.0, ±∞ clamp to the end levels.
pub fn nearest_round(g: &[f32], levels: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(g.len());
    let s = levels.len();
    for &v in g {
        // Clamp into the level span so the distance comparison below never
        // sees an ∞ − ∞ tie (which would mis-pick the lower level).
        let v = sanitize(v).clamp(levels[0], levels[s - 1]);
        let lower = levels.partition_point(|&b| b <= v).saturating_sub(1).min(s - 2);
        let idx = if (v - levels[lower]).abs() <= (levels[lower + 1] - v).abs() {
            lower
        } else {
            lower + 1
        };
        out.push(idx as u8);
    }
}

/// Build a quantizer from its config name: `fp`, `signsgd`, `bingrad-pb`,
/// `bingrad-b`, `terngrad`, `qsgd-5`, `linear-9`, `orq-3`, ...
pub fn from_name(name: &str) -> crate::Result<Box<dyn Quantizer>> {
    let err = || crate::Error::InvalidArg(format!("unknown quantizer {name:?}"));
    let parse_s = |suffix: &str| -> crate::Result<usize> {
        let s: usize = suffix.parse().map_err(|_| err())?;
        if s < 2 || s > 255 {
            return Err(crate::Error::InvalidArg(format!(
                "level count must be in [2, 255], got {s}"
            )));
        }
        Ok(s)
    };
    Ok(match name {
        "fp" => Box::new(fp::FpQuantizer),
        "signsgd" => Box::new(signsgd::SignSgdQuantizer),
        "bingrad-pb" => Box::new(bingrad::BinGradPb::new()),
        "bingrad-b" => Box::new(bingrad::BinGradB::new()),
        "terngrad" => Box::new(terngrad::TernGradQuantizer),
        _ if name.starts_with("qsgd-") => {
            Box::new(qsgd::QsgdQuantizer::new(parse_s(&name[5..])?))
        }
        _ if name.starts_with("linear-") => {
            Box::new(linear::LinearQuantizer::new(parse_s(&name[7..])?))
        }
        _ if name.starts_with("orq-") => {
            Box::new(orq::OrqQuantizer::new(parse_s(&name[4..])?))
        }
        _ => return Err(err()),
    })
}

/// All method names used across the paper's tables, in table order.
pub fn paper_methods() -> Vec<&'static str> {
    vec![
        "fp", "bingrad-pb", "bingrad-b", "signsgd", "terngrad", "orq-3",
        "qsgd-5", "orq-5", "linear-5", "qsgd-9", "orq-9", "linear-9",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_element() {
        assert_eq!(from_name("terngrad").unwrap().bits_per_element(), 2);
        assert_eq!(from_name("qsgd-5").unwrap().bits_per_element(), 3);
        assert_eq!(from_name("orq-9").unwrap().bits_per_element(), 4);
        assert_eq!(from_name("bingrad-b").unwrap().bits_per_element(), 1);
        assert_eq!(from_name("fp").unwrap().bits_per_element(), 32);
        assert_eq!(from_name("signsgd").unwrap().bits_per_element(), 1);
    }

    #[test]
    fn from_name_roundtrip() {
        for n in paper_methods() {
            let q = from_name(n).unwrap();
            assert_eq!(q.name(), n, "name roundtrip for {n}");
        }
    }

    #[test]
    fn from_name_rejects_garbage() {
        assert!(from_name("nope").is_err());
        assert!(from_name("orq-").is_err());
        assert!(from_name("orq-1").is_err());
        assert!(from_name("qsgd-999").is_err());
        assert!(from_name("").is_err());
    }

    #[test]
    fn random_round_on_grid() {
        let levels = [-1.0f32, 0.0, 1.0];
        let g = [-1.0f32, 0.0, 1.0];
        let mut rng = Rng::seed_from(0);
        let mut out = Vec::new();
        random_round(&g, &levels, &mut rng, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn random_round_clamps() {
        let levels = [-1.0f32, 1.0];
        let g = [-100.0f32, 100.0];
        let mut rng = Rng::seed_from(0);
        let mut out = Vec::new();
        for _ in 0..10 {
            random_round(&g, &levels, &mut rng, &mut out);
            assert_eq!(out, vec![0, 1]);
        }
    }

    #[test]
    fn random_round_probability() {
        // v = 0.25 on levels {0, 1}: P(round up) = 0.25.
        let levels = [0.0f32, 1.0];
        let g = vec![0.25f32; 40_000];
        let mut rng = Rng::seed_from(42);
        let mut out = Vec::new();
        random_round(&g, &levels, &mut rng, &mut out);
        let ups = out.iter().filter(|&&i| i == 1).count() as f64 / g.len() as f64;
        assert!((ups - 0.25).abs() < 0.01, "P(up)={ups}");
    }

    /// Regression: NaN gradients must not panic (the old binary-search
    /// bracketing died in `partial_cmp().unwrap()`); they behave as 0.0,
    /// and ±∞ clamp to the end levels — on BOTH bracketing paths.
    #[test]
    fn random_round_survives_non_finite() {
        let mut rng = Rng::seed_from(1);
        let mut out = Vec::new();
        // s=3 exercises the branch-free path; NaN→0.0 lands exactly on
        // the middle level, deterministically.
        let levels3 = [-1.0f32, 0.0, 1.0];
        let g = [f32::NAN, f32::NEG_INFINITY, f32::INFINITY];
        for _ in 0..20 {
            random_round(&g, &levels3, &mut rng, &mut out);
            assert_eq!(out, vec![1, 0, 2]);
        }
        // s=17 exercises the search path (the one that used to panic).
        let levels17: Vec<f32> = (0..17).map(|i| i as f32 - 8.0).collect();
        for _ in 0..20 {
            random_round(&g, &levels17, &mut rng, &mut out);
            assert_eq!(out, vec![8, 0, 16]);
        }
    }

    #[test]
    fn nearest_round_survives_non_finite() {
        let levels = [-1.0f32, 0.0, 1.0];
        let mut out = Vec::new();
        nearest_round(&[f32::NAN, f32::NEG_INFINITY, f32::INFINITY], &levels, &mut out);
        assert_eq!(out, vec![1, 0, 2]);
        // 17 levels: the former binary-search path
        let levels17: Vec<f32> = (0..17).map(|i| i as f32 - 8.0).collect();
        nearest_round(&[f32::NAN, f32::NEG_INFINITY, f32::INFINITY], &levels17, &mut out);
        assert_eq!(out, vec![8, 0, 16]);
    }

    #[test]
    fn nearest_round_ties_and_halves() {
        let levels = [0.0f32, 1.0];
        let mut out = Vec::new();
        nearest_round(&[0.4, 0.6, 0.5, -3.0, 3.0], &levels, &mut out);
        assert_eq!(out, vec![0, 1, 0, 0, 1]);
    }

    #[test]
    fn dequantize_roundtrip() {
        let qb = QuantizedBucket { levels: vec![-1.0, 0.0, 2.0], indices: vec![2, 0, 1, 1] };
        assert_eq!(qb.dequantize(), vec![2.0, -1.0, 0.0, 0.0]);
        let mut buf = vec![0.0; 4];
        qb.dequantize_into(&mut buf);
        assert_eq!(buf, vec![2.0, -1.0, 0.0, 0.0]);
    }

    /// The two-pass lane-block kernel must be bit-identical to the
    /// retained scalar reference: same indices from the same seed for
    /// every level count, every length (incl. non-multiples of the lane
    /// width and lengths below one block), and non-finite inputs — and
    /// the RNG must end in the same state (draw-sequence contract).
    #[test]
    fn two_pass_round_bit_identical_to_scalar_reference() {
        let mut data_rng = Rng::seed_from(17);
        for s in [2usize, 3, 5, 9, 16, 17, 33] {
            let levels: Vec<f32> =
                (0..s).map(|i| i as f32 / (s - 1) as f32 * 2.0 - 1.0).collect();
            for n in [0usize, 1, 63, 64, 65, 200, 1024] {
                let mut g: Vec<f32> = (0..n).map(|_| data_rng.gaussian_f32()).collect();
                if n > 4 {
                    g[0] = f32::NAN;
                    g[1] = f32::INFINITY;
                    g[2] = f32::NEG_INFINITY;
                    g[3] = levels[0]; // exactly on a level: width-0 guard
                }
                let seed = 90 + (s * 1000 + n) as u64;
                let mut rng_a = Rng::seed_from(seed);
                let mut rng_b = Rng::seed_from(seed);
                let mut a = Vec::new();
                let mut b = Vec::new();
                random_round(&g, &levels, &mut rng_a, &mut a);
                random_round_reference(&g, &levels, &mut rng_b, &mut b);
                assert_eq!(a, b, "s={s} n={n}");
                // same number of draws consumed → identical next output
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "s={s} n={n}");
            }
        }
    }

    /// The differential holds through every scheme's solved level tables
    /// too (degenerate tables with repeated levels included).
    #[test]
    fn two_pass_round_matches_reference_through_schemes() {
        let mut data_rng = Rng::seed_from(23);
        let g: Vec<f32> = (0..777).map(|_| data_rng.gaussian_f32()).collect();
        for name in paper_methods() {
            if name == "fp" {
                continue;
            }
            let q = from_name(name).unwrap();
            let qb = q.quantize_bucket(&g, &mut Rng::seed_from(5));
            if qb.levels.len() < 2 {
                continue; // deterministic schemes may bypass random_round
            }
            let mut a = Vec::new();
            let mut b = Vec::new();
            random_round(&g, &qb.levels, &mut Rng::seed_from(6), &mut a);
            random_round_reference(&g, &qb.levels, &mut Rng::seed_from(6), &mut b);
            assert_eq!(a, b, "{name}");
        }
    }

    /// `quantize_bucket_into` must reuse the output's buffers and agree
    /// with the allocating wrapper for every scheme.
    #[test]
    fn quantize_into_matches_wrapper() {
        let mut rng = Rng::seed_from(3);
        let g: Vec<f32> = (0..512).map(|_| rng.gaussian_f32()).collect();
        for name in paper_methods() {
            if name == "fp" {
                continue;
            }
            let q = from_name(name).unwrap();
            let fresh = q.quantize_bucket(&g, &mut Rng::seed_from(7));
            let mut reused = QuantizedBucket {
                levels: vec![99.0; 32], // stale garbage must be overwritten
                indices: vec![255; 700],
            };
            q.quantize_bucket_into(&g, &mut Rng::seed_from(7), &mut reused);
            assert_eq!(fresh, reused, "{name}");
        }
    }
}
