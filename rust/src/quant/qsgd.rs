//! QSGD-s (Alistarh et al., NeurIPS 2017) as evaluated in the paper:
//! s evenly spaced levels spanning [−max|v|, max|v|], random rounding.
//!
//! (The original QSGD normalizes by the bucket ℓ₂ norm and ships
//! sign+magnitude; the paper's figures place both baselines on the same
//! "evenly spaced levels" footing — "in both QSGD and TernGrad, {b_k} are
//! evenly spaced" (§3.1) — which is what we implement. QSGD-3 ≈ TernGrad.)

use super::{random_round, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;
use crate::tensor::stats::SliceStats;

pub struct QsgdQuantizer {
    s: usize,
}

impl QsgdQuantizer {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2, "QSGD needs at least 2 levels");
        QsgdQuantizer { s }
    }

    /// The evenly spaced level grid for a given max-abs. The fraction is
    /// computed first so the grid stays finite up to m = f32::MAX/2
    /// (found by the adversarial-bucket test).
    pub fn grid(s: usize, m: f32) -> Vec<f32> {
        let mut out = Vec::new();
        Self::grid_into(s, m, &mut out);
        out
    }

    /// [`QsgdQuantizer::grid`] into a reused buffer (cleared first).
    pub fn grid_into(s: usize, m: f32, out: &mut Vec<f32>) {
        let m = if m > 0.0 { m } else { 1.0 };
        out.clear();
        out.extend((0..s).map(|k| -m + 2.0 * m * (k as f32 / (s - 1) as f32)));
    }
}

impl Quantizer for QsgdQuantizer {
    fn name(&self) -> String {
        format!("qsgd-{}", self.s)
    }

    fn num_levels(&self) -> usize {
        self.s
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket) {
        let m = SliceStats::compute(g).max_abs();
        Self::grid_into(self.s, m, &mut out.levels);
        random_round(g, &out.levels, rng, &mut out.indices);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::mse;

    #[test]
    fn grid_even_and_symmetric() {
        let lv = QsgdQuantizer::grid(5, 2.0);
        assert_eq!(lv, vec![-2.0, -1.0, 0.0, 1.0, 2.0]);
        let lv9 = QsgdQuantizer::grid(9, 1.0);
        assert_eq!(lv9.len(), 9);
        for (a, b) in lv9.iter().zip(lv9.iter().rev()) {
            assert!((a + b).abs() < 1e-6, "grid must be symmetric");
        }
    }

    #[test]
    fn qsgd3_matches_terngrad_levels() {
        let g = [0.4f32, -1.5, 0.9];
        let q = QsgdQuantizer::new(3).quantize_bucket(&g, &mut Rng::seed_from(0));
        assert_eq!(q.levels, vec![-1.5, 0.0, 1.5]);
    }

    #[test]
    fn finer_grid_lower_error() {
        let mut rng = Rng::seed_from(7);
        let g: Vec<f32> = (0..4096).map(|_| rng.gaussian_f32()).collect();
        let e3 = mse(
            &g,
            &QsgdQuantizer::new(3).quantize_bucket(&g, &mut Rng::seed_from(1)).dequantize(),
        );
        let e9 = mse(
            &g,
            &QsgdQuantizer::new(9).quantize_bucket(&g, &mut Rng::seed_from(1)).dequantize(),
        );
        let e17 = mse(
            &g,
            &QsgdQuantizer::new(17).quantize_bucket(&g, &mut Rng::seed_from(1)).dequantize(),
        );
        assert!(e9 < e3, "e9={e9} e3={e3}");
        assert!(e17 < e9, "e17={e17} e9={e9}");
    }

    #[test]
    fn unbiased_in_expectation() {
        // Average many independent quantizations of the same bucket.
        let mut rng = Rng::seed_from(8);
        let g: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        let q = QsgdQuantizer::new(5);
        let n = 2000;
        let mut acc = vec![0.0f64; g.len()];
        for t in 0..n {
            let qb = q.quantize_bucket(&g, &mut Rng::seed_from(1000 + t));
            for (a, v) in acc.iter_mut().zip(qb.dequantize()) {
                *a += v as f64;
            }
        }
        let max_width = {
            let lv = &q.quantize_bucket(&g, &mut Rng::seed_from(0)).levels;
            lv.windows(2).map(|w| (w[1] - w[0]) as f64).fold(0.0, f64::max)
        };
        for (a, v) in acc.iter().zip(&g) {
            let mean = a / n as f64;
            let tol = 4.0 * max_width / (n as f64).sqrt() + 1e-4;
            assert!((mean - *v as f64).abs() < tol, "E[Q(v)]={mean} v={v}");
        }
    }

    #[test]
    fn constant_bucket() {
        let g = vec![0.7f32; 128];
        let q = QsgdQuantizer::new(5).quantize_bucket(&g, &mut Rng::seed_from(9));
        // max == 0.7 -> top level is exactly 0.7
        assert!(q.dequantize().iter().all(|&v| v == 0.7));
    }
}
