//! Adaptive per-round bit allocation under a byte budget.
//!
//! The paper's level solvers recompute optimal *levels* per bucket each
//! round, but the *width* (level count s) is static for the whole run.
//! DQ-SGD and ALQ/AMQ (PAPERS.md) show the rate itself should be
//! dynamic: given per-bucket second-moment statistics, choose each
//! bucket's width to minimize total quantization variance subject to a
//! per-round uplink byte budget.
//!
//! For an s-level quantizer over a bucket with second moment
//! `E = Σ v²`, the rounding variance scales like `E / (s − 1)²` (the
//! uniform-grid bound of paper Eq. (7); exact constants differ per
//! scheme but the *ratio* between widths is what drives allocation).
//! [`allocate_widths`] therefore runs a greedy water-filling ascent:
//! start every bucket at the 2-level floor, repeatedly upgrade the
//! bucket with the best variance-reduction-per-byte, stop when the
//! budget is spent. Ties break by `f64::total_cmp` on the gain and then
//! by *lower bucket index first* — fully deterministic, so every node
//! (and every thread count) derives the identical table from identical
//! statistics.
//!
//! The byte costs come straight from the codec's cost model
//! ([`codec::per_bucket_bytes`], [`codec::wire_size_widths`]), with the
//! message header and the in-band width table itself counted — the
//! budget is respected *exactly*, headers included. The chosen widths
//! travel in-band as the codec's width table
//! ([`codec::encode_quantized_header_widths_into`]), so downstream
//! decoders and re-encoding hops read them from the frame instead of
//! re-deriving them.
//!
//! [`scheduled_budget`] implements the optional `coarse-to-fine`
//! schedule: rounds start at half the configured budget and ramp
//! linearly to the full budget by round [`COARSE_TO_FINE_RAMP`] (coarse
//! early when gradients are large and noisy, fine late — the DQ-SGD
//! trajectory).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::codec::{self, Packing};
use crate::error::{Error, Result};

/// Minimum per-bucket width: 2 levels (1 bit + table) is the coarsest
/// representable quantized bucket.
pub const MIN_WIDTH: usize = 2;

/// Rounds over which the `coarse-to-fine` schedule ramps from half to
/// the full budget.
pub const COARSE_TO_FINE_RAMP: u64 = 64;

/// Time-varying budget schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetSchedule {
    /// Half the budget at round 0, linear ramp to the full budget by
    /// round [`COARSE_TO_FINE_RAMP`], constant after.
    CoarseToFine,
}

impl BudgetSchedule {
    pub fn parse(name: &str) -> Result<BudgetSchedule> {
        match name {
            "coarse-to-fine" => Ok(BudgetSchedule::CoarseToFine),
            _ => Err(Error::Config(format!(
                "unknown budget schedule {name:?} (supported: coarse-to-fine)"
            ))),
        }
    }
}

/// The budget in effect at `round` under an optional schedule. Never
/// exceeds `budget`, so scheduled rounds still respect the configured
/// ceiling.
pub fn scheduled_budget(budget: usize, schedule: Option<BudgetSchedule>, round: u64) -> usize {
    match schedule {
        None => budget,
        Some(BudgetSchedule::CoarseToFine) => {
            let half = budget / 2;
            let t = round.min(COARSE_TO_FINE_RAMP);
            half + ((budget - half) as u64 * t / COARSE_TO_FINE_RAMP) as usize
        }
    }
}

/// The parameterizable scheme family of `method` — `orq-S`, `qsgd-S` or
/// `linear-S` → `Some((family, s))`, anything else (fixed-level schemes,
/// `fp`) → `None`. Only these families can vary their per-bucket level
/// count, so only they support a byte budget or width-table re-encodes.
pub fn parse_family(method: &str) -> Option<(&str, usize)> {
    let (family, s) = method.rsplit_once('-')?;
    if !matches!(family, "orq" | "qsgd" | "linear") {
        return None;
    }
    s.parse::<usize>().ok().filter(|s| (2..=255).contains(s)).map(|s| (family, s))
}

/// Wire bytes of the *smallest* width message for a gradient of `total`
/// elements: every bucket at the 2-level floor, header and width table
/// included. Budgets below this are unsatisfiable — config validation
/// rejects them with this figure in the message.
pub fn min_message_bytes(total: usize, bucket: usize, packing: Packing, scheme: &str) -> usize {
    let widths = vec![MIN_WIDTH as u8; total.div_ceil(bucket.max(1))];
    codec::wire_size_widths(total, bucket, &widths, packing, scheme)
}

/// One pending upgrade in the greedy ascent: bucket `idx` from width `w`
/// to `w + 1`, buying `gain` variance reduction per byte. Max-heap
/// ordered by gain, ties to the lower bucket index — deterministic.
struct Upgrade {
    gain: f64,
    idx: usize,
    w: usize,
    delta: usize,
}

impl Ord for Upgrade {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain.total_cmp(&other.gain).then_with(|| other.idx.cmp(&self.idx))
    }
}
impl PartialOrd for Upgrade {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl PartialEq for Upgrade {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Upgrade {}

/// Variance model `E / (s − 1)²` for a bucket with second moment `e`.
fn var_at(e: f64, s: usize) -> f64 {
    e / (((s - 1) * (s - 1)) as f64)
}

/// Choose per-bucket widths for a gradient of `total` elements in
/// buckets of `bucket`, minimizing Σ statsᵢ/(sᵢ−1)² subject to
/// `wire_size_widths(..) ≤ budget_bytes` with widths in
/// `[`[`MIN_WIDTH`]`, s_max]`.
///
/// `stats[i]` is bucket i's second moment (Σ v² over its elements) —
/// any deterministic, node-identical statistic works; the trainer feeds
/// the previous round's *decoded mean* so every node derives the same
/// table with zero extra coordination (round 0 uses uniform statistics).
///
/// Greedy water-filling: all buckets start at the [`MIN_WIDTH`] floor;
/// each step upgrades the affordable bucket with the highest variance
/// reduction per byte (ties → lower index). Unaffordable upgrades are
/// skipped, not terminal: a cheaper upgrade elsewhere may still fit.
/// If even the floor exceeds the budget the floor table is returned —
/// callers validate against [`min_message_bytes`] up front.
pub fn allocate_widths(
    stats: &[f64],
    total: usize,
    bucket: usize,
    s_max: usize,
    budget_bytes: usize,
    packing: Packing,
    scheme: &str,
) -> Vec<u8> {
    let nb = total.div_ceil(bucket.max(1));
    debug_assert_eq!(stats.len(), nb, "one statistic per bucket");
    debug_assert!((MIN_WIDTH..=255).contains(&s_max));
    let mut widths = vec![MIN_WIDTH as u8; nb];
    if nb == 0 || s_max == MIN_WIDTH {
        return widths;
    }
    let blen =
        |bi: usize| if bi + 1 == nb { codec_tail_len(total, bucket) } else { bucket };
    let mut spent = min_message_bytes(total, bucket, packing, scheme);
    let upgrade = |idx: usize, w: usize| -> Upgrade {
        let e = stats.get(idx).copied().unwrap_or(0.0).max(0.0);
        let delta = codec::per_bucket_bytes(blen(idx), w + 1, packing)
            - codec::per_bucket_bytes(blen(idx), w, packing);
        // Δbytes ≥ 4 (one more f32 level) so the division is safe.
        Upgrade { gain: (var_at(e, w) - var_at(e, w + 1)) / delta as f64, idx, w, delta }
    };
    let mut heap: BinaryHeap<Upgrade> = (0..nb).map(|i| upgrade(i, MIN_WIDTH)).collect();
    while let Some(u) = heap.pop() {
        if spent + u.delta <= budget_bytes {
            spent += u.delta;
            widths[u.idx] = (u.w + 1) as u8;
            if u.w + 1 < s_max {
                heap.push(upgrade(u.idx, u.w + 1));
            }
        }
        // else: skip — later (cheaper) candidates may still fit.
    }
    debug_assert_eq!(spent, codec::wire_size_widths(total, bucket, &widths, packing, scheme));
    widths
}

/// Length of the final (possibly ragged) bucket — mirrors the codec's
/// tail rule so the byte accounting agrees bucket for bucket.
fn codec_tail_len(total: usize, bucket: usize) -> usize {
    if total % bucket == 0 {
        bucket
    } else {
        total % bucket
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values on hand-computed statistics (Fixed packing so the
    /// byte deltas are easy to verify by hand).
    ///
    /// 3 buckets of 4 elements, scheme "orq-4" (5-byte name, header 25).
    /// per_bucket_bytes(4, s, Fixed) = 4s + ceil(4·bits(s)/8):
    ///   s=2 → 9, s=3 → 13, s=4 → 17  (Δ = 4 each step).
    /// Base cost = header 25 + table 3 + 3×9 = 55.
    /// stats = [9, 1, 0]; gain(w→w+1) = stats·(1/(w−1)² − 1/w²)/Δ:
    ///   2→3: stats·0.75/4;  3→4: stats·(1/4 − 1/9)/4.
    /// Upgrade order: b0→3 (1.6875), b0→4 (0.3125), b1→3 (0.1875),
    /// b1→4, b2→3, b2→4 (zero-gain ties, lower index first).
    #[test]
    fn golden_allocation_hand_computed() {
        let stats = [9.0, 1.0, 0.0];
        let p = Packing::Fixed;
        assert_eq!(min_message_bytes(12, 4, p, "orq-4"), 55);
        // exactly the floor: no upgrades fit
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 55, p, "orq-4"), vec![2, 2, 2]);
        // +4: one upgrade — the high-energy bucket
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 59, p, "orq-4"), vec![3, 2, 2]);
        // +8: b0 climbs to 4 before b1 leaves the floor
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 63, p, "orq-4"), vec![4, 2, 2]);
        // +12: then b1
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 67, p, "orq-4"), vec![4, 3, 2]);
        // unconstrained: everything at s_max
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 10_000, p, "orq-4"), vec![4, 4, 4]);
        // below the floor: floor returned (caller validates)
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 10, p, "orq-4"), vec![2, 2, 2]);
        // slack smaller than any Δ is left unspent
        assert_eq!(allocate_widths(&stats, 12, 4, 4, 58, p, "orq-4"), vec![2, 2, 2]);
    }

    /// Zero-gain ties (all-zero stats) must break toward lower bucket
    /// indices, and identical inputs must always produce identical
    /// tables — the determinism the cross-node contract rests on.
    #[test]
    fn deterministic_tie_breaking() {
        let stats = [0.0; 4];
        let p = Packing::Fixed;
        let floor = min_message_bytes(16, 4, p, "orq-4");
        // room for exactly two upgrades → buckets 0 and 1
        let w = allocate_widths(&stats, 16, 4, 4, floor + 8, p, "orq-4");
        assert_eq!(w, vec![3, 3, 2, 2]);
        for _ in 0..10 {
            assert_eq!(allocate_widths(&stats, 16, 4, 4, floor + 8, p, "orq-4"), w);
        }
        // NaN statistics must not poison the ordering (total_cmp sorts
        // them deterministically; max(0.0) floors them out)
        let w = allocate_widths(&[f64::NAN, 1.0, 0.0, 0.0], 16, 4, 4, floor + 8, p, "orq-4");
        assert_eq!(w.len(), 4);
        assert_eq!(w.iter().map(|&x| x as usize).sum::<usize>(), 2 * 4 + 2);
    }

    /// The allocator's spend equals the codec's closed-form size for the
    /// chosen table and never exceeds the budget, across packings,
    /// ragged tails, and budgets from the floor to beyond saturation.
    #[test]
    fn spend_never_exceeds_budget() {
        let stats: Vec<f64> = (0..9).map(|i| ((i * 37) % 11) as f64).collect();
        for packing in [Packing::Fixed, Packing::BaseS] {
            let floor = min_message_bytes(1100, 128, packing, "qsgd-8");
            let max = {
                let w = vec![8u8; 9];
                codec::wire_size_widths(1100, 128, &w, packing, "qsgd-8")
            };
            for budget in
                [floor, floor + 1, floor + 13, (floor + max) / 2, max - 1, max, max + 100]
            {
                let w = allocate_widths(&stats, 1100, 128, 8, budget, packing, "qsgd-8");
                let spend = codec::wire_size_widths(1100, 128, &w, packing, "qsgd-8");
                assert!(
                    spend <= budget,
                    "{packing:?} budget {budget}: spent {spend}"
                );
                assert!(w.iter().all(|&x| (2..=8).contains(&x)), "{packing:?}");
                if budget >= max {
                    assert_eq!(w, vec![8u8; 9], "{packing:?} saturates at s_max");
                }
            }
        }
    }

    /// More budget can only help: total modeled variance is
    /// non-increasing and spend non-decreasing in the budget — the
    /// monotonicity perfbench's Pareto section asserts end-to-end.
    #[test]
    fn variance_monotone_in_budget() {
        let stats: Vec<f64> = (0..16).map(|i| (1.0 + i as f64).powi(2)).collect();
        let p = Packing::BaseS;
        let total = 16 * 64;
        let var = |w: &[u8]| -> f64 {
            w.iter().zip(&stats).map(|(&s, &e)| var_at(e, s as usize)).sum()
        };
        let floor = min_message_bytes(total, 64, p, "orq-16");
        let mut last_var = f64::INFINITY;
        let mut last_spend = 0usize;
        for step in 0..12 {
            let budget = floor + step * 40;
            let w = allocate_widths(&stats, total, 64, 16, budget, p, "orq-16");
            let v = var(&w);
            let spend = codec::wire_size_widths(total, 64, &w, p, "orq-16");
            assert!(v <= last_var, "variance rose with budget at step {step}");
            assert!(spend >= last_spend, "spend shrank with budget at step {step}");
            last_var = v;
            last_spend = spend;
        }
    }

    #[test]
    fn schedule_ramps_half_to_full() {
        assert_eq!(scheduled_budget(1000, None, 0), 1000);
        let s = Some(BudgetSchedule::CoarseToFine);
        assert_eq!(scheduled_budget(1000, s, 0), 500);
        assert_eq!(scheduled_budget(1000, s, COARSE_TO_FINE_RAMP / 2), 750);
        assert_eq!(scheduled_budget(1000, s, COARSE_TO_FINE_RAMP), 1000);
        assert_eq!(scheduled_budget(1000, s, COARSE_TO_FINE_RAMP * 10), 1000);
        for t in 0..200 {
            assert!(scheduled_budget(777, s, t) <= 777, "never exceeds the ceiling");
        }
        assert!(BudgetSchedule::parse("coarse-to-fine").is_ok());
        assert!(BudgetSchedule::parse("fine-to-coarse").is_err());
    }
}
