//! Error feedback (EF) — the compensation technique of the paper's
//! related-work §2 ([24] DGC, [34] ECQ-SGD, [17] EF-SignSGD): each worker
//! keeps a residual memory `m`, quantizes `g + m` instead of `g`, and
//! stores back the quantization error:
//!
//! ```text
//! q_t = Q(g_t + m_t);   m_{t+1} = (g_t + m_t) − q_t
//! ```
//!
//! The paper deliberately excludes EF from its experiments ("without the
//! interference of other compensational methods", §2) but names it as a
//! composable reinforcement — so it ships here as an opt-in wrapper any
//! [`Quantizer`] can be lifted into, with an ablation showing it rescues
//! the *biased* schemes (SignSGD/BinGrad-b) most, exactly as [17] proves.
//!
//! One instance compensates one *requantization site*, not one worker:
//! besides the worker uplink, the collectives keep an `ErrorFeedback`
//! per ring hop position, per hierarchy edge, and (under
//! `quantize_downlink`) on the server's mean broadcast — each site sees
//! its own signal stream, so each needs its own residual. The memory
//! resets whenever the signal length changes, which is also why a site's
//! instance must only ever see one stable length.

use super::bucket::{BucketQuantizer, QuantizedGrad};
use super::Quantizer;
use crate::tensor::rng::Rng;

/// Per-worker error-feedback state wrapping a bucketed quantizer.
pub struct ErrorFeedback {
    bucketq: BucketQuantizer,
    /// Residual memory, lazily sized to the first gradient.
    memory: Vec<f32>,
    /// Scratch for g + m.
    compensated: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(bucketq: BucketQuantizer) -> Self {
        ErrorFeedback { bucketq, memory: Vec::new(), compensated: Vec::new() }
    }

    /// Residual ℓ₂ norm (diagnostic; bounded for contractive quantizers).
    pub fn memory_norm(&self) -> f32 {
        crate::tensor::norm2(&self.memory)
    }

    /// Quantize `g + memory`, update memory with the new residual.
    pub fn quantize(&mut self, g: &[f32], q: &dyn Quantizer, rng: &mut Rng) -> QuantizedGrad {
        let mut qg = QuantizedGrad::default();
        self.quantize_into(g, q, rng, &mut qg);
        qg
    }

    /// Fill and return the compensated signal `g + m`, lazily sizing the
    /// residual memory to `g`. The split entry point of the parallel
    /// pipeline's EF path ([`crate::quant::parallel::BucketPipeline::
    /// encode_ef_into`]): compensate → quantize+encode (sharded) →
    /// [`Self::update_residual`] with the dequantized wire values.
    pub(crate) fn compensate(&mut self, g: &[f32]) -> &[f32] {
        if self.memory.len() != g.len() {
            self.memory = vec![0.0; g.len()];
        }
        self.compensated.clear();
        self.compensated.extend(g.iter().zip(&self.memory).map(|(a, b)| a + b));
        &self.compensated
    }

    /// The residual memory, lazily sized to `n`. The overlap driver
    /// ([`crate::comm::overlap::OverlapEncoder`]) stages per-section
    /// compensation `g[sec] + m[sec]` itself — it never holds the whole
    /// gradient mid-backward — then settles the round through
    /// [`Self::compensate`] + [`Self::update_residual`] once backward
    /// and the decode of its own message are done.
    pub(crate) fn residual(&mut self, n: usize) -> &[f32] {
        if self.memory.len() != n {
            self.memory = vec![0.0; n];
        }
        &self.memory
    }

    /// Absorb the residual after the caller quantized the compensated
    /// signal from [`Self::compensate`]: `m ← (g + m) − deq`, where
    /// `deq` is the dequantized transmitted signal (for wire codecs,
    /// decoding one's own message — exact dequantization).
    pub(crate) fn update_residual(&mut self, deq: &[f32]) {
        debug_assert_eq!(deq.len(), self.compensated.len());
        for ((m, c), d) in self.memory.iter_mut().zip(&self.compensated).zip(deq) {
            *m = c - d;
        }
    }

    /// Like [`Self::quantize`] but into a reused [`QuantizedGrad`] — the
    /// trainer's per-round hot path (steady-state rounds allocate
    /// nothing beyond the lazily-sized residual memory).
    pub fn quantize_into(
        &mut self,
        g: &[f32],
        q: &dyn Quantizer,
        rng: &mut Rng,
        out: &mut QuantizedGrad,
    ) {
        self.compensate(g);
        self.bucketq.quantize_into(&self.compensated, q, rng, out);
        // m ← (g + m) − Q(g + m), computed bucket-wise without allocating
        // the full dequantized vector.
        for (bi, chunk) in self
            .memory
            .chunks_mut(self.bucketq.bucket_size)
            .enumerate()
        {
            let qb = &out.buckets[bi];
            let base = bi * self.bucketq.bucket_size;
            for (j, m) in chunk.iter_mut().enumerate() {
                *m = self.compensated[base + j] - qb.levels[qb.indices[j] as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::from_name;
    use crate::tensor::{dot, norm2};

    fn grad(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut g = vec![0.0; n];
        rng.fill_gaussian(&mut g, 1.0);
        g
    }

    #[test]
    fn memory_tracks_residual_exactly() {
        let q = from_name("signsgd").unwrap();
        let mut ef = ErrorFeedback::new(BucketQuantizer::new(64));
        let g = grad(1, 256);
        let mut rng = Rng::seed_from(2);
        let qg = ef.quantize(&g, q.as_ref(), &mut rng);
        let deq = qg.dequantize();
        // after the first step: m = g − Q(g)
        for i in 0..g.len() {
            let expect = g[i] - deq[i];
            assert!((ef.memory[i] - expect).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn residual_memory_stays_bounded() {
        // For a contractive compressor, ‖m‖ stays bounded across steps.
        let q = from_name("bingrad-b").unwrap();
        let mut ef = ErrorFeedback::new(BucketQuantizer::new(128));
        let mut rng = Rng::seed_from(3);
        let mut norms = Vec::new();
        for t in 0..50 {
            let g = grad(100 + t, 1024);
            ef.quantize(&g, q.as_ref(), &mut rng);
            norms.push(ef.memory_norm());
        }
        let tail_max = norms[25..].iter().cloned().fold(0.0f32, f32::max);
        let g_norm = norm2(&grad(0, 1024));
        assert!(tail_max < 3.0 * g_norm, "memory must not blow up: {tail_max}");
    }

    #[test]
    fn ef_recovers_direction_over_time() {
        // Feed the SAME gradient repeatedly through a coarse biased
        // quantizer: the cumulative transmitted sum with EF converges to
        // the true direction much better than without EF.
        let q = from_name("signsgd").unwrap();
        let g = grad(7, 512);
        let steps = 30;

        let mut ef = ErrorFeedback::new(BucketQuantizer::new(512));
        let mut rng = Rng::seed_from(8);
        let mut sum_ef = vec![0.0f32; g.len()];
        for _ in 0..steps {
            let qg = ef.quantize(&g, q.as_ref(), &mut rng);
            for (s, v) in sum_ef.iter_mut().zip(qg.dequantize()) {
                *s += v;
            }
        }
        let bq = BucketQuantizer::new(512);
        let mut sum_plain = vec![0.0f32; g.len()];
        for _ in 0..steps {
            let qg = bq.quantize(&g, q.as_ref(), &mut Rng::seed_from(9));
            for (s, v) in sum_plain.iter_mut().zip(qg.dequantize()) {
                *s += v;
            }
        }
        let cos = |a: &[f32]| dot(a, &g) as f64 / (norm2(a) as f64 * norm2(&g) as f64);
        let c_ef = cos(&sum_ef);
        let c_plain = cos(&sum_plain);
        assert!(
            c_ef > c_plain + 0.05,
            "EF should recover the direction: ef={c_ef:.4} plain={c_plain:.4}"
        );
        assert!(c_ef > 0.95, "cumulative EF signal should approach g: {c_ef:.4}");
    }

    #[test]
    fn ef_with_unbiased_quantizer_is_harmless() {
        let q = from_name("orq-9").unwrap();
        let mut ef = ErrorFeedback::new(BucketQuantizer::new(256));
        let g = grad(11, 1024);
        let mut rng = Rng::seed_from(12);
        let qg = ef.quantize(&g, q.as_ref(), &mut rng);
        let e = crate::quant::error::measure(&g, &qg);
        assert!(e.cosine > 0.9, "first EF step ≈ plain quantization");
    }

    /// Regression for the trainer wiring: across rounds, the EF memory
    /// drives the *cumulative transmitted mean* toward the true
    /// gradient — the error of the running mean decays monotonically
    /// between checkpoints (it cannot with the plain biased quantizer,
    /// whose running mean converges to the biased expectation instead).
    #[test]
    fn ef_memory_decays_quantization_error_across_rounds() {
        let q = from_name("bingrad-b").unwrap();
        let g = grad(21, 768);
        let mut ef = ErrorFeedback::new(BucketQuantizer::new(256));
        let mut rng = Rng::seed_from(22);
        let mut sum = vec![0.0f32; g.len()];
        let mut qg = crate::quant::bucket::QuantizedGrad::default();
        let err_at = |sum: &[f32], t: usize| {
            let mean: Vec<f32> = sum.iter().map(|s| s / t as f32).collect();
            let diff: Vec<f32> = mean.iter().zip(&g).map(|(a, b)| a - b).collect();
            norm2(&diff) / norm2(&g)
        };
        let mut checkpoints = Vec::new();
        for t in 1..=32 {
            ef.quantize_into(&g, q.as_ref(), &mut rng, &mut qg);
            for (s, v) in sum.iter_mut().zip(qg.dequantize()) {
                *s += v;
            }
            if t == 1 || t == 8 || t == 32 {
                checkpoints.push(err_at(&sum, t));
            }
        }
        assert!(
            checkpoints[1] < 0.6 * checkpoints[0],
            "relative error must decay: {checkpoints:?}"
        );
        assert!(
            checkpoints[2] < 0.6 * checkpoints[1],
            "…and keep decaying: {checkpoints:?}"
        );
        // the reused-buffer entry point matches the allocating one
        let mut ef2 = ErrorFeedback::new(BucketQuantizer::new(256));
        let fresh = ef2.quantize(&g, q.as_ref(), &mut Rng::seed_from(22));
        let mut ef3 = ErrorFeedback::new(BucketQuantizer::new(256));
        let mut reused = crate::quant::bucket::QuantizedGrad::default();
        ef3.quantize_into(&g, q.as_ref(), &mut Rng::seed_from(22), &mut reused);
        assert_eq!(fresh.dequantize(), reused.dequantize());
    }

    #[test]
    fn gradient_length_change_resets_memory() {
        let q = from_name("terngrad").unwrap();
        let mut ef = ErrorFeedback::new(BucketQuantizer::new(64));
        let mut rng = Rng::seed_from(13);
        ef.quantize(&grad(1, 128), q.as_ref(), &mut rng);
        assert_eq!(ef.memory.len(), 128);
        ef.quantize(&grad(2, 256), q.as_ref(), &mut rng);
        assert_eq!(ef.memory.len(), 256);
    }
}
