//! Parallel per-bucket pipeline: quantize→encode and decode→reduce
//! sharded across worker threads.
//!
//! Buckets are independent by construction (paper §5: each bucket solves
//! its own levels and rounds its own elements), so the two hot loops of
//! an exchange round parallelize along the bucket grid:
//!
//! * **quantize + encode** — [`BucketPipeline::encode_into`] writes the
//!   wire header, then splits the bucket range into contiguous shards;
//!   each shard task quantizes its buckets (per-bucket RNG streams,
//!   [`BucketQuantizer::quantize_bucket_stream`]) and serializes them
//!   into its own segment buffer; segments concatenate in bucket order,
//!   so the wire bytes are identical for every thread count (and to the
//!   serial [`BucketQuantizer::quantize_streams_into`] reference).
//! * **decode + reduce** — [`BucketPipeline::decode_flat_into`] and
//!   [`BucketPipeline::decode_reduce_into`] split the *output* buffer
//!   into disjoint bucket-aligned slices and decode each range straight
//!   out of the shared message bytes ([`codec::decode_slice_into`]).
//!   The reduce variant preserves the per-element upload accumulation
//!   order, so the f64 sums are bit-identical to the serial loop.
//!
//! Execution is **pooled by default**: shard tasks run on a persistent
//! [`WorkerPool`](super::pool::WorkerPool) (owned by this pipeline, or
//! shared via [`BucketPipeline::with_pool`]), so thread spawns and the
//! per-thread level-solver arenas (`quant::scratch`) are paid once per
//! run instead of once per round. [`BucketPipeline::scoped`] retains the
//! PR 3 `std::thread::scope` execution as the measurable baseline
//! (perfbench reports pooled vs scoped round times side by side). Both
//! modes produce bit-identical output — shard results depend only on
//! `(bytes, round_key, bucket index)`, never on which thread ran them —
//! and all shard state (segment buffers, one reusable
//! [`QuantizedBucket`], clip scratch, decode scratch) lives in arenas
//! owned by the pipeline and reused across rounds: the steady-state
//! parallel path performs no per-bucket allocation and takes no locks.
//!
//! Error feedback composes with the pipeline through
//! [`BucketPipeline::encode_ef_into`]: the compensated signal `g + m` is
//! quantized in parallel, and the residual `m ← (g + m) − Q(g + m)` is
//! recovered through the pipeline-side dequantization buffer (decoding
//! one's own message is exact dequantization), so `--error-feedback`
//! no longer requires the serial codec.

use std::ops::Range;
use std::thread;

use crate::codec::{self, BucketEncoder, DecodeScratch, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::BucketQuantizer;
use crate::quant::error_feedback::ErrorFeedback;
use crate::quant::pool::PoolHandle;
use crate::quant::{QuantizedBucket, Quantizer};

/// Reusable parallel codec state: a thread count, per-shard arenas, and
/// the worker pool (or the legacy scoped-thread mode) that executes the
/// shard tasks.
pub struct BucketPipeline {
    threads: usize,
    shards: Vec<Shard>,
    /// `Some` = persistent pool execution (default); `None` = legacy
    /// per-round `std::thread::scope` (the retained perf baseline).
    pool: Option<PoolHandle>,
    /// Pipeline-side dequantization buffer for the error-feedback
    /// residual update (parallel EF never materializes a
    /// [`QuantizedGrad`](crate::quant::bucket::QuantizedGrad)).
    ef_deq: Vec<f32>,
}

#[derive(Default)]
struct Shard {
    /// Encoded payload segment (this shard's run of buckets).
    seg: Vec<u8>,
    /// One reusable quantized bucket — each bucket is serialized into
    /// `seg` immediately, so shards never materialize their whole run.
    qb: QuantizedBucket,
    clip: Vec<f32>,
    flat: Vec<f32>,
    scratch: DecodeScratch,
    /// Per-shard task outcome of the last pooled decode/reduce run.
    err: Option<Error>,
}

/// Bucket range of shard `i` of `k` over `n` buckets (contiguous,
/// balanced to within one bucket).
fn shard_range(n: usize, k: usize, i: usize) -> Range<usize> {
    (n * i / k)..(n * (i + 1) / k)
}

/// Element spans `[e0, e1)` of each of `k` decode/reduce shards: the
/// bucket grid of [`shard_range`] scaled to elements and clipped to
/// `total`. The ONE copy of the boundary math all four decode/reduce
/// loops (pooled and scoped) share — pooled and scoped execution must
/// shard identically or the bit-identity contract breaks.
fn shard_spans(
    nb: usize,
    k: usize,
    bucket: usize,
    total: usize,
) -> impl Iterator<Item = Range<usize>> {
    let mut e0 = 0usize;
    (0..k).map(move |i| {
        let e1 = (shard_range(nb, k, i).end * bucket).min(total);
        let span = e0..e1;
        e0 = e1;
        span
    })
}

/// Resolve a configured thread count (0 = auto) to the shard target.
fn resolve_threads(threads: usize) -> usize {
    let t = if threads == 0 { crate::quant::pool::auto_threads() } else { threads };
    // Beyond core counts extra shards only cost dispatches, and the cap
    // bounds thread explosion if an absurd count slips past validation.
    t.min(256)
}

impl BucketPipeline {
    /// Pooled pipeline with its own persistent worker pool.
    /// `threads == 0` means auto (`std::thread::available_parallelism`).
    pub fn new(threads: usize) -> BucketPipeline {
        let t = resolve_threads(threads);
        BucketPipeline {
            threads: t,
            shards: Vec::new(),
            pool: Some(PoolHandle::new(t)),
            ef_deq: Vec::new(),
        }
    }

    /// Pooled pipeline on a caller-shared pool (one pool per run,
    /// threaded through `WireSpec` — codecs, shard servers and drivers
    /// then reuse the same threads).
    pub fn with_pool(threads: usize, pool: PoolHandle) -> BucketPipeline {
        BucketPipeline {
            threads: resolve_threads(threads),
            shards: Vec::new(),
            pool: Some(pool),
            ef_deq: Vec::new(),
        }
    }

    /// Legacy scoped-thread pipeline: spawns `k` threads per call, as in
    /// PR 3. Retained as the same-machine baseline perfbench measures
    /// the pool against; output is bit-identical to the pooled modes.
    pub fn scoped(threads: usize) -> BucketPipeline {
        BucketPipeline {
            threads: resolve_threads(threads),
            shards: Vec::new(),
            pool: None,
            ef_deq: Vec::new(),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether shard tasks run on a persistent pool (vs per-round scoped
    /// threads).
    pub fn is_pooled(&self) -> bool {
        self.pool.is_some()
    }

    fn ensure_shards(&mut self, k: usize) {
        while self.shards.len() < k {
            self.shards.push(Shard::default());
        }
    }

    /// Quantize `g` bucket-by-bucket (per-bucket RNG streams derived from
    /// `round_key`) and encode it as a wire message into `out` (cleared
    /// first). Byte-identical to serial
    /// [`BucketQuantizer::quantize_streams_into`] + [`codec::encode`]
    /// for every thread count and both execution modes.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_into(
        &mut self,
        bq: &BucketQuantizer,
        q: &dyn Quantizer,
        g: &[f32],
        round_key: u64,
        scheme: &str,
        packing: Packing,
        out: &mut Vec<u8>,
    ) {
        let s = q.num_levels();
        debug_assert!(s >= 2, "FP gradients take the fp framing, not the pipeline");
        let nb = bq.num_buckets(g.len());
        out.clear();
        codec::encode_quantized_header_into(s, scheme, packing, g.len(), bq.bucket_size, out);
        if nb == 0 {
            return;
        }
        let k = self.threads.min(nb);
        self.ensure_shards(k);
        let enc = BucketEncoder::new(s, packing);
        if k == 1 {
            let shard = &mut self.shards[0];
            encode_shard(bq, q, g, round_key, 0..nb, enc, shard);
            out.extend_from_slice(&shard.seg);
            return;
        }
        let shards = &mut self.shards[..k];
        match &self.pool {
            Some(pool) => pool
                .scope(|sc| {
                    for (i, shard) in shards.iter_mut().enumerate() {
                        let range = shard_range(nb, k, i);
                        sc.spawn(move || encode_shard(bq, q, g, round_key, range, enc, shard));
                    }
                })
                // A panicking quantizer is a bug; scoped mode would
                // propagate the panic from the join, so mirror it.
                .unwrap_or_else(|e| panic!("parallel encode failed: {e}")),
            None => thread::scope(|scope| {
                for (i, shard) in shards.iter_mut().enumerate() {
                    let range = shard_range(nb, k, i);
                    scope.spawn(move || encode_shard(bq, q, g, round_key, range, enc, shard));
                }
            }),
        }
        for shard in &self.shards[..k] {
            out.extend_from_slice(&shard.seg);
        }
    }

    /// Width-table twin of [`Self::encode_into`] for the adaptive byte
    /// budget: bucket `bi` is quantized by `bank[widths[bi] - 2]` (the
    /// per-width quantizer bank, indexed `s − 2`) and serialized at its
    /// own level count behind a [`codec::encode_quantized_header_widths_into`]
    /// header. The shard grid, per-bucket RNG streams, and segment
    /// concatenation are identical to the uniform path, so the wire
    /// bytes stay invariant across thread counts and execution modes.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_widths_into(
        &mut self,
        bq: &BucketQuantizer,
        bank: &[Box<dyn Quantizer>],
        widths: &[u8],
        g: &[f32],
        round_key: u64,
        scheme: &str,
        packing: Packing,
        out: &mut Vec<u8>,
    ) {
        let nb = bq.num_buckets(g.len());
        debug_assert_eq!(widths.len(), nb, "one width per bucket");
        out.clear();
        if nb == 0 {
            // An empty gradient cannot carry a width table (the format
            // forbids it); emit the uniform floor-width framing instead.
            codec::encode_quantized_header_into(2, scheme, packing, 0, bq.bucket_size, out);
            return;
        }
        codec::encode_quantized_header_widths_into(
            widths,
            scheme,
            packing,
            g.len(),
            bq.bucket_size,
            out,
        );
        let k = self.threads.min(nb);
        self.ensure_shards(k);
        if k == 1 {
            let shard = &mut self.shards[0];
            encode_widths_shard(bq, bank, widths, g, round_key, 0..nb, packing, shard);
            out.extend_from_slice(&shard.seg);
            return;
        }
        let shards = &mut self.shards[..k];
        match &self.pool {
            Some(pool) => pool
                .scope(|sc| {
                    for (i, shard) in shards.iter_mut().enumerate() {
                        let range = shard_range(nb, k, i);
                        sc.spawn(move || {
                            encode_widths_shard(
                                bq, bank, widths, g, round_key, range, packing, shard,
                            )
                        });
                    }
                })
                .unwrap_or_else(|e| panic!("parallel width encode failed: {e}")),
            None => thread::scope(|scope| {
                for (i, shard) in shards.iter_mut().enumerate() {
                    let range = shard_range(nb, k, i);
                    scope.spawn(move || {
                        encode_widths_shard(bq, bank, widths, g, round_key, range, packing, shard)
                    });
                }
            }),
        }
        for shard in &self.shards[..k] {
            out.extend_from_slice(&shard.seg);
        }
    }

    /// Error-feedback twin of [`Self::encode_widths_into`]: quantize the
    /// compensated signal `g + m` at the budgeted per-bucket widths and
    /// recover the residual through the width-aware wire decode.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_widths_ef_into(
        &mut self,
        bq: &BucketQuantizer,
        bank: &[Box<dyn Quantizer>],
        widths: &[u8],
        ef: &mut ErrorFeedback,
        g: &[f32],
        round_key: u64,
        scheme: &str,
        packing: Packing,
        out: &mut Vec<u8>,
    ) {
        {
            let comp = ef.compensate(g);
            self.encode_widths_into(bq, bank, widths, comp, round_key, scheme, packing, out);
        }
        let mut deq = std::mem::take(&mut self.ef_deq);
        self.decode_flat_into(out, &mut deq).expect("own encoding always decodes");
        ef.update_residual(&deq);
        self.ef_deq = deq;
    }

    /// The error-feedback twin of [`Self::encode_into`]: quantize and
    /// encode the compensated signal `g + m` (sharded exactly like the
    /// plain path, so the wire bytes stay thread-count invariant), then
    /// recover the residual `m ← (g + m) − Q(g + m)` by decoding the
    /// message just written — dequantization through the wire, exact by
    /// construction. `ef` carries the residual memory across rounds.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_ef_into(
        &mut self,
        bq: &BucketQuantizer,
        q: &dyn Quantizer,
        ef: &mut ErrorFeedback,
        g: &[f32],
        round_key: u64,
        scheme: &str,
        packing: Packing,
        out: &mut Vec<u8>,
    ) {
        {
            let comp = ef.compensate(g);
            self.encode_into(bq, q, comp, round_key, scheme, packing, out);
        }
        let mut deq = std::mem::take(&mut self.ef_deq);
        self.decode_flat_into(out, &mut deq).expect("own encoding always decodes");
        ef.update_residual(&deq);
        self.ef_deq = deq;
    }

    /// The dequantized transmitted signal of the last
    /// [`Self::encode_ef_into`] call — the buffer the residual update
    /// decoded. Exposed so callers measuring quantization error (the
    /// trainer's per-step rel-MSE/cosine) can reuse it instead of
    /// decoding the same message a second time.
    pub fn ef_dequant(&self) -> &[f32] {
        &self.ef_deq
    }

    /// Decode a wire message into a flat f32 buffer (cleared and
    /// refilled), sharding the bucket grid across threads. Identical
    /// output to [`codec::decode_flat_into`].
    pub fn decode_flat_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
        let (total, bucket) = codec::peek_shape(bytes)?;
        out.clear();
        out.resize(total, 0.0);
        let nb = total.div_ceil(bucket.max(1));
        let k = self.threads.min(nb.max(1));
        self.ensure_shards(k);
        if k == 1 {
            return codec::decode_slice_into(bytes, 0, total, out, &mut self.shards[0].scratch);
        }
        let shards = &mut self.shards[..k];
        match &self.pool {
            Some(pool) => {
                let pooled = pool.scope(|sc| {
                    let mut rest: &mut [f32] = out;
                    for (shard, span) in shards.iter_mut().zip(shard_spans(nb, k, bucket, total))
                    {
                        let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len());
                        rest = tail;
                        let (e0, e1) = (span.start, span.end);
                        sc.spawn(move || {
                            let r =
                                codec::decode_slice_into(bytes, e0, e1, mine, &mut shard.scratch);
                            shard.err = r.err();
                        });
                    }
                });
                pooled.map_err(|e| Error::Comm(format!("decode shard died: {e}")))?;
                self.first_shard_err(k)
            }
            None => {
                let mut res: Result<()> = Ok(());
                thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(k);
                    let mut rest: &mut [f32] = out;
                    for (shard, span) in shards.iter_mut().zip(shard_spans(nb, k, bucket, total))
                    {
                        let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len());
                        rest = tail;
                        let (e0, e1) = (span.start, span.end);
                        let sc = &mut shard.scratch;
                        handles.push(
                            scope.spawn(move || codec::decode_slice_into(bytes, e0, e1, mine, sc)),
                        );
                    }
                    for h in handles {
                        let r = h
                            .join()
                            .unwrap_or_else(|_| Err(Error::Comm("decode shard panicked".into())));
                        if res.is_ok() {
                            res = r;
                        }
                    }
                });
                res
            }
        }
    }

    /// Decode every upload and accumulate element-wise f64 sums into
    /// `acc` (cleared and resized to the shared gradient length). The
    /// per-element accumulation order over uploads is the upload order —
    /// exactly the serial decode-then-add loop — so the reduced sums are
    /// bit-identical to the serial path for any thread count.
    pub fn decode_reduce_into(&mut self, uploads: &[Vec<u8>], acc: &mut Vec<f64>) -> Result<()> {
        let mut shape: Option<(usize, usize)> = None;
        for u in uploads {
            let (t, b) = codec::peek_shape(u)?;
            match shape {
                None => shape = Some((t, b)),
                Some((n, _)) if n != t => {
                    return Err(Error::Shape(format!(
                        "worker gradient has {t} elements, expected {n}"
                    )))
                }
                Some(_) => {}
            }
        }
        let (total, bucket) = shape.unwrap_or((0, 1));
        acc.clear();
        acc.resize(total, 0.0);
        let nb = total.div_ceil(bucket.max(1));
        let k = self.threads.min(nb.max(1));
        self.ensure_shards(k);
        if k == 1 {
            return reduce_shard(uploads, 0, total, acc, &mut self.shards[0]);
        }
        let shards = &mut self.shards[..k];
        match &self.pool {
            Some(pool) => {
                let pooled = pool.scope(|sc| {
                    let mut rest: &mut [f64] = acc;
                    for (shard, span) in shards.iter_mut().zip(shard_spans(nb, k, bucket, total))
                    {
                        let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len());
                        rest = tail;
                        let (e0, e1) = (span.start, span.end);
                        sc.spawn(move || {
                            let r = reduce_shard(uploads, e0, e1, mine, &mut *shard);
                            shard.err = r.err();
                        });
                    }
                });
                pooled.map_err(|e| Error::Comm(format!("reduce shard died: {e}")))?;
                self.first_shard_err(k)
            }
            None => {
                let mut res: Result<()> = Ok(());
                thread::scope(|scope| {
                    let mut handles = Vec::with_capacity(k);
                    let mut rest: &mut [f64] = acc;
                    for (shard, span) in shards.iter_mut().zip(shard_spans(nb, k, bucket, total))
                    {
                        let (mine, tail) = std::mem::take(&mut rest).split_at_mut(span.len());
                        rest = tail;
                        let (e0, e1) = (span.start, span.end);
                        handles
                            .push(scope.spawn(move || reduce_shard(uploads, e0, e1, mine, shard)));
                    }
                    for h in handles {
                        let r = h
                            .join()
                            .unwrap_or_else(|_| Err(Error::Comm("reduce shard panicked".into())));
                        if res.is_ok() {
                            res = r;
                        }
                    }
                });
                res
            }
        }
    }

    /// First (in shard order) error reported by the last pooled run —
    /// the same priority the scoped join loop uses.
    fn first_shard_err(&mut self, k: usize) -> Result<()> {
        let mut res: Result<()> = Ok(());
        for shard in &mut self.shards[..k] {
            if let (Some(e), true) = (shard.err.take(), res.is_ok()) {
                res = Err(e);
            }
        }
        res
    }
}

/// Quantize and serialize one contiguous run of buckets into the shard's
/// segment buffer.
fn encode_shard(
    bq: &BucketQuantizer,
    q: &dyn Quantizer,
    g: &[f32],
    round_key: u64,
    buckets: Range<usize>,
    enc: BucketEncoder,
    shard: &mut Shard,
) {
    shard.seg.clear();
    let d = bq.bucket_size;
    for bi in buckets {
        let lo = bi * d;
        let hi = (lo + d).min(g.len());
        bq.quantize_bucket_stream(&g[lo..hi], bi, q, round_key, &mut shard.clip, &mut shard.qb);
        enc.encode_bucket_into(&shard.qb, &mut shard.seg);
    }
}

/// Width-table variant of [`encode_shard`]: each bucket picks its
/// quantizer out of the per-width bank and its own [`BucketEncoder`].
#[allow(clippy::too_many_arguments)]
fn encode_widths_shard(
    bq: &BucketQuantizer,
    bank: &[Box<dyn Quantizer>],
    widths: &[u8],
    g: &[f32],
    round_key: u64,
    buckets: Range<usize>,
    packing: Packing,
    shard: &mut Shard,
) {
    shard.seg.clear();
    let d = bq.bucket_size;
    for bi in buckets {
        let lo = bi * d;
        let hi = (lo + d).min(g.len());
        let w = widths[bi] as usize;
        let q = bank[w - 2].as_ref();
        bq.quantize_bucket_stream(&g[lo..hi], bi, q, round_key, &mut shard.clip, &mut shard.qb);
        debug_assert_eq!(shard.qb.levels.len(), w, "bank[{w} - 2] must be a {w}-level scheme");
        BucketEncoder::new(w, packing).encode_bucket_into(&shard.qb, &mut shard.seg);
    }
}

/// Decode elements `[e0, e1)` of every upload and add them (in upload
/// order) into this shard's slice of the accumulator.
fn reduce_shard(
    uploads: &[Vec<u8>],
    e0: usize,
    e1: usize,
    acc: &mut [f64],
    shard: &mut Shard,
) -> Result<()> {
    shard.flat.clear();
    shard.flat.resize(e1 - e0, 0.0);
    for u in uploads {
        let Shard { flat, scratch, .. } = shard;
        codec::decode_slice_into(u, e0, e1, flat, scratch)?;
        for (a, v) in acc.iter_mut().zip(flat.iter()) {
            *a += *v as f64;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bucket::QuantizedGrad;
    use crate::quant::from_name;
    use crate::tensor::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    /// Wire bytes must be identical for every thread count and execution
    /// mode (pooled own-pool, pooled shared-pool, scoped) and equal to
    /// the serial per-bucket-stream reference, across schemes, packings,
    /// ragged tails, and clipping.
    #[test]
    fn parallel_encode_bit_identical_to_serial_streams() {
        let shared = PoolHandle::new(3);
        for (n, d) in [(1500usize, 256usize), (1000, 128), (255, 64), (64, 64), (10, 256)] {
            let g = sample(n, n as u64);
            for method in ["terngrad", "orq-5", "linear-9", "bingrad-b"] {
                let q = from_name(method).unwrap();
                for bq in [BucketQuantizer::new(d), BucketQuantizer::with_clip(d, 2.5)] {
                    for packing in [Packing::Fixed, Packing::BaseS] {
                        let mut qg = QuantizedGrad::default();
                        bq.quantize_streams_into(&g, q.as_ref(), 7, &mut qg);
                        let want = codec::encode(&qg, method, packing);
                        for threads in [1usize, 2, 3, 8] {
                            for pipe in [
                                BucketPipeline::new(threads),
                                BucketPipeline::with_pool(threads, shared.clone()),
                                BucketPipeline::scoped(threads),
                            ] {
                                let mut pipe = pipe;
                                let mut got = Vec::new();
                                pipe.encode_into(
                                    &bq,
                                    q.as_ref(),
                                    &g,
                                    7,
                                    method,
                                    packing,
                                    &mut got,
                                );
                                assert_eq!(
                                    got, want,
                                    "{method} {packing:?} n={n} d={d} threads={threads} \
                                     pooled={}",
                                    pipe.is_pooled()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// The pool-reuse contract of the tentpole: one pipeline driven for
    /// several rounds (arenas and pool threads reused throughout) must
    /// emit bit-identical bytes to a fresh pipeline per round, for every
    /// scheme family — arena history is invisible in the output.
    #[test]
    fn reused_arenas_match_fresh_pipelines_across_rounds() {
        let g = sample(2200, 5);
        let bq = BucketQuantizer::new(256);
        for method in ["terngrad", "qsgd-5", "orq-5", "linear-9", "bingrad-b", "signsgd"] {
            let q = from_name(method).unwrap();
            let mut reused = BucketPipeline::new(3);
            let spawned_after_round1 = {
                let mut out = Vec::new();
                reused.encode_into(&bq, q.as_ref(), &g, 0, method, Packing::BaseS, &mut out);
                // threads_spawned only counts this pipeline's own pool
                reused.pool.as_ref().unwrap().threads_spawned()
            };
            for round in 0..4u64 {
                let mut got = Vec::new();
                reused.encode_into(&bq, q.as_ref(), &g, round, method, Packing::BaseS, &mut got);
                let mut fresh = BucketPipeline::new(3);
                let mut want = Vec::new();
                fresh.encode_into(&bq, q.as_ref(), &g, round, method, Packing::BaseS, &mut want);
                assert_eq!(got, want, "{method} round {round}");
            }
            // steady state: no new threads after round 1's peak
            assert_eq!(
                reused.pool.as_ref().unwrap().threads_spawned(),
                spawned_after_round1,
                "{method}: pool must reuse its workers across rounds"
            );
        }
    }

    #[test]
    fn parallel_decode_matches_serial_decode() {
        let g = sample(3001, 3);
        let q = from_name("orq-5").unwrap();
        let bq = BucketQuantizer::new(128);
        let mut qg = QuantizedGrad::default();
        bq.quantize_streams_into(&g, q.as_ref(), 11, &mut qg);
        for packing in [Packing::Fixed, Packing::BaseS] {
            let bytes = codec::encode(&qg, "orq-5", packing);
            let mut want = Vec::new();
            codec::decode_flat_into(&bytes, &mut want, &mut DecodeScratch::default()).unwrap();
            for threads in [1usize, 2, 5, 16] {
                for mut pipe in [BucketPipeline::new(threads), BucketPipeline::scoped(threads)] {
                    let mut got = Vec::new();
                    pipe.decode_flat_into(&bytes, &mut got).unwrap();
                    assert_eq!(got, want, "{packing:?} threads={threads}");
                }
            }
        }
        // FP framing takes the single-shard path and round-trips exactly
        let fp = codec::encode_fp(&g);
        let mut pipe = BucketPipeline::new(4);
        let mut got = Vec::new();
        pipe.decode_flat_into(&fp, &mut got).unwrap();
        assert_eq!(got, g);
    }

    /// Parallel decode+reduce must produce bit-identical f64 sums to the
    /// serial decode-then-add loop (same per-element accumulation order),
    /// in both execution modes, including across repeated rounds on one
    /// pipeline.
    #[test]
    fn parallel_reduce_bit_identical_to_serial() {
        let bq = BucketQuantizer::new(200);
        let q = from_name("terngrad").unwrap();
        let uploads: Vec<Vec<u8>> = (0..5)
            .map(|w| {
                let g = sample(1700, 40 + w);
                let mut qg = QuantizedGrad::default();
                bq.quantize_streams_into(&g, q.as_ref(), w, &mut qg);
                codec::encode(&qg, "terngrad", Packing::BaseS)
            })
            .collect();
        // serial reference
        let mut flat = Vec::new();
        let mut sc = DecodeScratch::default();
        let mut want = vec![0.0f64; 1700];
        for u in &uploads {
            codec::decode_flat_into(u, &mut flat, &mut sc).unwrap();
            for (a, v) in want.iter_mut().zip(&flat) {
                *a += *v as f64;
            }
        }
        for threads in [1usize, 2, 3, 8] {
            for mut pipe in [BucketPipeline::new(threads), BucketPipeline::scoped(threads)] {
                let mut acc = Vec::new();
                for round in 0..3 {
                    pipe.decode_reduce_into(&uploads, &mut acc).unwrap();
                    assert_eq!(acc, want, "threads={threads} round={round}");
                }
            }
        }
    }

    #[test]
    fn reduce_rejects_mismatched_shapes_and_corrupt_bytes() {
        let bq = BucketQuantizer::new(64);
        let q = from_name("terngrad").unwrap();
        let enc = |n: usize, key: u64| {
            let g = sample(n, key);
            let mut qg = QuantizedGrad::default();
            bq.quantize_streams_into(&g, q.as_ref(), key, &mut qg);
            codec::encode(&qg, "terngrad", Packing::BaseS)
        };
        for mut pipe in [BucketPipeline::new(4), BucketPipeline::scoped(4)] {
            let mut acc = Vec::new();
            let mismatched = vec![enc(128, 1), enc(256, 2)];
            assert!(pipe.decode_reduce_into(&mismatched, &mut acc).is_err());
            let mut corrupt = enc(128, 3);
            corrupt.truncate(corrupt.len() - 3);
            assert!(pipe.decode_reduce_into(&[corrupt], &mut acc).is_err());
            let mut out = Vec::new();
            let mut short = enc(128, 4);
            short.truncate(10);
            assert!(pipe.decode_flat_into(&short, &mut out).is_err());
            // empty upload set reduces to an empty accumulator
            pipe.decode_reduce_into(&[], &mut acc).unwrap();
            assert!(acc.is_empty());
            // after errors, the same pipeline still works (pool survives)
            let mut round = Vec::new();
            pipe.decode_flat_into(&enc(128, 5), &mut round).unwrap();
            assert_eq!(round.len(), 128);
        }
    }

    /// Pipeline-side error feedback: byte-identical to compensating by
    /// hand and feeding the plain pipeline, residual tracked exactly,
    /// and invariant across thread counts and execution modes.
    #[test]
    fn pipeline_error_feedback_matches_manual_compensation() {
        let g = sample(1600, 9);
        let bq = BucketQuantizer::new(256);
        let q = from_name("bingrad-b").unwrap();
        // reference: EF round 1 compensates with m = 0, so the bytes are
        // the plain pipeline's bytes for g
        let mut reference: Option<(Vec<u8>, Vec<u8>)> = None;
        for threads in [2usize, 3, 8] {
            for pooled in [true, false] {
                let mut pipe = if pooled {
                    BucketPipeline::new(threads)
                } else {
                    BucketPipeline::scoped(threads)
                };
                let mut ef = ErrorFeedback::new(bq.clone());
                let mut r1 = Vec::new();
                let ps = Packing::BaseS;
                pipe.encode_ef_into(&bq, q.as_ref(), &mut ef, &g, 1, "bingrad-b", ps, &mut r1);
                let mut plain = Vec::new();
                pipe.encode_into(&bq, q.as_ref(), &g, 1, "bingrad-b", Packing::BaseS, &mut plain);
                assert_eq!(r1, plain, "round 1 has zero residual");
                // round 2 must carry the residual: different bytes than a
                // memoryless encode of the same gradient
                let mut r2 = Vec::new();
                pipe.encode_ef_into(&bq, q.as_ref(), &mut ef, &g, 2, "bingrad-b", ps, &mut r2);
                let mut plain2 = Vec::new();
                pipe.encode_into(&bq, q.as_ref(), &g, 2, "bingrad-b", Packing::BaseS, &mut plain2);
                assert_ne!(r2, plain2, "round 2 must quantize g + m");
                match &reference {
                    None => reference = Some((r1, r2)),
                    Some((w1, w2)) => {
                        assert_eq!(&r1, w1, "threads={threads} pooled={pooled}");
                        assert_eq!(&r2, w2, "threads={threads} pooled={pooled}");
                    }
                }
            }
        }
    }

    /// Width-table encode: bit-identical across thread counts and
    /// execution modes, and equal to the serial per-bucket reference
    /// (quantize each bucket with its width's bank entry, then
    /// [`codec::encode_widths_into`]).
    #[test]
    fn width_encode_bit_identical_across_threads_and_modes() {
        let shared = PoolHandle::new(3);
        for (n, d) in [(1500usize, 256usize), (255, 64), (100, 128)] {
            let g = sample(n, n as u64 + 1);
            let bq = BucketQuantizer::new(d);
            let nb = bq.num_buckets(n);
            let bank: Vec<Box<dyn Quantizer>> =
                (2..=6).map(|s| from_name(&format!("orq-{s}")).unwrap()).collect();
            let widths: Vec<u8> = (0..nb).map(|bi| 2 + (bi % 5) as u8).collect();
            // serial reference through the allocating bucket API
            let mut qg = QuantizedGrad {
                bucket_size: d,
                total_len: n,
                buckets: Vec::new(),
            };
            let (mut clip, mut qb) = (Vec::new(), QuantizedBucket::default());
            for (bi, &w) in widths.iter().enumerate() {
                let lo = bi * d;
                let hi = (lo + d).min(n);
                let q = bank[w as usize - 2].as_ref();
                bq.quantize_bucket_stream(&g[lo..hi], bi, q, 7, &mut clip, &mut qb);
                qg.buckets.push(qb.clone());
            }
            for packing in [Packing::Fixed, Packing::BaseS] {
                let mut want = Vec::new();
                codec::encode_widths_into(&qg, "orq-6", packing, &mut want);
                for threads in [1usize, 2, 3, 8] {
                    for mut pipe in [
                        BucketPipeline::new(threads),
                        BucketPipeline::with_pool(threads, shared.clone()),
                        BucketPipeline::scoped(threads),
                    ] {
                        let mut got = Vec::new();
                        pipe.encode_widths_into(
                            &bq, &bank, &widths, &g, 7, "orq-6", packing, &mut got,
                        );
                        assert_eq!(
                            got, want,
                            "n={n} d={d} {packing:?} threads={threads} pooled={}",
                            pipe.is_pooled()
                        );
                        // and it round-trips through the width-aware decode
                        let mut flat = Vec::new();
                        pipe.decode_flat_into(&got, &mut flat).unwrap();
                        assert_eq!(flat.len(), n);
                    }
                }
            }
        }
    }

    /// Width-table error feedback: round 1 (zero residual) matches the
    /// plain width encode, round 2 carries the residual, and both are
    /// thread-count invariant.
    #[test]
    fn width_ef_matches_plain_on_first_round_and_is_invariant() {
        let g = sample(1600, 17);
        let bq = BucketQuantizer::new(256);
        let nb = bq.num_buckets(g.len());
        let bank: Vec<Box<dyn Quantizer>> =
            (2..=4).map(|s| from_name(&format!("qsgd-{s}")).unwrap()).collect();
        let widths: Vec<u8> = (0..nb).map(|bi| 2 + (bi % 3) as u8).collect();
        let ps = Packing::BaseS;
        let mut reference: Option<(Vec<u8>, Vec<u8>)> = None;
        for threads in [1usize, 2, 3, 8] {
            let mut pipe = BucketPipeline::new(threads);
            let mut ef = ErrorFeedback::new(bq.clone());
            let mut r1 = Vec::new();
            pipe.encode_widths_ef_into(
                &bq, &bank, &widths, &mut ef, &g, 1, "qsgd-4", ps, &mut r1,
            );
            let mut plain = Vec::new();
            pipe.encode_widths_into(&bq, &bank, &widths, &g, 1, "qsgd-4", ps, &mut plain);
            assert_eq!(r1, plain, "round 1 has zero residual (threads={threads})");
            let mut r2 = Vec::new();
            pipe.encode_widths_ef_into(
                &bq, &bank, &widths, &mut ef, &g, 2, "qsgd-4", ps, &mut r2,
            );
            assert_ne!(r2, plain, "round 2 must quantize g + m");
            match &reference {
                None => reference = Some((r1, r2)),
                Some((w1, w2)) => {
                    assert_eq!(&r1, w1, "threads={threads}");
                    assert_eq!(&r2, w2, "threads={threads}");
                }
            }
        }
    }

    /// `threads == 0` auto-sizing is deterministic: repeated
    /// constructions agree with each other and with the explicit count.
    #[test]
    fn auto_thread_count_is_positive_and_deterministic() {
        let a = BucketPipeline::new(0).threads();
        let b = BucketPipeline::new(0).threads();
        assert_eq!(a, b);
        assert!(a >= 1);
        assert_eq!(BucketPipeline::new(3).threads(), 3);
        assert_eq!(BucketPipeline::scoped(0).threads(), a);
        assert_eq!(BucketPipeline::new(a).threads(), a);
    }
}
