//! Parallel per-bucket pipeline: quantize→encode and decode→reduce
//! sharded across scoped threads.
//!
//! Buckets are independent by construction (paper §5: each bucket solves
//! its own levels and rounds its own elements), so the two hot loops of
//! an exchange round parallelize along the bucket grid:
//!
//! * **quantize + encode** — [`BucketPipeline::encode_into`] writes the
//!   wire header, then splits the bucket range into contiguous shards;
//!   each shard thread quantizes its buckets (per-bucket RNG streams,
//!   [`BucketQuantizer::quantize_bucket_stream`]) and serializes them
//!   into its own segment buffer; segments concatenate in bucket order,
//!   so the wire bytes are identical for every thread count (and to the
//!   serial [`BucketQuantizer::quantize_streams_into`] reference).
//! * **decode + reduce** — [`BucketPipeline::decode_flat_into`] and
//!   [`BucketPipeline::decode_reduce_into`] split the *output* buffer
//!   into disjoint bucket-aligned slices and decode each range straight
//!   out of the shared message bytes ([`codec::decode_slice_into`]).
//!   The reduce variant preserves the per-element upload accumulation
//!   order, so the f64 sums are bit-identical to the serial loop.
//!
//! Threading is `std::thread::scope` (dependency-free, the `trainer.rs`
//! idiom). All shard state — segment buffers, one reusable
//! [`QuantizedBucket`], clip scratch, decode scratch — lives in arenas
//! reused across rounds: the steady-state parallel path performs no
//! per-bucket allocation and takes no locks (the level solvers use
//! per-thread arenas, `quant::scratch`). Scoped threads are spawned per
//! call, so the *solver* arenas amortize across a shard's buckets within
//! one round rather than across rounds, and each call pays k thread
//! spawns — worth it for multi-bucket gradients, not for tiny ones (the
//! shard count is capped by the bucket count; a persistent worker pool
//! is the ROADMAP follow-up that would remove both costs).

use std::ops::Range;
use std::thread;

use crate::codec::{self, BucketEncoder, DecodeScratch, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::BucketQuantizer;
use crate::quant::{QuantizedBucket, Quantizer};

/// Reusable parallel codec state: a thread count plus per-shard arenas.
pub struct BucketPipeline {
    threads: usize,
    shards: Vec<Shard>,
}

#[derive(Default)]
struct Shard {
    /// Encoded payload segment (this shard's run of buckets).
    seg: Vec<u8>,
    /// One reusable quantized bucket — each bucket is serialized into
    /// `seg` immediately, so shards never materialize their whole run.
    qb: QuantizedBucket,
    clip: Vec<f32>,
    flat: Vec<f32>,
    scratch: DecodeScratch,
}

/// Bucket range of shard `i` of `k` over `n` buckets (contiguous,
/// balanced to within one bucket).
fn shard_range(n: usize, k: usize, i: usize) -> Range<usize> {
    (n * i / k)..(n * (i + 1) / k)
}

impl BucketPipeline {
    /// `threads == 0` means auto (`std::thread::available_parallelism`).
    /// Counts are capped at 256 — beyond core counts extra shards only
    /// cost spawns, and the cap bounds thread explosion if an absurd
    /// count slips past config validation.
    pub fn new(threads: usize) -> BucketPipeline {
        let t = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        BucketPipeline { threads: t.min(256), shards: Vec::new() }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn ensure_shards(&mut self, k: usize) {
        while self.shards.len() < k {
            self.shards.push(Shard::default());
        }
    }

    /// Quantize `g` bucket-by-bucket (per-bucket RNG streams derived from
    /// `round_key`) and encode it as a wire message into `out` (cleared
    /// first). Byte-identical to serial
    /// [`BucketQuantizer::quantize_streams_into`] + [`codec::encode`]
    /// for every thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_into(
        &mut self,
        bq: &BucketQuantizer,
        q: &dyn Quantizer,
        g: &[f32],
        round_key: u64,
        scheme: &str,
        packing: Packing,
        out: &mut Vec<u8>,
    ) {
        let s = q.num_levels();
        debug_assert!(s >= 2, "FP gradients take the fp framing, not the pipeline");
        let nb = bq.num_buckets(g.len());
        out.clear();
        codec::encode_quantized_header_into(s, scheme, packing, g.len(), bq.bucket_size, out);
        if nb == 0 {
            return;
        }
        let k = self.threads.min(nb);
        self.ensure_shards(k);
        let enc = BucketEncoder::new(s, packing);
        if k == 1 {
            let shard = &mut self.shards[0];
            encode_shard(bq, q, g, round_key, 0..nb, enc, shard);
            out.extend_from_slice(&shard.seg);
            return;
        }
        let shards = &mut self.shards[..k];
        thread::scope(|scope| {
            for (i, shard) in shards.iter_mut().enumerate() {
                let range = shard_range(nb, k, i);
                scope.spawn(move || encode_shard(bq, q, g, round_key, range, enc, shard));
            }
        });
        for shard in &self.shards[..k] {
            out.extend_from_slice(&shard.seg);
        }
    }

    /// Decode a wire message into a flat f32 buffer (cleared and
    /// refilled), sharding the bucket grid across threads. Identical
    /// output to [`codec::decode_flat_into`].
    pub fn decode_flat_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
        let (total, bucket) = codec::peek_shape(bytes)?;
        out.clear();
        out.resize(total, 0.0);
        let nb = total.div_ceil(bucket.max(1));
        let k = self.threads.min(nb.max(1));
        self.ensure_shards(k);
        if k == 1 {
            return codec::decode_slice_into(bytes, 0, total, out, &mut self.shards[0].scratch);
        }
        let shards = &mut self.shards[..k];
        let mut res: Result<()> = Ok(());
        thread::scope(|scope| {
            let mut handles = Vec::with_capacity(k);
            let mut rest: &mut [f32] = out;
            let mut e0 = 0usize;
            for (i, shard) in shards.iter_mut().enumerate() {
                let e1 = (shard_range(nb, k, i).end * bucket).min(total);
                let (mine, tail) = std::mem::take(&mut rest).split_at_mut(e1 - e0);
                rest = tail;
                let sc = &mut shard.scratch;
                handles
                    .push(scope.spawn(move || codec::decode_slice_into(bytes, e0, e1, mine, sc)));
                e0 = e1;
            }
            for h in handles {
                let r = h
                    .join()
                    .unwrap_or_else(|_| Err(Error::Comm("decode shard panicked".into())));
                if res.is_ok() {
                    res = r;
                }
            }
        });
        res
    }

    /// Decode every upload and accumulate element-wise f64 sums into
    /// `acc` (cleared and resized to the shared gradient length). The
    /// per-element accumulation order over uploads is the upload order —
    /// exactly the serial decode-then-add loop — so the reduced sums are
    /// bit-identical to the serial path for any thread count.
    pub fn decode_reduce_into(&mut self, uploads: &[Vec<u8>], acc: &mut Vec<f64>) -> Result<()> {
        let mut shape: Option<(usize, usize)> = None;
        for u in uploads {
            let (t, b) = codec::peek_shape(u)?;
            match shape {
                None => shape = Some((t, b)),
                Some((n, _)) if n != t => {
                    return Err(Error::Shape(format!(
                        "worker gradient has {t} elements, expected {n}"
                    )))
                }
                Some(_) => {}
            }
        }
        let (total, bucket) = shape.unwrap_or((0, 1));
        acc.clear();
        acc.resize(total, 0.0);
        let nb = total.div_ceil(bucket.max(1));
        let k = self.threads.min(nb.max(1));
        self.ensure_shards(k);
        if k == 1 {
            return reduce_shard(uploads, 0, total, acc, &mut self.shards[0]);
        }
        let shards = &mut self.shards[..k];
        let mut res: Result<()> = Ok(());
        thread::scope(|scope| {
            let mut handles = Vec::with_capacity(k);
            let mut rest: &mut [f64] = acc;
            let mut e0 = 0usize;
            for (i, shard) in shards.iter_mut().enumerate() {
                let e1 = (shard_range(nb, k, i).end * bucket).min(total);
                let (mine, tail) = std::mem::take(&mut rest).split_at_mut(e1 - e0);
                rest = tail;
                handles.push(scope.spawn(move || reduce_shard(uploads, e0, e1, mine, shard)));
                e0 = e1;
            }
            for h in handles {
                let r = h
                    .join()
                    .unwrap_or_else(|_| Err(Error::Comm("reduce shard panicked".into())));
                if res.is_ok() {
                    res = r;
                }
            }
        });
        res
    }
}

/// Quantize and serialize one contiguous run of buckets into the shard's
/// segment buffer.
fn encode_shard(
    bq: &BucketQuantizer,
    q: &dyn Quantizer,
    g: &[f32],
    round_key: u64,
    buckets: Range<usize>,
    enc: BucketEncoder,
    shard: &mut Shard,
) {
    shard.seg.clear();
    let d = bq.bucket_size;
    for bi in buckets {
        let lo = bi * d;
        let hi = (lo + d).min(g.len());
        bq.quantize_bucket_stream(&g[lo..hi], bi, q, round_key, &mut shard.clip, &mut shard.qb);
        enc.encode_bucket_into(&shard.qb, &mut shard.seg);
    }
}

/// Decode elements `[e0, e1)` of every upload and add them (in upload
/// order) into this shard's slice of the accumulator.
fn reduce_shard(
    uploads: &[Vec<u8>],
    e0: usize,
    e1: usize,
    acc: &mut [f64],
    shard: &mut Shard,
) -> Result<()> {
    shard.flat.clear();
    shard.flat.resize(e1 - e0, 0.0);
    for u in uploads {
        let Shard { flat, scratch, .. } = shard;
        codec::decode_slice_into(u, e0, e1, flat, scratch)?;
        for (a, v) in acc.iter_mut().zip(flat.iter()) {
            *a += *v as f64;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bucket::QuantizedGrad;
    use crate::quant::from_name;
    use crate::tensor::rng::Rng;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    /// Wire bytes must be identical for every thread count and equal to
    /// the serial per-bucket-stream reference, across schemes, packings,
    /// ragged tails, and clipping.
    #[test]
    fn parallel_encode_bit_identical_to_serial_streams() {
        for (n, d) in [(1500usize, 256usize), (1000, 128), (255, 64), (64, 64), (10, 256)] {
            let g = sample(n, n as u64);
            for method in ["terngrad", "orq-5", "linear-9", "bingrad-b"] {
                let q = from_name(method).unwrap();
                for bq in [BucketQuantizer::new(d), BucketQuantizer::with_clip(d, 2.5)] {
                    for packing in [Packing::Fixed, Packing::BaseS] {
                        let mut qg = QuantizedGrad::default();
                        bq.quantize_streams_into(&g, q.as_ref(), 7, &mut qg);
                        let want = codec::encode(&qg, method, packing);
                        for threads in [1usize, 2, 3, 8] {
                            let mut pipe = BucketPipeline::new(threads);
                            let mut got = Vec::new();
                            pipe.encode_into(&bq, q.as_ref(), &g, 7, method, packing, &mut got);
                            assert_eq!(
                                got, want,
                                "{method} {packing:?} n={n} d={d} threads={threads}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn parallel_decode_matches_serial_decode() {
        let g = sample(3001, 3);
        let q = from_name("orq-5").unwrap();
        let bq = BucketQuantizer::new(128);
        let mut qg = QuantizedGrad::default();
        bq.quantize_streams_into(&g, q.as_ref(), 11, &mut qg);
        for packing in [Packing::Fixed, Packing::BaseS] {
            let bytes = codec::encode(&qg, "orq-5", packing);
            let mut want = Vec::new();
            codec::decode_flat_into(&bytes, &mut want, &mut DecodeScratch::default()).unwrap();
            for threads in [1usize, 2, 5, 16] {
                let mut pipe = BucketPipeline::new(threads);
                let mut got = Vec::new();
                pipe.decode_flat_into(&bytes, &mut got).unwrap();
                assert_eq!(got, want, "{packing:?} threads={threads}");
            }
        }
        // FP framing takes the single-shard path and round-trips exactly
        let fp = codec::encode_fp(&g);
        let mut pipe = BucketPipeline::new(4);
        let mut got = Vec::new();
        pipe.decode_flat_into(&fp, &mut got).unwrap();
        assert_eq!(got, g);
    }

    /// Parallel decode+reduce must produce bit-identical f64 sums to the
    /// serial decode-then-add loop (same per-element accumulation order).
    #[test]
    fn parallel_reduce_bit_identical_to_serial() {
        let bq = BucketQuantizer::new(200);
        let q = from_name("terngrad").unwrap();
        let uploads: Vec<Vec<u8>> = (0..5)
            .map(|w| {
                let g = sample(1700, 40 + w);
                let mut qg = QuantizedGrad::default();
                bq.quantize_streams_into(&g, q.as_ref(), w, &mut qg);
                codec::encode(&qg, "terngrad", Packing::BaseS)
            })
            .collect();
        // serial reference
        let mut flat = Vec::new();
        let mut sc = DecodeScratch::default();
        let mut want = vec![0.0f64; 1700];
        for u in &uploads {
            codec::decode_flat_into(u, &mut flat, &mut sc).unwrap();
            for (a, v) in want.iter_mut().zip(&flat) {
                *a += *v as f64;
            }
        }
        for threads in [1usize, 2, 3, 8] {
            let mut pipe = BucketPipeline::new(threads);
            let mut acc = Vec::new();
            pipe.decode_reduce_into(&uploads, &mut acc).unwrap();
            assert_eq!(acc, want, "threads={threads}");
        }
    }

    #[test]
    fn reduce_rejects_mismatched_shapes_and_corrupt_bytes() {
        let bq = BucketQuantizer::new(64);
        let q = from_name("terngrad").unwrap();
        let enc = |n: usize, key: u64| {
            let g = sample(n, key);
            let mut qg = QuantizedGrad::default();
            bq.quantize_streams_into(&g, q.as_ref(), key, &mut qg);
            codec::encode(&qg, "terngrad", Packing::BaseS)
        };
        let mut pipe = BucketPipeline::new(4);
        let mut acc = Vec::new();
        let mismatched = vec![enc(128, 1), enc(256, 2)];
        assert!(pipe.decode_reduce_into(&mismatched, &mut acc).is_err());
        let mut corrupt = enc(128, 3);
        corrupt.truncate(corrupt.len() - 3);
        assert!(pipe.decode_reduce_into(&[corrupt], &mut acc).is_err());
        let mut out = Vec::new();
        let mut short = enc(128, 4);
        short.truncate(10);
        assert!(pipe.decode_flat_into(&short, &mut out).is_err());
        // empty upload set reduces to an empty accumulator
        pipe.decode_reduce_into(&[], &mut acc).unwrap();
        assert!(acc.is_empty());
    }

    #[test]
    fn auto_thread_count_is_positive() {
        assert!(BucketPipeline::new(0).threads() >= 1);
        assert_eq!(BucketPipeline::new(3).threads(), 3);
    }
}
