//! ORQ — Optimized Random Quantization (the paper's multi-level method).
//!
//! Theorem 1 gives the stationarity condition for the levels `{b_k}` that
//! minimize the expected random-rounding MSE under *any* distribution
//! p(v); Remark 1.2 / Eq. (12) is its empirical (discrete) form:
//!
//! ```text
//! |{ b_k ≤ v ≤ b_{k+1} }|  =  Σ_{b_{k-1} ≤ v ≤ b_{k+1}} (v − b_{k−1})
//!                             ─────────────────────────────────────────
//!                                        b_{k+1} − b_{k−1}
//! ```
//!
//! Algorithm 1 solves it greedily and recursively for s = 2^K + 1 levels:
//! pin the extreme levels to the support endpoints (Corollary 1.1), solve
//! the midpoint level from Eq. (12) with the endpoints as neighbors, then
//! recurse into each half. On a sorted bucket with prefix sums, each
//! midpoint solve is O(log d) (two binary searches), so level selection is
//! O(d log d) overall — dominated by the sort, matching the paper's
//! "trivial O(D) compared with training" claim.
//!
//! [`OrqQuantizer::with_refinement`] optionally post-processes the greedy
//! solution with coordinate-descent sweeps of the *exact* condition
//! (Eq. 12 applied to every interior level with its true neighbors) — the
//! "future work" improvement the paper's conclusion hints at; the ablation
//! bench (`quant_throughput --ablation`) quantifies what it buys.

use super::scratch::{with_sort_scratch, SortScratch};
use super::{random_round, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

/// Stateless solver configuration: all working memory lives in the
/// per-thread [`SortScratch`] arena (`quant::scratch`), so one quantizer
/// instance can serve many pipeline threads with no lock and no
/// per-bucket allocation. (PR 2 kept this scratch behind a per-quantizer
/// `Mutex`; the tests retain a locked replica and assert bit-identity.)
pub struct OrqQuantizer {
    s: usize,
    refine_sweeps: usize,
}

impl OrqQuantizer {
    /// `s` must be ≥ 2. Paper uses s = 2^K + 1 (3, 5, 9); other s are
    /// supported by splitting the largest interval first (see
    /// [`solve_levels`]).
    pub fn new(s: usize) -> Self {
        assert!(s >= 2, "ORQ needs at least 2 levels");
        OrqQuantizer { s, refine_sweeps: 0 }
    }

    /// Greedy solution + `sweeps` coordinate-descent refinement passes.
    pub fn with_refinement(s: usize, sweeps: usize) -> Self {
        OrqQuantizer { s, refine_sweeps: sweeps }
    }

    /// Solve the optimal levels for a bucket. Exposed for the figure
    /// benches and the property tests.
    pub fn levels_for(&self, g: &[f32]) -> Vec<f32> {
        let mut levels = Vec::with_capacity(self.s);
        with_sort_scratch(|sc| self.solve_into(g, sc, &mut levels));
        levels
    }

    /// Sort + greedy solve + optional refinement through the reused
    /// scratch, writing the levels into `out` (cleared first).
    fn solve_into(&self, g: &[f32], sc: &mut SortScratch, out: &mut Vec<f32>) {
        sc.sorted.clear();
        sc.sorted.extend_from_slice(g);
        sc.sorted.sort_unstable_by(f32::total_cmp);
        let SortScratch { sorted, prefix, stack } = sc;
        solve_levels_into(sorted, self.s, prefix, stack, out);
        // Degenerate buckets (empty/constant) never fill the prefix sums;
        // their synthetic ladders need no refinement anyway.
        if self.refine_sweeps > 0 && !sorted.is_empty() && sorted[sorted.len() - 1] > sorted[0] {
            for _ in 0..self.refine_sweeps {
                if !refine_once(sorted, prefix, out) {
                    break;
                }
            }
        }
    }
}

impl Quantizer for OrqQuantizer {
    fn name(&self) -> String {
        format!("orq-{}", self.s)
    }

    fn num_levels(&self) -> usize {
        self.s
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket) {
        with_sort_scratch(|sc| self.solve_into(g, sc, &mut out.levels));
        random_round(g, &out.levels, rng, &mut out.indices);
    }
}

/// Prefix sums of a sorted bucket: `prefix[i] = Σ sorted[..i]` (f64).
fn prefix_sums(sorted: &[f32]) -> Vec<f64> {
    let mut p = Vec::new();
    prefix_sums_into(sorted, &mut p);
    p
}

/// [`prefix_sums`] into a reused buffer (cleared first).
fn prefix_sums_into(sorted: &[f32], p: &mut Vec<f64>) {
    p.clear();
    p.reserve(sorted.len() + 1);
    p.push(0.0);
    let mut acc = 0.0f64;
    for &v in sorted {
        acc += v as f64;
        p.push(acc);
    }
}

/// First index with `sorted[i] >= x`.
fn lower_bound(sorted: &[f32], x: f32) -> usize {
    sorted.partition_point(|&v| v < x)
}

/// Solve Eq. (12) for the midpoint level given neighbors `(l, r)`:
/// find b with  count{v ∈ [b, r]} = Σ_{v ∈ [l, r]} (v − l) / (r − l),
/// restricted to the sorted index range `[i0, i1)` (the values in [l, r]).
/// Fractional counts are resolved by linear interpolation between order
/// statistics, which makes the solution continuous in the data.
fn solve_mid(sorted: &[f32], prefix: &[f64], i0: usize, i1: usize, l: f32, r: f32) -> f32 {
    let n = i1.saturating_sub(i0);
    if n == 0 || r <= l {
        return 0.5 * (l + r);
    }
    let sum = prefix[i1] - prefix[i0];
    // Target count of elements that should sit in the upper interval [b, r].
    let t = (sum - (l as f64) * n as f64) / ((r - l) as f64);
    let t = t.clamp(0.0, n as f64);
    // b sits at fractional order-statistic position j* = i1 - t.
    let jf = i1 as f64 - t;
    let j0 = jf.floor() as usize;
    let frac = (jf - j0 as f64) as f32;
    let at = |j: usize| -> f32 {
        if j < i0 {
            l
        } else if j >= i1 {
            r
        } else {
            sorted[j]
        }
    };
    let b = at(j0.max(i0).min(i1.saturating_sub(1)));
    let b_next = at((j0 + 1).min(i1.saturating_sub(1)).max(i0));
    let mid = b * (1.0 - frac) + b_next * frac;
    mid.clamp(l, r)
}

/// Algorithm 1: greedy recursive level placement on the sorted bucket.
///
/// For s = 2^K + 1 this is exactly the paper's recursion. For other s the
/// recursion splits the interval containing the most remaining splits
/// first, which degenerates to the same thing for powers of two.
///
/// Allocating reference path; the exchange hot path goes through
/// [`solve_levels_into`] with hoisted scratch, which is asserted
/// bit-identical to this in the tests.
pub fn solve_levels(sorted: &[f32], s: usize) -> Vec<f32> {
    let mut prefix = Vec::new();
    let mut stack = Vec::new();
    let mut levels = Vec::new();
    solve_levels_into(sorted, s, &mut prefix, &mut stack, &mut levels);
    levels
}

/// [`solve_levels`] through caller-owned prefix-sum/stack scratch, writing
/// into `levels` (cleared first). No allocation once the buffers have
/// capacity. `prefix` is left holding the bucket's prefix sums (valid for
/// [`refine_once`]) except on degenerate (empty/constant) buckets.
fn solve_levels_into(
    sorted: &[f32],
    s: usize,
    prefix: &mut Vec<f64>,
    stack: &mut Vec<(usize, usize, f32, f32)>,
    levels: &mut Vec<f32>,
) {
    assert!(s >= 2);
    let n = sorted.len();
    levels.clear();
    if n == 0 {
        // Degenerate: synthesize a strictly increasing ladder around 0.
        levels.extend((0..s).map(|k| k as f32 * 1e-12));
        return;
    }
    let lo = sorted[0];
    let hi = sorted[n - 1];
    if hi - lo <= 0.0 {
        // Constant bucket: ladder of epsilons above the single value so the
        // level vector stays strictly sorted; everything quantizes to lo.
        let eps = (lo.abs() * 1e-6).max(1e-12);
        levels.extend((0..s).map(|k| lo + k as f32 * eps));
        return;
    }
    prefix_sums_into(sorted, prefix);

    // Recursive subdivision: (level_index_l, level_index_r, value_l, value_r).
    levels.resize(s, 0.0);
    levels[0] = lo;
    levels[s - 1] = hi;
    stack.clear();
    stack.push((0usize, s - 1, lo, hi));
    while let Some((kl, kr, vl, vr)) = stack.pop() {
        if kr - kl < 2 {
            continue;
        }
        let km = (kl + kr) / 2;
        let i0 = lower_bound(sorted, vl);
        let i1 = lower_bound(sorted, nextafter_up(vr));
        let vm = solve_mid(sorted, prefix, i0, i1, vl, vr);
        levels[km] = vm;
        stack.push((kl, km, vl, vm));
        stack.push((km, kr, vm, vr));
    }
    enforce_increasing(levels);
}

/// One coordinate-descent sweep of the exact optimality condition over the
/// interior levels, given the bucket's precomputed prefix sums. Returns
/// true if any level moved materially.
fn refine_once(sorted: &[f32], prefix: &[f64], levels: &mut [f32]) -> bool {
    let mut moved = false;
    for k in 1..levels.len() - 1 {
        let l = levels[k - 1];
        let r = levels[k + 1];
        let i0 = lower_bound(sorted, l);
        let i1 = lower_bound(sorted, nextafter_up(r));
        let new = solve_mid(sorted, prefix, i0, i1, l, r);
        if (new - levels[k]).abs() > 1e-7 * (r - l).abs().max(1e-12) {
            moved = true;
        }
        levels[k] = new;
    }
    enforce_increasing(levels);
    moved
}

/// Residual of the discrete optimal condition Eq. (12) at each interior
/// level, normalized by the in-range count (0 = exactly optimal). Used by
/// the property tests and the ablation bench.
pub fn condition_residual(sorted: &[f32], levels: &[f32]) -> Vec<f64> {
    let prefix = prefix_sums(sorted);
    let mut out = Vec::with_capacity(levels.len().saturating_sub(2));
    for k in 1..levels.len().saturating_sub(1) {
        let l = levels[k - 1];
        let b = levels[k];
        let r = levels[k + 1];
        let i0 = lower_bound(sorted, l);
        let ib = lower_bound(sorted, b);
        let i1 = lower_bound(sorted, nextafter_up(r));
        let n_range = (i1 - i0) as f64;
        if n_range == 0.0 || r <= l {
            out.push(0.0);
            continue;
        }
        let lhs = (i1 - ib) as f64; // |{b ≤ v ≤ r}|
        let sum = prefix[i1] - prefix[i0];
        let rhs = (sum - l as f64 * n_range) / ((r - l) as f64);
        out.push((lhs - rhs).abs() / n_range.max(1.0));
    }
    out
}

fn enforce_increasing(levels: &mut [f32]) {
    for i in 1..levels.len() {
        if levels[i] <= levels[i - 1] {
            let eps = (levels[i - 1].abs() * 1e-6).max(1e-12);
            levels[i] = levels[i - 1] + eps;
        }
    }
}

/// Smallest f32 strictly greater than x (for inclusive upper bounds).
fn nextafter_up(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        return x;
    }
    let bits = x.to_bits();
    let next = if x >= 0.0 { bits + 1 } else { bits - 1 };
    f32::from_bits(if x == 0.0 { 1 } else { next })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::expected_rr_mse;
    use crate::quant::linear::LinearQuantizer;
    use crate::quant::qsgd::QsgdQuantizer;

    fn sorted_gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        let mut g: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        g.sort_by(|a, b| a.partial_cmp(b).unwrap());
        g
    }

    #[test]
    fn endpoints_pinned_to_support() {
        // Corollary 1.1: extreme levels == min/max of the bucket.
        let g = sorted_gaussian(2048, 1);
        for s in [3, 5, 9] {
            let lv = solve_levels(&g, s);
            assert_eq!(lv[0], g[0]);
            assert_eq!(*lv.last().unwrap(), *g.last().unwrap());
            assert!(lv.windows(2).all(|w| w[1] > w[0]), "strictly increasing");
        }
    }

    #[test]
    fn uniform_distribution_gives_even_grid() {
        // Remark 1.1: for uniform p the optimal condition collapses to the
        // midpoint rule, i.e. evenly spaced levels.
        let g: Vec<f32> = (0..4097).map(|i| i as f32 / 4096.0).collect();
        let lv = solve_levels(&g, 5);
        for (k, &b) in lv.iter().enumerate() {
            let expect = k as f32 / 4.0;
            assert!((b - expect).abs() < 0.01, "level {k}: {b} vs {expect}");
        }
    }

    #[test]
    fn condition_residual_small_at_solution() {
        let g = sorted_gaussian(8192, 2);
        // Greedy Algorithm 1 is approximate (condition holds w.r.t. the
        // recursion's neighbors, not the final ones) — loose bound.
        let lv = solve_levels(&g, 9);
        for (k, r) in condition_residual(&g, &lv).iter().enumerate() {
            assert!(*r < 0.15, "greedy interior level {k} residual {r}");
        }
        // After coordinate-descent refinement the exact Eq. (12) condition
        // must hold tightly at every interior level.
        let refined = OrqQuantizer::with_refinement(9, 32).levels_for(&g);
        for (k, r) in condition_residual(&g, &refined).iter().enumerate() {
            assert!(*r < 0.01, "refined interior level {k} residual {r}");
        }
    }

    #[test]
    fn orq_beats_qsgd_and_linear_on_gaussian() {
        // The headline property: expected random-rounding MSE of the ORQ
        // levels ≤ evenly spaced (QSGD) and quantile (Linear) levels.
        let g = sorted_gaussian(4096, 3);
        for s in [3usize, 5, 9] {
            let orq_lv = solve_levels(&g, s);
            let m = g.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let qsgd_lv = QsgdQuantizer::grid(s, m);
            let lin_lv = LinearQuantizer::quantile_levels(&g, s);
            let e_orq = expected_rr_mse(&g, &orq_lv);
            let e_qsgd = expected_rr_mse(&g, &qsgd_lv);
            let e_lin = expected_rr_mse(&g, &lin_lv);
            assert!(e_orq <= e_qsgd * 1.001, "s={s}: orq={e_orq} qsgd={e_qsgd}");
            assert!(e_orq <= e_lin * 1.001, "s={s}: orq={e_orq} linear={e_lin}");
        }
    }

    #[test]
    fn refinement_does_not_hurt() {
        let g = sorted_gaussian(4096, 4);
        for s in [5usize, 9] {
            let greedy = OrqQuantizer::new(s).levels_for(&g);
            let refined = OrqQuantizer::with_refinement(s, 8).levels_for(&g);
            let e_g = expected_rr_mse(&g, &greedy);
            let e_r = expected_rr_mse(&g, &refined);
            assert!(e_r <= e_g * 1.01, "s={s}: refined {e_r} vs greedy {e_g}");
        }
    }

    #[test]
    fn constant_and_empty_buckets() {
        let lv = solve_levels(&[], 3);
        assert_eq!(lv.len(), 3);
        let lv = solve_levels(&[2.0; 64], 5);
        assert_eq!(lv.len(), 5);
        assert!(lv.windows(2).all(|w| w[1] > w[0]));
        assert!((lv[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn two_level_solution_is_support() {
        let g = sorted_gaussian(512, 5);
        let lv = solve_levels(&g, 2);
        assert_eq!(lv, vec![g[0], *g.last().unwrap()]);
    }

    #[test]
    fn bimodal_distribution_levels_track_modes() {
        // Two tight clusters at ±1: with s=3 the optimal interior level
        // must sit between them, and the expected MSE should be far below
        // what an evenly spaced grid with the same endpoints... (equal
        // here) — instead check MSE is near zero for s=5 (two levels per
        // mode + midpoint).
        let mut rng = Rng::seed_from(6);
        let mut g: Vec<f32> = (0..2048)
            .map(|i| if i % 2 == 0 { -1.0 } else { 1.0 } + rng.gaussian_f32() * 0.01)
            .collect();
        g.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lv = solve_levels(&g, 5);
        let e = expected_rr_mse(&g, &lv);
        let m = g.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let e_even = expected_rr_mse(&g, &QsgdQuantizer::grid(5, m));
        assert!(e < e_even * 0.25, "bimodal: orq={e} even={e_even}");
    }

    /// The hoisted-scratch hot path must be bit-identical to the
    /// allocating reference solver, including after the scratch has been
    /// dirtied by buckets of very different shapes and sizes.
    #[test]
    fn scratch_reuse_bit_identical_to_allocating_path() {
        let mut data_rng = Rng::seed_from(21);
        let reused = OrqQuantizer::new(5);
        let reused_refined = OrqQuantizer::with_refinement(5, 8);
        for (i, n) in [2048usize, 7, 64, 0, 513, 1, 300].into_iter().enumerate() {
            let g: Vec<f32> = (0..n).map(|_| data_rng.gaussian_f32()).collect();
            let mut sorted = g.clone();
            sorted.sort_unstable_by(f32::total_cmp);
            // allocating reference: fresh sort + fresh solve_levels
            assert_eq!(OrqQuantizer::new(5).levels_for(&g), solve_levels(&sorted, 5), "{n}");
            // greedy path, dirty scratch vs fresh quantizer
            let seed = 100 + i as u64;
            let a = reused.quantize_bucket(&g, &mut Rng::seed_from(seed));
            let b = OrqQuantizer::new(5).quantize_bucket(&g, &mut Rng::seed_from(seed));
            assert_eq!(a, b, "greedy n={n}");
            assert_eq!(a.levels, solve_levels(&sorted, 5), "levels n={n}");
            // refined path too
            let a = reused_refined.quantize_bucket(&g, &mut Rng::seed_from(seed));
            let fresh = OrqQuantizer::with_refinement(5, 8);
            let b = fresh.quantize_bucket(&g, &mut Rng::seed_from(seed));
            assert_eq!(a, b, "refined n={n}");
        }
    }

    /// The per-thread-arena path must be bit-identical to the old
    /// per-quantizer-mutex path (a locked replica of the PR 2 design:
    /// same `solve_levels_into`, scratch behind a `Mutex` instead of the
    /// thread-local arena).
    #[test]
    fn thread_local_scratch_bit_identical_to_locked_path() {
        use std::sync::Mutex;
        let locked = Mutex::new(SortScratch::default());
        let q = OrqQuantizer::new(5);
        let mut data_rng = Rng::seed_from(77);
        for n in [0usize, 1, 7, 300, 513, 2048] {
            let g: Vec<f32> = (0..n).map(|_| data_rng.gaussian_f32()).collect();
            let mut want = Vec::new();
            {
                let mut guard = locked.lock().unwrap();
                let sc = &mut *guard;
                let mut sorted = g.clone();
                sorted.sort_unstable_by(f32::total_cmp);
                solve_levels_into(&sorted, 5, &mut sc.prefix, &mut sc.stack, &mut want);
            }
            assert_eq!(q.levels_for(&g), want, "n={n}");
        }
    }

    /// One shared quantizer instance driven from many threads at once
    /// (the parallel pipeline's access pattern) must produce exactly the
    /// per-bucket results of a serial run — per-thread arenas cannot
    /// interfere.
    #[test]
    fn concurrent_buckets_match_serial() {
        let q = OrqQuantizer::new(9);
        let mut data_rng = Rng::seed_from(31);
        let buckets: Vec<Vec<f32>> = (0..8)
            .map(|i| (0..300 + 40 * i).map(|_| data_rng.gaussian_f32()).collect())
            .collect();
        let serial: Vec<QuantizedBucket> = buckets
            .iter()
            .enumerate()
            .map(|(i, b)| q.quantize_bucket(b, &mut Rng::seed_from(500 + i as u64)))
            .collect();
        std::thread::scope(|scope| {
            for (i, b) in buckets.iter().enumerate() {
                let (q, want) = (&q, &serial[i]);
                scope.spawn(move || {
                    for _ in 0..4 {
                        let got = q.quantize_bucket(b, &mut Rng::seed_from(500 + i as u64));
                        assert_eq!(&got, want, "bucket {i}");
                    }
                });
            }
        });
    }

    #[test]
    fn quantize_bucket_end_to_end() {
        let mut rng = Rng::seed_from(7);
        let g: Vec<f32> = (0..2048).map(|_| rng.gaussian_f32()).collect();
        let q = OrqQuantizer::new(9);
        let qb = q.quantize_bucket(&g, &mut rng);
        assert_eq!(qb.levels.len(), 9);
        assert_eq!(qb.indices.len(), g.len());
        assert!(qb.indices.iter().all(|&i| (i as usize) < 9));
        let deq = qb.dequantize();
        let mse = crate::tensor::mse(&g, &deq);
        assert!(mse < 0.1, "9-level quantization of N(0,1) should be tight: {mse}");
    }
}
