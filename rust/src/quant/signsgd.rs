//! Scaled SignSGD (Bernstein et al. 2018), Eq. (13) of the paper:
//! `Q(G) = (‖G‖₁ / dim(G)) · sign(G)` — deterministic, biased, 1 bit.

use super::{QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

pub struct SignSgdQuantizer;

impl Quantizer for SignSgdQuantizer {
    fn name(&self) -> String {
        "signsgd".into()
    }

    fn num_levels(&self) -> usize {
        2
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn quantize_bucket_into(&self, g: &[f32], _rng: &mut Rng, out: &mut QuantizedBucket) {
        let n = g.len().max(1) as f64;
        let scale = (g.iter().map(|v| v.abs() as f64).sum::<f64>() / n) as f32;
        let scale = if scale > 0.0 { scale } else { 1e-12 };
        out.levels.clear();
        out.levels.extend_from_slice(&[-scale, scale]);
        out.indices.clear();
        out.indices.extend(g.iter().map(|&v| (v >= 0.0) as u8));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_is_mean_abs() {
        let g = [1.0f32, -2.0, 3.0, -4.0];
        let qb = SignSgdQuantizer.quantize_bucket(&g, &mut Rng::seed_from(0));
        assert_eq!(qb.levels, vec![-2.5, 2.5]);
        assert_eq!(qb.indices, vec![1, 0, 1, 0]);
        assert_eq!(qb.dequantize(), vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn preserves_sign_everywhere() {
        let mut rng = Rng::seed_from(1);
        let g: Vec<f32> = (0..1024).map(|_| rng.gaussian_f32()).collect();
        let qb = SignSgdQuantizer.quantize_bucket(&g, &mut rng);
        for (v, d) in g.iter().zip(qb.dequantize()) {
            if *v != 0.0 {
                assert_eq!(v.signum(), d.signum());
            }
        }
    }

    #[test]
    fn l1_norm_preserved() {
        // ‖Q(G)‖₁ = ‖G‖₁ by construction.
        let mut rng = Rng::seed_from(2);
        let g: Vec<f32> = (0..512).map(|_| rng.gaussian_f32() * 3.0).collect();
        let qb = SignSgdQuantizer.quantize_bucket(&g, &mut rng);
        let l1_orig: f64 = g.iter().map(|v| v.abs() as f64).sum();
        let l1_quant: f64 = qb.dequantize().iter().map(|v| v.abs() as f64).sum();
        assert!((l1_orig - l1_quant).abs() / l1_orig < 1e-4);
    }

    #[test]
    fn zero_bucket() {
        let qb = SignSgdQuantizer.quantize_bucket(&[0.0; 16], &mut Rng::seed_from(0));
        assert!(qb.dequantize().iter().all(|v| v.abs() < 1e-6));
    }
}
