//! Per-thread level-solver scratch arenas.
//!
//! The sort-based level solvers (`orq-S`, `linear-S`) need a sorted copy
//! of the bucket plus prefix-sum/recursion buffers. PR 2 hoisted those
//! behind a per-quantizer `Mutex` to keep the `&self` [`super::Quantizer`]
//! interface; that was uncontended with one quantizer per worker, but the
//! parallel bucket pipeline (`super::parallel`) drives *one* quantizer
//! from many threads, where a shared lock would serialize every bucket.
//!
//! Instead each thread owns one [`SortScratch`] arena in a `thread_local`,
//! shared by every solver instance on that thread (the buffers are
//! cleared before each use, so solver output depends only on the input —
//! the scheme tests assert bit-identity against both the allocating
//! reference solvers and a mutex-locked replica of the old path). No
//! locks, no per-bucket allocation once a thread's arena reaches steady
//! state, and the quantizer structs themselves become stateless. On
//! long-lived threads (trainer workers, ring/hier nodes, serial codecs,
//! and the persistent pool workers of `super::pool` — the pipeline's
//! default execution since PR 5) steady state spans the whole run; only
//! the legacy scoped mode (`BucketPipeline::scoped`, retained as the
//! perf baseline) still pays per-round arena regrowth, which is exactly
//! the gap perfbench's `amortization` section measures.

use std::cell::RefCell;

/// Reusable level-solver scratch: the sorted copy of the bucket, its
/// prefix sums, and the recursion stack.
#[derive(Debug, Default)]
pub(crate) struct SortScratch {
    pub(crate) sorted: Vec<f32>,
    pub(crate) prefix: Vec<f64>,
    pub(crate) stack: Vec<(usize, usize, f32, f32)>,
}

thread_local! {
    static ARENA: RefCell<SortScratch> = RefCell::new(SortScratch::default());
}

/// Run `f` with this thread's solver arena. Non-reentrant (the solvers
/// never nest).
pub(crate) fn with_sort_scratch<R>(f: impl FnOnce(&mut SortScratch) -> R) -> R {
    ARENA.with(|cell| f(&mut cell.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_reuses_capacity_within_a_thread() {
        let cap = with_sort_scratch(|sc| {
            sc.sorted.clear();
            sc.sorted.extend_from_slice(&[1.0; 4096]);
            sc.sorted.capacity()
        });
        let cap2 = with_sort_scratch(|sc| {
            assert!(sc.sorted.capacity() >= 4096, "arena persists across calls");
            sc.sorted.clear();
            sc.sorted.capacity()
        });
        assert_eq!(cap, cap2);
    }

    #[test]
    fn arenas_are_independent_per_thread() {
        with_sort_scratch(|sc| {
            sc.sorted.clear();
            sc.sorted.push(7.0);
        });
        std::thread::spawn(|| {
            with_sort_scratch(|sc| assert!(sc.sorted.is_empty(), "fresh arena per thread"));
        })
        .join()
        .unwrap();
    }
}
