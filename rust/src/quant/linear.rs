//! Linear-s: the paper's naive baseline — levels at equal-mass quantiles
//! of the empirical CDF ("linearly dividing the gradient cumulative
//! distribution", §5), random rounding.
//!
//! The paper shows this *loses* to evenly spaced levels because all the
//! levels crowd into the high-density region around zero and the gradient
//! shape information is destroyed (Fig. 1 discussion).

use super::scratch::with_sort_scratch;
use super::{random_round, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

/// Stateless: the sorted-bucket scratch lives in the per-thread arena
/// (`quant::scratch`), so one instance serves many pipeline threads
/// lock-free; see [`super::orq::OrqQuantizer`].
pub struct LinearQuantizer {
    s: usize,
}

impl LinearQuantizer {
    pub fn new(s: usize) -> Self {
        assert!(s >= 2);
        LinearQuantizer { s }
    }

    /// Levels at quantiles k/(s-1) of the sorted bucket, deduplicated with
    /// a strictly-increasing nudge so `random_round`'s invariant holds.
    /// Allocating reference path; the hot path is
    /// [`Self::quantile_levels_into`].
    pub fn quantile_levels(sorted: &[f32], s: usize) -> Vec<f32> {
        let mut levels = Vec::new();
        Self::quantile_levels_into(sorted, s, &mut levels);
        levels
    }

    /// [`Self::quantile_levels`] into a reused buffer (cleared first) —
    /// no allocation once `levels` has capacity.
    pub fn quantile_levels_into(sorted: &[f32], s: usize, levels: &mut Vec<f32>) {
        debug_assert!(!sorted.is_empty());
        let n = sorted.len();
        levels.clear();
        levels.extend((0..s).map(|k| {
            let pos = (k as f64 / (s - 1) as f64) * (n - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                let w = (pos - lo as f64) as f32;
                sorted[lo] * (1.0 - w) + sorted[hi] * w
            }
        }));
        // Strictly increasing: duplicate quantiles (heavy mass at one value)
        // get an epsilon ladder so binary search stays well-defined.
        for i in 1..levels.len() {
            if levels[i] <= levels[i - 1] {
                let eps = (levels[i - 1].abs() * 1e-6).max(1e-12);
                levels[i] = levels[i - 1] + eps;
            }
        }
    }
}

impl Quantizer for LinearQuantizer {
    fn name(&self) -> String {
        format!("linear-{}", self.s)
    }

    fn num_levels(&self) -> usize {
        self.s
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn quantize_bucket_into(&self, g: &[f32], rng: &mut Rng, out: &mut QuantizedBucket) {
        with_sort_scratch(|sc| {
            sc.sorted.clear();
            sc.sorted.extend_from_slice(g);
            sc.sorted.sort_unstable_by(f32::total_cmp);
            Self::quantile_levels_into(&sc.sorted, self.s, &mut out.levels);
        });
        random_round(g, &out.levels, rng, &mut out.indices);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_of_uniform_grid() {
        let sorted: Vec<f32> = (0..=100).map(|i| i as f32).collect();
        let lv = LinearQuantizer::quantile_levels(&sorted, 5);
        assert_eq!(lv, vec![0.0, 25.0, 50.0, 75.0, 100.0]);
    }

    #[test]
    fn endpoints_are_min_max() {
        let mut sorted = vec![-3.0f32, -1.0, 0.0, 0.1, 7.5];
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lv = LinearQuantizer::quantile_levels(&sorted, 3);
        assert_eq!(lv[0], -3.0);
        assert_eq!(*lv.last().unwrap(), 7.5);
    }

    #[test]
    fn handles_mass_at_zero() {
        // 90% zeros: naive quantiles would collapse; we require strictly
        // increasing output.
        let mut g = vec![0.0f32; 90];
        g.extend((1..=10).map(|i| i as f32));
        g.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lv = LinearQuantizer::quantile_levels(&g, 9);
        for w in lv.windows(2) {
            assert!(w[1] > w[0], "levels must be strictly increasing: {lv:?}");
        }
    }

    #[test]
    fn levels_crowd_high_density_region() {
        // Gaussian bucket: linear quantile levels should be denser near 0
        // than near the tails — the failure mode the paper describes.
        let mut rng = Rng::seed_from(11);
        let mut g: Vec<f32> = (0..8192).map(|_| rng.gaussian_f32()).collect();
        g.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lv = LinearQuantizer::quantile_levels(&g, 9);
        let central_gap = lv[5] - lv[4];
        let tail_gap = lv[1] - lv[0];
        assert!(
            central_gap < tail_gap,
            "central {central_gap} should be tighter than tail {tail_gap}"
        );
    }

    /// The hoisted-scratch hot path must be bit-identical to the
    /// allocating reference solver across reuse with different bucket
    /// shapes.
    #[test]
    fn scratch_reuse_bit_identical_to_allocating_path() {
        let mut data_rng = Rng::seed_from(13);
        let reused = LinearQuantizer::new(9);
        for (i, n) in [1024usize, 3, 200, 1, 4096].into_iter().enumerate() {
            let g: Vec<f32> = (0..n).map(|_| data_rng.gaussian_f32()).collect();
            let mut sorted = g.clone();
            sorted.sort_unstable_by(f32::total_cmp);
            let seed = 40 + i as u64;
            let a = reused.quantize_bucket(&g, &mut Rng::seed_from(seed));
            let b = LinearQuantizer::new(9).quantize_bucket(&g, &mut Rng::seed_from(seed));
            assert_eq!(a, b, "n={n}");
            assert_eq!(a.levels, LinearQuantizer::quantile_levels(&sorted, 9), "n={n}");
        }
    }

    #[test]
    fn quantize_bucket_valid_indices() {
        let mut rng = Rng::seed_from(12);
        let g: Vec<f32> = (0..512).map(|_| rng.gaussian_f32()).collect();
        let q = LinearQuantizer::new(5).quantize_bucket(&g, &mut rng);
        assert_eq!(q.levels.len(), 5);
        assert!(q.indices.iter().all(|&i| (i as usize) < 5));
        assert_eq!(q.indices.len(), g.len());
    }
}
