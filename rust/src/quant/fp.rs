//! Full-precision pass-through (the ×1 baseline row of every table).

use super::{QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

/// Identity quantizer. The codec recognizes `num_levels() == 0` and ships
/// raw f32, so `quantize_bucket` is only used by the error-metric paths.
pub struct FpQuantizer;

impl Quantizer for FpQuantizer {
    fn name(&self) -> String {
        "fp".into()
    }

    fn num_levels(&self) -> usize {
        0
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn quantize_bucket_into(&self, g: &[f32], _rng: &mut Rng, out: &mut QuantizedBucket) {
        // Degenerate exact representation: every element is its own level.
        // Only used in metric paths on small buckets; the wire path skips it.
        out.levels.clear();
        out.levels.extend_from_slice(g);
        out.indices.clear();
        out.indices.extend((0..g.len()).map(|i| i as u8));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_on_small_bucket() {
        let g = [0.5f32, -1.0, 2.0];
        let qb = FpQuantizer.quantize_bucket(&g, &mut Rng::seed_from(0));
        assert_eq!(qb.dequantize(), g.to_vec());
    }

    #[test]
    fn reports_fp_bits() {
        assert_eq!(FpQuantizer.bits_per_element(), 32);
        assert!(FpQuantizer.is_unbiased());
    }
}
