//! Tiny CSV writer for metric series and figure data.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    /// Write one row of f64 cells (formatted compactly).
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        debug_assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        let mut line = String::with_capacity(cells.len() * 12);
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format_cell(*c));
        }
        writeln!(self.w, "{line}")?;
        Ok(())
    }

    /// Write one row of mixed string cells.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        debug_assert_eq!(cells.len(), self.cols, "csv row width mismatch");
        writeln!(self.w, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

fn format_cell(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("orq_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&[0.0, 2.5]).unwrap();
            w.row(&[1.0, 2.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,2.500000\n1,2.250000\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn integer_cells_compact() {
        assert_eq!(format_cell(3.0), "3");
        assert_eq!(format_cell(-2.0), "-2");
        assert_eq!(format_cell(0.5), "0.500000");
    }
}
