//! Small shared substrates: JSON parsing, CSV writing, formatting helpers.

pub mod csv;
pub mod fmt;
pub mod json;
