//! Minimal recursive-descent JSON parser.
//!
//! The offline build vendors only the `xla` crate closure (no serde), so
//! the artifact manifest (`artifacts/meta.json`) is parsed with this
//! ~300-line substrate. It supports the full JSON grammar except exotic
//! number forms beyond f64 and does not preserve key order.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Object field access; `None` if not an object or key absent.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The underlying key → value map of an object (`None` otherwise).
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field` access that produces a descriptive error.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Json> {
        self.get(key).ok_or_else(|| Error::Json {
            offset: 0,
            msg: format!("missing required field {key:?}"),
        })
    }

    /// Serialize to a compact JSON string (stable key order — objects
    /// are `BTreeMap`s). Non-finite numbers become `null` (JSON has no
    /// NaN/∞); finite numbers use Rust's shortest-roundtrip formatting,
    /// so `parse(dump(v)) == v`. The `BENCH_*.json` perf artifacts are
    /// written through this.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) if !n.is_finite() => out.push_str("null"),
            Json::Num(n) => {
                use std::fmt::Write;
                let _ = write!(out, "{n}");
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let ch = chunk.chars().next().unwrap();
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let j = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "b": true, "a": [1]}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert!(j.req("missing").is_err());
        assert!(j.req("n").is_ok());
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let j = Json::parse(
            r#"{"a": [1, 2.5, {"b": "c\nd \"q\""}], "n": null, "t": true, "u": "héllo → 世界"}"#,
        )
        .unwrap();
        let dumped = j.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), j);
        // stable output (BTreeMap key order)
        assert_eq!(Json::parse(&dumped).unwrap().dump(), dumped);
        // empty containers and scalars
        assert_eq!(Json::Arr(vec![]).dump(), "[]");
        assert_eq!(Json::Obj(Default::default()).dump(), "{}");
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Bool(false).dump(), "false");
        // JSON has no NaN/∞ — they degrade to null
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        // control characters escape as \u sequences
        assert_eq!(Json::Str("\u{1}".into()).dump(), "\"\\u0001\"");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "models": [
            {"name": "mlp_s", "kind": "classifier", "param_count": 445540,
             "sections": [{"name": "w0", "shape": [256, 512], "init": "he",
                           "fan_in": 256, "size": 131072}]}
          ]
        }"#;
        let j = Json::parse(text).unwrap();
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models[0].get("param_count").unwrap().as_usize(), Some(445540));
        let secs = models[0].get("sections").unwrap().as_arr().unwrap();
        assert_eq!(secs[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
    }
}
