//! Human-readable formatting helpers (sizes, durations, counts, tables).

/// `1234567` -> `"1,234,567"`.
pub fn commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Bytes with binary-ish pragmatic units (paper uses decimal for bandwidth).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Seconds -> adaptive ms/s formatting.
pub fn duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} min", secs / 60.0)
    }
}

/// Render an aligned text table (first row is the header).
pub fn table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.chars().count());
        }
    }
    let mut out = String::new();
    for (ri, r) in rows.iter().enumerate() {
        for (i, c) in r.iter().enumerate() {
            out.push_str(c);
            if i + 1 < r.len() {
                for _ in 0..widths[i].saturating_sub(c.chars().count()) + 2 {
                    out.push(' ');
                }
            }
        }
        out.push('\n');
        if ri == 0 {
            let total: usize = widths.iter().sum::<usize>() + 2 * (cols - 1);
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commas_groups() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(61_100_000), "61,100,000");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(12), "12 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert!(bytes(25_600_000 * 4).contains("MiB"));
    }

    #[test]
    fn duration_units() {
        assert_eq!(duration(0.000_000_5), "500.0 ns");
        assert_eq!(duration(0.000_5), "500.0 µs");
        assert_eq!(duration(0.5), "500.0 ms");
        assert_eq!(duration(1.5), "1.50 s");
        assert!(duration(600.0).contains("min"));
    }

    #[test]
    fn table_aligns() {
        let t = table(&[
            vec!["a".into(), "long-col".into()],
            vec!["xxxx".into(), "y".into()],
        ]);
        let lines: Vec<_> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("---"));
    }
}
