//! Pure-Rust MLP with manual backprop — same math as the JAX `mlp_*`
//! family (ReLU hidden layers, linear head, mean softmax cross-entropy,
//! parameters flattened as `w0, b0, w1, b1, …`).
//!
//! Exists so the Table 2-4 sweeps can run hundreds of configurations
//! without PJRT compile cost, and as a numerics cross-check for the PJRT
//! path (integration test `pjrt_matches_native`).

use super::init::{Init, Section};
use super::Backend;
use crate::data::Batch;
use crate::tensor::rng::Rng;

pub struct NativeMlp {
    pub dims: Vec<usize>, // [in, h1, ..., classes]
    scratch: Scratch,
}

#[derive(Default)]
struct Scratch {
    /// Activations per layer (a[0] = input copy .. a[L] = logits).
    acts: Vec<Vec<f32>>,
    /// Pre-activation ReLU masks for hidden layers.
    masks: Vec<Vec<bool>>,
    /// Backprop delta buffers.
    delta: Vec<f32>,
    delta_next: Vec<f32>,
    probs: Vec<f32>,
}

impl NativeMlp {
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        NativeMlp { dims, scratch: Scratch::default() }
    }

    /// The three CIFAR-substitute architectures of Table 2.
    pub fn mlp_s() -> Self {
        NativeMlp::new(vec![256, 512, 512, 100])
    }

    pub fn mlp_m() -> Self {
        NativeMlp::new(vec![256, 1024, 1024, 1024, 100])
    }

    pub fn mlp_l() -> Self {
        NativeMlp::new(vec![512, 2048, 2048, 2048, 200])
    }

    pub fn layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn sections(&self) -> Vec<Section> {
        let mut out = Vec::new();
        for l in 0..self.layers() {
            let (a, b) = (self.dims[l], self.dims[l + 1]);
            out.push(Section { name: format!("w{l}"), size: a * b, fan_in: a, init: Init::He });
            out.push(Section { name: format!("b{l}"), size: b, fan_in: b, init: Init::Zeros });
        }
        out
    }

    /// `(w_offset, b_offset)` per layer in the flat parameter vector —
    /// the layer structure the overlap section map is seeded from.
    pub fn layer_offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.layers());
        let mut off = 0;
        for l in 0..self.layers() {
            let (a, b) = (self.dims[l], self.dims[l + 1]);
            out.push((off, off + a * b));
            off += a * b + b;
        }
        out
    }

    /// Forward pass; fills scratch activations/masks. Returns nothing —
    /// logits live in `scratch.acts[L]`.
    fn forward(&mut self, params: &[f32], batch: &Batch) {
        let layers = self.layers();
        let b = batch.batch;
        let offsets = self.layer_offsets();
        let s = &mut self.scratch;
        s.acts.resize(layers + 1, Vec::new());
        s.masks.resize(layers.saturating_sub(1), Vec::new());
        s.acts[0].clear();
        s.acts[0].extend_from_slice(&batch.x);
        for l in 0..layers {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = offsets[l];
            let w = &params[wo..wo + din * dout];
            let bias = &params[bo..bo + dout];
            let (inp, out) = {
                // activations[l] -> activations[l+1]
                let (left, right) = s.acts.split_at_mut(l + 1);
                (&left[l], &mut right[0])
            };
            out.clear();
            out.resize(b * dout, 0.0);
            matmul_bias(inp, w, bias, out, b, din, dout);
            if l + 1 < layers {
                let mask = &mut s.masks[l];
                mask.clear();
                mask.reserve(out.len());
                for v in out.iter_mut() {
                    let on = *v > 0.0;
                    mask.push(on);
                    if !on {
                        *v = 0.0;
                    }
                }
            }
        }
    }

    /// Softmax probabilities of current logits into `scratch.probs`;
    /// returns mean CE loss for `labels`.
    fn softmax_loss(&mut self, labels: &[i32]) -> f32 {
        let layers = self.layers();
        let classes = *self.dims.last().unwrap();
        let logits = &self.scratch.acts[layers];
        let b = labels.len();
        let probs = &mut self.scratch.probs;
        probs.clear();
        probs.extend_from_slice(logits);
        let mut loss = 0.0f64;
        for (i, &y) in labels.iter().enumerate() {
            let row = &mut probs[i * classes..(i + 1) * classes];
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f64;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                z += *v as f64;
            }
            for v in row.iter_mut() {
                *v = (*v as f64 / z) as f32;
            }
            loss -= (row[y as usize].max(1e-30) as f64).ln();
        }
        (loss / b as f64) as f32
    }
}

impl Backend for NativeMlp {
    fn name(&self) -> String {
        format!("native-mlp{:?}", self.dims)
    }

    fn param_count(&self) -> usize {
        self.sections().iter().map(|s| s.size).sum()
    }

    fn num_classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        super::init::init_flat(&self.sections(), rng)
    }

    fn loss_grad(&mut self, params: &[f32], batch: &Batch, grad_out: &mut [f32]) -> f32 {
        self.backward(params, batch, grad_out, &mut |_, _| {})
    }

    fn layer_spans(&self) -> Vec<std::ops::Range<usize>> {
        let offsets = self.layer_offsets();
        (0..self.layers())
            .map(|l| offsets[l].0..offsets[l].1 + self.dims[l + 1])
            .collect()
    }

    fn loss_grad_sections(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_ready: &mut dyn FnMut(usize, &[f32]),
    ) -> f32 {
        self.backward(params, batch, grad_out, on_ready)
    }

    fn logits(&mut self, params: &[f32], batch: &Batch) -> Vec<f32> {
        self.forward(params, batch);
        self.scratch.acts[self.layers()].clone()
    }
}

impl NativeMlp {
    /// Manual backprop, reporting each layer's completed gradient slice
    /// through `on_ready` (reverse layer order — the completed region is
    /// the descending suffix `[frontier, n)`) before spending time on
    /// that layer's upstream delta. The callback is pure observation:
    /// loss and gradient are bit-identical for every callback.
    fn backward(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_ready: &mut dyn FnMut(usize, &[f32]),
    ) -> f32 {
        assert_eq!(params.len(), self.param_count(), "param length");
        assert_eq!(grad_out.len(), params.len(), "grad length");
        assert_eq!(batch.in_dim, self.dims[0], "input dim");
        let layers = self.layers();
        let b = batch.batch;
        let offsets = self.layer_offsets();

        self.forward(params, batch);
        let loss = self.softmax_loss(&batch.y);

        grad_out.fill(0.0);
        // delta at output: (softmax - onehot) / B
        let classes = *self.dims.last().unwrap();
        {
            let s = &mut self.scratch;
            s.delta.clear();
            s.delta.extend_from_slice(&s.probs);
            for (i, &y) in batch.y.iter().enumerate() {
                s.delta[i * classes + y as usize] -= 1.0;
            }
            let inv = 1.0 / b as f32;
            for v in s.delta.iter_mut() {
                *v *= inv;
            }
        }

        for l in (0..layers).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let (wo, bo) = offsets[l];
            // dW = a[l]^T · delta ; db = Σ_rows delta
            {
                let a = &self.scratch.acts[l];
                let delta = &self.scratch.delta;
                let dw = &mut grad_out[wo..wo + din * dout];
                for r in 0..b {
                    let arow = &a[r * din..(r + 1) * din];
                    let drow = &delta[r * dout..(r + 1) * dout];
                    for (i, &ai) in arow.iter().enumerate() {
                        if ai != 0.0 {
                            let dst = &mut dw[i * dout..(i + 1) * dout];
                            for (d, &dj) in dst.iter_mut().zip(drow) {
                                *d += ai * dj;
                            }
                        }
                    }
                }
            }
            {
                let delta = &self.scratch.delta;
                let db = &mut grad_out[bo..bo + dout];
                for r in 0..b {
                    for (d, &dj) in db.iter_mut().zip(&delta[r * dout..(r + 1) * dout]) {
                        *d += dj;
                    }
                }
            }
            // Layer l's whole slice (dW then db) is final: report the new
            // frontier before spending time on the upstream delta.
            on_ready(wo, grad_out);
            if l > 0 {
                // delta_prev = (delta · W^T) ⊙ relu'(z[l-1])
                let w = &params[wo..wo + din * dout];
                let s = &mut self.scratch;
                s.delta_next.clear();
                s.delta_next.resize(b * din, 0.0);
                for r in 0..b {
                    let drow = &s.delta[r * dout..(r + 1) * dout];
                    let prev = &mut s.delta_next[r * din..(r + 1) * din];
                    for i in 0..din {
                        let wrow = &w[i * dout..(i + 1) * dout];
                        let mut acc = 0.0f32;
                        for (wj, dj) in wrow.iter().zip(drow) {
                            acc += wj * dj;
                        }
                        prev[i] = acc;
                    }
                }
                let mask = &s.masks[l - 1];
                for (v, &m) in s.delta_next.iter_mut().zip(mask) {
                    if !m {
                        *v = 0.0;
                    }
                }
                std::mem::swap(&mut s.delta, &mut s.delta_next);
            }
        }
        loss
    }
}

/// `out[b,n] = inp[b,k] · w[k,n] + bias[n]` (row-major, k-inner blocked).
fn matmul_bias(inp: &[f32], w: &[f32], bias: &[f32], out: &mut [f32], b: usize, k: usize, n: usize) {
    debug_assert_eq!(inp.len(), b * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), b * n);
    for r in 0..b {
        let orow = &mut out[r * n..(r + 1) * n];
        orow.copy_from_slice(bias);
        let irow = &inp[r * k..(r + 1) * k];
        for (i, &x) in irow.iter().enumerate() {
            if x == 0.0 {
                continue; // ReLU sparsity
            }
            let wrow = &w[i * n..(i + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += x * wv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{ClassDataset, DatasetSpec};

    fn tiny_model_and_batch() -> (NativeMlp, Vec<f32>, Batch) {
        let mut m = NativeMlp::new(vec![8, 16, 4]);
        let params = m.init_params(&mut Rng::seed_from(1));
        let mut rng = Rng::seed_from(2);
        let mut x = vec![0.0f32; 16 * 8];
        rng.fill_gaussian(&mut x, 1.0);
        let y: Vec<i32> = (0..16).map(|_| rng.below(4) as i32).collect();
        let batch = Batch { x, y, batch: 16, in_dim: 8 };
        (m, params, batch)
    }

    #[test]
    fn param_count_matches_formula() {
        let m = NativeMlp::new(vec![256, 512, 512, 100]);
        assert_eq!(m.param_count(), 256 * 512 + 512 + 512 * 512 + 512 + 512 * 100 + 100);
        // same as python registry's mlp_s
        assert_eq!(m.param_count(), 445_540);
    }

    #[test]
    fn loss_at_init_near_log_c() {
        let (mut m, params, batch) = tiny_model_and_batch();
        let mut g = vec![0.0f32; m.param_count()];
        let loss = m.loss_grad(&params, &batch, &mut g);
        assert!((loss - (4.0f32).ln()).abs() < 1.0, "loss={loss}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (mut m, params, batch) = tiny_model_and_batch();
        let p = m.param_count();
        let mut g = vec![0.0f32; p];
        m.loss_grad(&params, &batch, &mut g);
        // directional FD along a random direction
        let mut rng = Rng::seed_from(3);
        let mut v = vec![0.0f32; p];
        rng.fill_gaussian(&mut v, 1.0);
        let norm = crate::tensor::norm2(&v);
        for x in v.iter_mut() {
            *x /= norm;
        }
        let eps = 1e-3f32;
        let mut scratch = vec![0.0f32; p];
        let plus: Vec<f32> = params.iter().zip(&v).map(|(p, d)| p + eps * d).collect();
        let minus: Vec<f32> = params.iter().zip(&v).map(|(p, d)| p - eps * d).collect();
        let lp = m.loss_grad(&plus, &batch, &mut scratch);
        let lm = m.loss_grad(&minus, &batch, &mut scratch);
        let fd = (lp - lm) / (2.0 * eps);
        let analytic = crate::tensor::dot(&g, &v);
        assert!(
            (fd - analytic).abs() < 2e-3 * analytic.abs().max(1.0),
            "fd={fd} analytic={analytic}"
        );
    }

    #[test]
    fn per_coordinate_fd_spot_check() {
        let (mut m, mut params, batch) = tiny_model_and_batch();
        let p = m.param_count();
        let mut g = vec![0.0f32; p];
        m.loss_grad(&params, &batch, &mut g);
        let mut scratch = vec![0.0f32; p];
        for idx in [0usize, 7, p / 2, p - 1] {
            let eps = 1e-2f32;
            let orig = params[idx];
            params[idx] = orig + eps;
            let lp = m.loss_grad(&params, &batch, &mut scratch);
            params[idx] = orig - eps;
            let lm = m.loss_grad(&params, &batch, &mut scratch);
            params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[idx]).abs() < 1e-2 * g[idx].abs().max(0.1),
                "coord {idx}: fd={fd} g={}",
                g[idx]
            );
        }
    }

    #[test]
    fn sgd_learns_separable_task() {
        let spec = DatasetSpec {
            in_dim: 16,
            classes: 4,
            train_n: 512,
            test_n: 256,
            margin: 3.0,
            noise: 0.6,
            label_noise: 0.0,
            seed: 5,
        };
        let ds = ClassDataset::generate(spec);
        let mut m = NativeMlp::new(vec![16, 32, 4]);
        let mut params = m.init_params(&mut Rng::seed_from(6));
        let mut g = vec![0.0f32; m.param_count()];
        let mut rng = Rng::seed_from(7);
        for _ in 0..300 {
            let b = ds.train_batch(32, &mut rng);
            m.loss_grad(&params, &b, &mut g);
            crate::tensor::axpy(-0.1, &g, &mut params);
        }
        // evaluate
        let mut correct = 0.0;
        let mut total = 0.0;
        for b in ds.test_batches(64) {
            let logits = m.logits(&params, &b);
            correct += super::super::topk_accuracy(&logits, &b.y, 4, 1) * b.batch as f64;
            total += b.batch as f64;
        }
        let acc = correct / total;
        assert!(acc > 0.9, "trained accuracy {acc}");
    }

    #[test]
    fn sectioned_backward_bit_identical_and_frontiers_descend() {
        let (mut m, params, batch) = tiny_model_and_batch();
        let p = m.param_count();
        let mut flat = vec![0.0f32; p];
        let loss_flat = m.loss_grad(&params, &batch, &mut flat);

        let mut g = vec![0.0f32; p];
        let mut frontiers = Vec::new();
        let loss = m.loss_grad_sections(&params, &batch, &mut g, &mut |f, grad| {
            assert_eq!(grad.len(), p);
            // the reported suffix is final: it already matches the
            // flat-backward gradient bit for bit
            assert_eq!(&grad[f..], &flat[f..], "suffix [{f}..) not final");
            frontiers.push(f);
        });
        assert_eq!(loss.to_bits(), loss_flat.to_bits());
        assert_eq!(g, flat);

        // one report per layer, reverse layer order, down to 0
        let spans = m.layer_spans();
        assert_eq!(frontiers.len(), spans.len());
        let mut want: Vec<usize> = spans.iter().map(|s| s.start).collect();
        want.reverse();
        assert_eq!(frontiers, want);
        assert_eq!(*frontiers.last().unwrap(), 0);

        // spans tile the parameter vector contiguously
        let mut covered = 0usize;
        for s in &spans {
            assert_eq!(s.start, covered);
            covered = s.end;
        }
        assert_eq!(covered, p);
    }

    #[test]
    fn logits_shape() {
        let (mut m, params, batch) = tiny_model_and_batch();
        let logits = m.logits(&params, &batch);
        assert_eq!(logits.len(), 16 * 4);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
