//! Model backends.
//!
//! [`Backend`] is what a worker calls per step: loss + flat gradient for a
//! minibatch. Two implementations:
//! * [`native::NativeMlp`] — pure-Rust MLP with manual backprop, exactly
//!   the same math as the JAX `mlp_*` models (same section layout, same
//!   He/zeros init recipe). Used by the table benches (fast sweeps, no
//!   artifacts needed) and as the cross-check oracle for the PJRT path.
//! * [`crate::runtime::PjrtBackend`] — executes the AOT-lowered JAX/Pallas
//!   HLO through the PJRT CPU client (the production path).

pub mod init;
pub mod native;

use crate::data::Batch;
use crate::tensor::rng::Rng;

/// A gradient-producing model.
pub trait Backend: Send {
    fn name(&self) -> String;

    fn param_count(&self) -> usize;

    /// Number of output classes (for accuracy metrics).
    fn num_classes(&self) -> usize;

    /// Fresh flat parameter vector per the model's init recipe.
    fn init_params(&self, rng: &mut Rng) -> Vec<f32>;

    /// Compute loss and write the flat gradient into `grad_out`.
    fn loss_grad(&mut self, params: &[f32], batch: &Batch, grad_out: &mut [f32]) -> f32;

    /// Contiguous parameter span of each model layer, in flat-vector
    /// order (spans tile `0..param_count()`). Backends without exposed
    /// layer structure report one whole-vector span; the overlap driver
    /// ([`crate::comm::overlap`]) seeds its section bucket map from this.
    fn layer_spans(&self) -> Vec<std::ops::Range<usize>> {
        vec![0..self.param_count()]
    }

    /// [`Self::loss_grad`] that reports gradient completion while
    /// backward is still running: `on_ready(frontier, grad)` fires
    /// whenever the finished region of `grad_out` grows to
    /// `[frontier, len)` — reverse layer order, so frontiers strictly
    /// descend and reach 0 by return. Loss and gradient are bit-identical
    /// to [`Self::loss_grad`]; the callback is pure observation. The
    /// default computes the full gradient and reports everything at
    /// once — correct for any backend, with no overlap to exploit.
    fn loss_grad_sections(
        &mut self,
        params: &[f32],
        batch: &Batch,
        grad_out: &mut [f32],
        on_ready: &mut dyn FnMut(usize, &[f32]),
    ) -> f32 {
        let loss = self.loss_grad(params, batch, grad_out);
        on_ready(0, grad_out);
        loss
    }

    /// Logits for evaluation, `batch × classes` row-major.
    fn logits(&mut self, params: &[f32], batch: &Batch) -> Vec<f32>;
}

/// Top-k accuracy from row-major logits.
pub fn topk_accuracy(logits: &[f32], labels: &[i32], classes: usize, k: usize) -> f64 {
    if labels.is_empty() {
        return 0.0;
    }
    debug_assert_eq!(logits.len(), labels.len() * classes);
    let mut hits = 0usize;
    for (i, &y) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let target = row[y as usize];
        // count strictly-greater entries; ties resolve in our favor
        let greater = row.iter().filter(|&&v| v > target).count();
        if greater < k {
            hits += 1;
        }
    }
    hits as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_basics() {
        let logits = [0.1f32, 0.9, 0.0, /* row2 */ 0.5, 0.2, 0.3];
        let labels = [1, 0];
        assert_eq!(topk_accuracy(&logits, &labels, 3, 1), 1.0);
        let labels_wrong = [0, 2];
        assert_eq!(topk_accuracy(&logits, &labels_wrong, 3, 1), 0.0);
        assert_eq!(topk_accuracy(&logits, &labels_wrong, 3, 2), 1.0);
        let labels_worst = [2, 1];
        assert_eq!(topk_accuracy(&logits, &labels_worst, 3, 2), 0.0);
        assert_eq!(topk_accuracy(&logits, &labels_worst, 3, 3), 1.0);
    }

    #[test]
    fn topk_empty() {
        assert_eq!(topk_accuracy(&[], &[], 5, 1), 0.0);
    }
}
