//! Parameter initialization from section specs — the Rust mirror of
//! `python/compile/model.py::init_flat` (same recipes, own PRNG).

use crate::tensor::rng::Rng;

/// One named parameter tensor inside the flat vector (mirrors the
/// `sections` entries of `artifacts/meta.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct Section {
    pub name: String,
    pub size: usize,
    pub fan_in: usize,
    pub init: Init,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    He,
    Xavier,
    Normal02,
    Zeros,
    Ones,
}

impl Init {
    pub fn parse(s: &str) -> Option<Init> {
        Some(match s {
            "he" => Init::He,
            "xavier" => Init::Xavier,
            "normal02" => Init::Normal02,
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            _ => return None,
        })
    }
}

/// Materialize the flat parameter vector.
pub fn init_flat(sections: &[Section], rng: &mut Rng) -> Vec<f32> {
    let total: usize = sections.iter().map(|s| s.size).sum();
    let mut out = Vec::with_capacity(total);
    for s in sections {
        match s.init {
            Init::He => {
                let std = (2.0 / s.fan_in.max(1) as f64).sqrt() as f32;
                out.extend((0..s.size).map(|_| rng.gaussian_f32() * std));
            }
            Init::Xavier => {
                let std = (1.0 / s.fan_in.max(1) as f64).sqrt() as f32;
                out.extend((0..s.size).map(|_| rng.gaussian_f32() * std));
            }
            Init::Normal02 => out.extend((0..s.size).map(|_| rng.gaussian_f32() * 0.02)),
            Init::Zeros => out.extend(std::iter::repeat(0.0f32).take(s.size)),
            Init::Ones => out.extend(std::iter::repeat(1.0f32).take(s.size)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recipe_statistics() {
        let secs = vec![
            Section { name: "w".into(), size: 100_000, fan_in: 1000, init: Init::He },
            Section { name: "b".into(), size: 100, fan_in: 100, init: Init::Zeros },
            Section { name: "g".into(), size: 100, fan_in: 100, init: Init::Ones },
        ];
        let flat = init_flat(&secs, &mut Rng::seed_from(1));
        assert_eq!(flat.len(), 100_200);
        let w = &flat[..100_000];
        let mean = w.iter().map(|&v| v as f64).sum::<f64>() / 1e5;
        let std =
            (w.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / 1e5).sqrt();
        let expect = (2.0f64 / 1000.0).sqrt();
        assert!(mean.abs() < 0.001);
        assert!((std - expect).abs() < expect * 0.05, "std={std} expect={expect}");
        assert!(flat[100_000..100_100].iter().all(|&v| v == 0.0));
        assert!(flat[100_100..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn init_parse() {
        assert_eq!(Init::parse("he"), Some(Init::He));
        assert_eq!(Init::parse("xavier"), Some(Init::Xavier));
        assert_eq!(Init::parse("nope"), None);
    }
}
