//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `orq <subcommand> [--key value | --flag]...`

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let subcommand = if it.peek().map(|a| a.starts_with("--")).unwrap_or(false) {
            String::new() // options-only invocation (examples/benches)
        } else {
            it.next().unwrap_or_default()
        };
        let mut out = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::InvalidArg(format!("expected --option, got {a:?}")))?
                .to_string();
            if key.is_empty() {
                return Err(Error::InvalidArg("empty option name".into()));
            }
            // `--key=value` or `--key value` or bare flag
            if let Some((k, v)) = key.split_once('=') {
                out.opts.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                out.opts.insert(key, it.next().unwrap());
            } else {
                out.flags.push(key);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::InvalidArg(format!("--{key}: cannot parse {s:?}"))),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Unknown-option guard: every provided option must be in `known`.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(Error::InvalidArg(format!(
                    "unknown option --{k} (known: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
orq — optimal gradient quantization for distributed training (ORQ/BinGrad)

USAGE:
  orq train [--config FILE] [--model M] [--method Q] [--workers N]
            [--steps N] [--batch N] [--dataset D] [--bucket N] [--clip C]
            [--topology ps|ring|hier|sharded-ps] [--groups N]
            [--shards S] [--staleness K] [--error-feedback]
            [--quantize-downlink] [--threads N]
            [--pool true|false] [--overlap] [--sections N]
            [--stream-sections] [--byte-budget BYTES]
            [--budget-schedule coarse-to-fine] [--backend native|pjrt]
            [--trace FILE] [--trace-level off|round|fine]
            [--intra-bandwidth BPS] [--intra-latency S]
            [--inter-bandwidth BPS] [--inter-latency S]
            [--artifacts DIR] [--out DIR] [--seed N]
  orq info  [--artifacts DIR]          inspect the AOT artifact manifest
  orq demo  [--method Q] [--n N]       quantize a synthetic gradient, show stats
  orq help

METHODS: fp, signsgd, bingrad-pb, bingrad-b, terngrad, qsgd-S, linear-S, orq-S
MODELS (native): mlp_s, mlp_m, mlp_l, mlp:d0-d1-...  (pjrt): names from meta.json
DATASETS: cifar10, cifar100, imagenet
TOPOLOGIES: ps (parameter-server star), ring (decode-reduce-requantize all-reduce),
            hier (intra-group rings + leader star; --groups must divide --workers),
            sharded-ps (bucket-aligned server shards; --shards S, and --staleness K
            lets workers run K rounds ahead of the slowest shard — K=0 synchronous)
LINKS: per edge class — intra (in-group) vs inter (cross-group / flat edges);
       bandwidth in bits/s, one-way latency in seconds (default 10e9 / 0)
THREADS: codec threads per node — 1 serial (default), 0 auto-detect cores,
       N ≥ 2 parallel per-bucket quantize/encode + decode/reduce pipeline
POOL: --pool true (default) runs codec shards, sharded-PS reduce loops and
       drivers on one persistent worker pool (spawns + solver arenas paid
       once per run); --pool false keeps per-round scoped threads —
       bit-identical results, retained as the perf baseline
ERROR FEEDBACK: --error-feedback quantizes g + m and keeps the residual m
       (any topology with a quantizing method; serial or parallel codec).
       On ring/hier each requantization hop carries its own residual; with
       --quantize-downlink the server keeps a downlink residual too
DOWNLINK: --quantize-downlink requantizes the mean broadcast once at the
       aggregation point (ps, hier root, each sharded-ps shard) instead of
       sending it FP — every node still decodes the identical bytes. Not
       applicable to ring (its all-gather chunks already ride encoded)
OVERLAP: --overlap buckets the gradient by model section (--sections N layer
       groups, cut on the bucket grid) and quantizes+encodes each section
       while backward still computes the remaining layers — on the worker
       pool with the parallel codec, or inline on the driver thread at
       --threads 1 (start-anywhere serial encoder) — bit-identical wire
       bytes and trained parameters vs the flat parallel exchange at every
       thread count. Needs a quantizing method. --sections without
       --overlap/--stream-sections is rejected (it would be ignored)
STREAMING: --stream-sections (implies --overlap) pushes each staged section
       into the exchange as a section frame the moment its encode completes,
       so early sections ride the link while the backward tail computes.
       ps/hier/sharded-ps reduce frames in worker order and stay
       bit-identical to the flat overlap run; ring runs one
       reduce-scatter/all-gather per section (deterministic, equivalent to
       its serial replay). Requires --staleness 0
BUDGET: --byte-budget BYTES caps every worker's per-round uplink — headers,
       frames and width tables included. Each round the allocator re-spends
       the method's bit width per bucket (water-filling on per-bucket
       gradient statistics from the previous round's decoded mean,
       deterministic tie-breaking) to minimize total quantization variance
       under the cap; the chosen widths ride in-band in the wire header so
       every hop decodes them from the frame, never assumes them. Needs a
       parameterizable method (orq-S, qsgd-S, linear-S); composes with
       --error-feedback, --overlap/--stream-sections and every topology.
       --budget-schedule coarse-to-fine spends half the budget at round 0
       and ramps linearly to the full budget by round 64 (never exceeding
       the cap). Without --byte-budget the wire bytes are bit-identical to
       the fixed-width codec
TRACING: --trace FILE records the run and writes a Chrome trace-event JSON
       (load it in chrome://tracing or Perfetto; one row per worker, server
       shard and pool thread, on both the wall clock and the simulated link
       clock) plus FILE.metrics.json (per-round series, named counters, and
       the measured-vs-model drift section — < 1% on every topology).
       --trace-level picks the detail: round (phase spans per training
       round), fine (adds collective-interior hops, pool queue waits and
       streamed-section instants; the --trace default). Tracing off costs
       one branch per site; results are bit-identical traced or not
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --model mlp_s --steps 100 --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model"), Some("mlp_s"));
        assert_eq!(a.get_parse::<usize>("steps").unwrap(), Some(100));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("train --method=orq-9 --lr=0.05");
        assert_eq!(a.get("method"), Some("orq-9"));
        assert_eq!(a.get_parse::<f32>("lr").unwrap(), Some(0.05));
    }

    #[test]
    fn defaults_and_missing() {
        let a = parse("info");
        assert_eq!(a.get_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.get_parse::<usize>("steps").unwrap(), None);
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse("train --steps abc");
        assert!(a.get_parse::<usize>("steps").is_err());
        assert!(Args::parse(["train".into(), "loose".into()]).is_err());
    }

    #[test]
    fn unknown_option_guard() {
        let a = parse("train --model mlp_s --typo 1");
        assert!(a.check_known(&["model"]).is_err());
        assert!(a.check_known(&["model", "typo"]).is_ok());
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
