//! Synthetic character corpus for the transformer LM: a low-entropy
//! order-1 Markov chain over a byte vocabulary. Learnable structure
//! (per-state preferred successors) gives the LM a loss floor well below
//! `ln(vocab)`, so a training curve visibly descends.

use crate::tensor::rng::Rng;

pub struct MarkovCorpus {
    pub vocab: usize,
    tokens: Vec<i32>,
}

impl MarkovCorpus {
    /// Generate `len` tokens from a random sparse transition structure:
    /// every state has `branch` preferred successors taking 90% of the
    /// probability mass.
    pub fn generate(vocab: usize, len: usize, branch: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && branch >= 1 && branch < vocab);
        let mut rng = Rng::seed_from(seed);
        // preferred successors per state
        let succ: Vec<Vec<u32>> = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut state = rng.below(vocab as u64) as u32;
        for _ in 0..len {
            tokens.push(state as i32);
            state = if rng.f32() < 0.9 {
                succ[state as usize][rng.below(branch as u64) as usize]
            } else {
                rng.below(vocab as u64) as u32
            };
        }
        MarkovCorpus { vocab, tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// A `[batch, seq+1]` window batch (flat row-major), random offsets.
    pub fn batch(&self, batch: usize, seq: usize, rng: &mut Rng) -> Vec<i32> {
        let window = seq + 1;
        assert!(self.tokens.len() > window, "corpus shorter than one window");
        let mut out = Vec::with_capacity(batch * window);
        for _ in 0..batch {
            let start = rng.below((self.tokens.len() - window) as u64) as usize;
            out.extend_from_slice(&self.tokens[start..start + window]);
        }
        out
    }

    /// Entropy rate estimate of the generating process (nats/token):
    /// H = 0.9·ln(branch/0.9-ish) mix — we just empirically measure the
    /// conditional distribution from the corpus itself.
    pub fn empirical_bigram_entropy(&self) -> f64 {
        let v = self.vocab;
        let mut counts = vec![0u32; v * v];
        let mut row_tot = vec![0u32; v];
        for w in self.tokens.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1;
            row_tot[w[0] as usize] += 1;
        }
        let n: f64 = (self.tokens.len() - 1) as f64;
        let mut h = 0.0;
        for a in 0..v {
            if row_tot[a] == 0 {
                continue;
            }
            let pa = row_tot[a] as f64 / n;
            for b in 0..v {
                let c = counts[a * v + b];
                if c > 0 {
                    let p = c as f64 / row_tot[a] as f64;
                    h -= pa * p * p.ln();
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_length() {
        let c = MarkovCorpus::generate(64, 10_000, 3, 1);
        assert_eq!(c.len(), 10_000);
        assert!(c.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn batch_shape_and_range() {
        let c = MarkovCorpus::generate(32, 5_000, 2, 2);
        let b = c.batch(8, 64, &mut Rng::seed_from(0));
        assert_eq!(b.len(), 8 * 65);
        assert!(b.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn entropy_below_uniform() {
        // Structure must exist: bigram entropy well below ln(vocab).
        let c = MarkovCorpus::generate(64, 100_000, 3, 3);
        let h = c.empirical_bigram_entropy();
        let uniform = (64f64).ln();
        assert!(h < uniform * 0.7, "H={h} vs uniform {uniform}");
        assert!(h > 0.5, "chain should not be deterministic: H={h}");
    }

    #[test]
    fn deterministic() {
        let a = MarkovCorpus::generate(16, 1000, 2, 7);
        let b = MarkovCorpus::generate(16, 1000, 2, 7);
        assert_eq!(a.tokens, b.tokens);
    }
}
