//! Synthetic datasets — the CIFAR/ImageNet substitutes (DESIGN.md §3).
//!
//! Quantizer quality only interacts with the *gradient distribution*, so
//! a Gaussian-mixture classification task with controllable margin/noise
//! reproduces the phenomena the paper measures: bell-shaped heavy-tailed
//! gradients, per-layer scale differences, and accuracy that degrades as
//! quantization coarsens. A Markov-chain character corpus plays the same
//! role for the transformer LM.

pub mod corpus;
pub mod synth;

pub use corpus::MarkovCorpus;
pub use synth::{Batch, ClassDataset, DatasetSpec};
