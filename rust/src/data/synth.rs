//! Gaussian-mixture classification datasets (cifar10/100-like,
//! imagenet-like presets).

use crate::tensor::rng::Rng;

/// One minibatch, row-major features + integer labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub in_dim: usize,
}

/// Dataset generation parameters.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub in_dim: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Distance of class means from the origin (signal strength).
    pub margin: f32,
    /// Per-feature noise std.
    pub noise: f32,
    /// Probability of replacing a label with a uniform random one.
    pub label_noise: f32,
    pub seed: u64,
}

impl DatasetSpec {
    /// CIFAR-10 stand-in: 10 classes, separable but noisy.
    pub fn cifar10_like(in_dim: usize) -> Self {
        DatasetSpec {
            in_dim,
            classes: 10,
            train_n: 8192,
            test_n: 2048,
            margin: 2.2,
            noise: 1.0,
            label_noise: 0.02,
            seed: 1234,
        }
    }

    /// CIFAR-100 stand-in: 100 classes, tighter margins (harder task, so
    /// quantization differences show up as they do in the paper's Table 2).
    pub fn cifar100_like(in_dim: usize) -> Self {
        DatasetSpec {
            in_dim,
            classes: 100,
            train_n: 16384,
            test_n: 4096,
            margin: 2.6,
            noise: 1.0,
            label_noise: 0.02,
            seed: 4321,
        }
    }

    /// ImageNet stand-in: 200 classes (1000 available via `classes`),
    /// larger corpus for the distributed runs of Table 5.
    pub fn imagenet_like(in_dim: usize) -> Self {
        DatasetSpec {
            in_dim,
            classes: 200,
            train_n: 32768,
            test_n: 8192,
            margin: 3.0,
            noise: 1.0,
            label_noise: 0.01,
            seed: 777,
        }
    }
}

/// A materialized classification dataset.
pub struct ClassDataset {
    pub spec: DatasetSpec,
    /// Class means, `classes × in_dim` row-major.
    means: Vec<f32>,
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

impl ClassDataset {
    pub fn generate(spec: DatasetSpec) -> Self {
        let mut rng = Rng::seed_from(spec.seed);
        // Random unit-vector means scaled by margin.
        let mut means = vec![0.0f32; spec.classes * spec.in_dim];
        for c in 0..spec.classes {
            let row = &mut means[c * spec.in_dim..(c + 1) * spec.in_dim];
            rng.fill_gaussian(row, 1.0);
            let n = crate::tensor::norm2(row).max(1e-9);
            for v in row.iter_mut() {
                *v = *v / n * spec.margin;
            }
        }
        let mut ds = ClassDataset {
            means,
            train_x: Vec::new(),
            train_y: Vec::new(),
            test_x: Vec::new(),
            test_y: Vec::new(),
            spec,
        };
        let (tx, ty) = ds.sample_split(ds.spec.train_n, &mut rng, true);
        let (ex, ey) = ds.sample_split(ds.spec.test_n, &mut rng, false);
        ds.train_x = tx;
        ds.train_y = ty;
        ds.test_x = ex;
        ds.test_y = ey;
        ds
    }

    fn sample_split(&self, n: usize, rng: &mut Rng, with_label_noise: bool) -> (Vec<f32>, Vec<i32>) {
        let d = self.spec.in_dim;
        let mut x = vec![0.0f32; n * d];
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.below(self.spec.classes as u64) as usize;
            let row = &mut x[i * d..(i + 1) * d];
            rng.fill_gaussian(row, self.spec.noise);
            for (v, m) in row.iter_mut().zip(&self.means[c * d..(c + 1) * d]) {
                *v += m;
            }
            let label = if with_label_noise && rng.f32() < self.spec.label_noise {
                rng.below(self.spec.classes as u64) as i32
            } else {
                c as i32
            };
            y.push(label);
        }
        (x, y)
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    /// Deterministic random minibatch from the training split.
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        self.batch_from(&self.train_x, &self.train_y, batch, rng)
    }

    /// Sequential test batches for evaluation, final one may be short.
    pub fn test_batches(&self, batch: usize) -> Vec<Batch> {
        let d = self.spec.in_dim;
        let mut out = Vec::new();
        let n = self.test_len();
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            out.push(Batch {
                x: self.test_x[i * d..(i + b) * d].to_vec(),
                y: self.test_y[i..i + b].to_vec(),
                batch: b,
                in_dim: d,
            });
            i += b;
        }
        out
    }

    fn batch_from(&self, xs: &[f32], ys: &[i32], batch: usize, rng: &mut Rng) -> Batch {
        let d = self.spec.in_dim;
        let n = ys.len();
        let mut x = Vec::with_capacity(batch * d);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = rng.below(n as u64) as usize;
            x.extend_from_slice(&xs[i * d..(i + 1) * d]);
            y.push(ys[i]);
        }
        Batch { x, y, batch, in_dim: d }
    }

    /// Shard the training set across `n_workers` (for distributed runs):
    /// worker `w` draws only from its contiguous slice, like the paper's
    /// per-worker minibatch split.
    pub fn worker_batch(&self, worker: usize, n_workers: usize, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.train_len();
        let shard = n / n_workers;
        let start = worker * shard;
        let end = if worker + 1 == n_workers { n } else { start + shard };
        let d = self.spec.in_dim;
        let mut x = Vec::with_capacity(batch * d);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let i = start + rng.below((end - start) as u64) as usize;
            x.extend_from_slice(&self.train_x[i * d..(i + 1) * d]);
            y.push(self.train_y[i]);
        }
        Batch { x, y, batch, in_dim: d }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> DatasetSpec {
        DatasetSpec {
            in_dim: 16,
            classes: 4,
            train_n: 400,
            test_n: 100,
            margin: 3.0,
            noise: 0.5,
            label_noise: 0.0,
            seed: 9,
        }
    }

    #[test]
    fn shapes_and_label_ranges() {
        let ds = ClassDataset::generate(tiny_spec());
        assert_eq!(ds.train_len(), 400);
        assert_eq!(ds.test_len(), 100);
        let mut rng = Rng::seed_from(0);
        let b = ds.train_batch(32, &mut rng);
        assert_eq!(b.x.len(), 32 * 16);
        assert_eq!(b.y.len(), 32);
        assert!(b.y.iter().all(|&y| (0..4).contains(&y)));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = ClassDataset::generate(tiny_spec());
        let b = ClassDataset::generate(tiny_spec());
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }

    #[test]
    fn classes_are_separable_at_high_margin() {
        // Nearest-mean classifier should do well at margin 3, noise 0.5.
        let ds = ClassDataset::generate(tiny_spec());
        let d = ds.spec.in_dim;
        let mut correct = 0usize;
        for (i, &y) in ds.test_y.iter().enumerate() {
            let x = &ds.test_x[i * d..(i + 1) * d];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..ds.spec.classes {
                let m = &ds.means[c * d..(c + 1) * d];
                let dist: f32 = x.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.95, "nearest-mean acc {acc}");
    }

    #[test]
    fn test_batches_cover_everything() {
        let ds = ClassDataset::generate(tiny_spec());
        let batches = ds.test_batches(32);
        let total: usize = batches.iter().map(|b| b.batch).sum();
        assert_eq!(total, 100);
        assert_eq!(batches.last().unwrap().batch, 100 % 32);
    }

    #[test]
    fn worker_shards_disjoint() {
        let ds = ClassDataset::generate(tiny_spec());
        // Worker batches draw from disjoint index ranges; with distinct
        // class means per sample we can't check exact disjointness of
        // values, but determinism per worker stream must hold.
        let b0 = ds.worker_batch(0, 4, 16, &mut Rng::stream(5, 0));
        let b0b = ds.worker_batch(0, 4, 16, &mut Rng::stream(5, 0));
        assert_eq!(b0.x, b0b.x);
        let b1 = ds.worker_batch(1, 4, 16, &mut Rng::stream(5, 1));
        assert_ne!(b0.x, b1.x);
    }

    #[test]
    fn label_noise_applied() {
        let mut spec = tiny_spec();
        spec.label_noise = 1.0; // every label resampled uniformly
        spec.margin = 10.0;
        let ds = ClassDataset::generate(spec);
        // with full label noise, nearest-mean accuracy collapses to ~1/4
        let d = ds.spec.in_dim;
        let mut correct = 0usize;
        for (i, &y) in ds.train_y.iter().enumerate() {
            let x = &ds.train_x[i * d..(i + 1) * d];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..ds.spec.classes {
                let m = &ds.means[c * d..(c + 1) * d];
                let dist: f32 = x.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as i32 == y {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.train_len() as f64;
        assert!(acc < 0.45, "label noise should break the signal, acc={acc}");
    }
}
