//! Training metrics: step series (Fig 2/3), histograms (Fig 1),
//! and the per-run summary the tables report.

pub mod histogram;
pub mod series;

pub use histogram::Histogram;
pub use series::SeriesLogger;

/// Per-step record of a training run (one row of a Fig 2/3 series CSV).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub step: usize,
    pub train_loss: f64,
    /// Relative quantization MSE ‖Q(G)−G‖²/(‖G‖²/D) averaged over workers.
    pub quant_rel_mse: f64,
    /// Cosine similarity between averaged quantized and FP gradient.
    pub quant_cosine: f64,
    /// Exact wire bytes sent this step (all uplinks + broadcast).
    pub wire_bytes: u64,
    /// Uplink share of [`wire_bytes`](Self::wire_bytes) (worker → server / peer sends).
    pub wire_bytes_up: u64,
    /// Downlink share of [`wire_bytes`](Self::wire_bytes) (broadcast / mean frames).
    pub wire_bytes_down: u64,
    /// Simulated communication seconds this step.
    pub comm_time_s: f64,
    /// Closed-form model prediction for this step's communication
    /// seconds (the `*_time` formulas; see the obs model-drift section).
    pub comm_model_time_s: f64,
    /// Maximum gradient age applied this step (sharded-PS staleness;
    /// 0 on synchronous topologies).
    pub staleness_max_age: u64,
}

/// End-of-run summary — one table row.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub method: String,
    pub model: String,
    pub steps: usize,
    pub final_train_loss: f64,
    pub test_top1: f64,
    pub test_top5: f64,
    pub mean_quant_rel_mse: f64,
    pub total_wire_bytes: u64,
    pub total_comm_time_s: f64,
    pub compression_ratio: f64,
}
