//! Step-series logger: accumulates [`StepMetrics`] and writes the
//! Fig 2/Fig 3 CSVs (`step,train_loss,test_top1,quant_rel_mse,...`).

use super::StepMetrics;
use crate::error::Result;
use crate::util::csv::CsvWriter;

#[derive(Debug, Default)]
pub struct SeriesLogger {
    pub steps: Vec<StepMetrics>,
    /// Sparse eval points: (step, top1, top5).
    pub evals: Vec<(usize, f64, f64)>,
    /// Emit the `staleness_max_age` CSV column (sharded-PS runs).
    pub staleness_column: bool,
}

impl SeriesLogger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn push_eval(&mut self, step: usize, top1: f64, top5: f64) {
        self.evals.push((step, top1, top5));
    }

    pub fn mean_rel_mse(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|m| m.quant_rel_mse).sum::<f64>() / self.steps.len() as f64
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.steps.iter().map(|m| m.wire_bytes).sum()
    }

    pub fn total_comm_time(&self) -> f64 {
        self.steps.iter().map(|m| m.comm_time_s).sum()
    }

    pub fn final_loss(&self) -> f64 {
        self.steps.last().map(|m| m.train_loss).unwrap_or(f64::NAN)
    }

    /// Smoothed training loss over the last `window` steps.
    pub fn tail_loss(&self, window: usize) -> f64 {
        if self.steps.is_empty() {
            return f64::NAN;
        }
        let take = window.min(self.steps.len());
        let tail = &self.steps[self.steps.len() - take..];
        tail.iter().map(|m| m.train_loss).sum::<f64>() / take as f64
    }

    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut headers = vec![
            "step",
            "train_loss",
            "quant_rel_mse",
            "quant_cosine",
            "wire_bytes_up",
            "wire_bytes_down",
            "comm_time_s",
        ];
        if self.staleness_column {
            headers.push("staleness_max_age");
        }
        let mut w = CsvWriter::create(path, &headers)?;
        for m in &self.steps {
            let mut row = vec![
                m.step as f64,
                m.train_loss,
                m.quant_rel_mse,
                m.quant_cosine,
                m.wire_bytes_up as f64,
                m.wire_bytes_down as f64,
                m.comm_time_s,
            ];
            if self.staleness_column {
                row.push(m.staleness_max_age as f64);
            }
            w.row(&row)?;
        }
        w.flush()
    }

    pub fn write_eval_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(path, &["step", "top1", "top5"])?;
        for (s, t1, t5) in &self.evals {
            w.row(&[*s as f64, *t1, *t5])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(step: usize, loss: f64) -> StepMetrics {
        StepMetrics { step, train_loss: loss, wire_bytes: 10, ..Default::default() }
    }

    #[test]
    fn aggregates() {
        let mut s = SeriesLogger::new();
        s.push(m(0, 4.0));
        s.push(m(1, 2.0));
        s.push(m(2, 1.0));
        assert_eq!(s.final_loss(), 1.0);
        assert_eq!(s.tail_loss(2), 1.5);
        assert_eq!(s.tail_loss(100), 7.0 / 3.0);
        assert_eq!(s.total_wire_bytes(), 30);
    }

    #[test]
    fn empty_series() {
        let s = SeriesLogger::new();
        assert!(s.final_loss().is_nan());
        assert_eq!(s.mean_rel_mse(), 0.0);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("orq_series_test");
        let path = dir.join("series.csv");
        let mut s = SeriesLogger::new();
        s.push(m(0, 1.0));
        s.push_eval(0, 0.5, 0.9);
        s.write_csv(path.to_str().unwrap()).unwrap();
        s.write_eval_csv(dir.join("eval.csv").to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,train_loss"));
        let header = text.lines().next().unwrap();
        assert!(header.contains("wire_bytes_up,wire_bytes_down"));
        assert!(!header.contains("staleness_max_age"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_staleness_column_on_sharded_runs() {
        let dir = std::env::temp_dir().join("orq_series_staleness_test");
        let path = dir.join("series.csv");
        let mut s = SeriesLogger::new();
        s.staleness_column = true;
        s.push(StepMetrics { step: 0, staleness_max_age: 3, ..Default::default() });
        s.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap();
        assert!(header.ends_with("staleness_max_age"), "{header}");
        let row = text.lines().nth(1).unwrap();
        assert_eq!(header.split(',').count(), row.split(',').count());
        assert!(row.ends_with('3'), "{row}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
