//! Fixed-bin histogram — the data behind Figure 1's gradient-distribution
//! plots (frequency normalized by the max bin, exactly as the paper
//! renders them).

use crate::error::Result;
use crate::util::csv::CsvWriter;

#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    /// Range = ±c·σ of the data (paper clips Figure 1's FP plot to 2.5σ).
    pub fn sigma_range(data: &[f32], c: f64, bins: usize) -> Self {
        let stats = crate::tensor::stats::SliceStats::compute(data);
        let s = stats.std().max(1e-12);
        let mut h = Histogram::new(-c * s, c * s, bins);
        h.fill(data);
        h
    }

    pub fn fill(&mut self, data: &[f32]) {
        for &v in data {
            self.push(v as f64);
        }
    }

    pub fn push(&mut self, v: f64) {
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let bins = self.counts.len();
            let idx = ((v - self.lo) / (self.hi - self.lo) * bins as f64) as usize;
            self.counts[idx.min(bins - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Frequencies normalized by the max bin (the paper's y-axis).
    pub fn normalized(&self) -> Vec<f64> {
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / max).collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let n = self.counts.len();
        let w = (self.hi - self.lo) / n as f64;
        (0..n).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Dump `center,count,normalized` rows.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut w = CsvWriter::create(path, &["center", "count", "normalized"])?;
        let norm = self.normalized();
        for ((c, &cnt), nv) in self.bin_centers().iter().zip(&self.counts).zip(norm) {
            w.row(&[*c, cnt as f64, nv])?;
        }
        w.flush()
    }

    /// Fraction of non-empty bins — the "utilization of quantization
    /// levels" criterion of §5.1.2 when filled with dequantized values.
    pub fn occupancy(&self) -> f64 {
        let used = self.counts.iter().filter(|&&c| c > 0).count();
        used as f64 / self.counts.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn bins_and_flows() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for v in [0.0, 0.5, 9.99, -1.0, 10.0, 5.0] {
            h.push(v);
        }
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 6);
    }

    #[test]
    fn normalized_max_is_one() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.fill(&[-0.9, -0.9, -0.9, 0.1, 0.9]);
        let n = h.normalized();
        assert_eq!(n[0], 1.0);
        assert!(n.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn gaussian_is_bell_shaped() {
        let mut rng = Rng::seed_from(1);
        let g: Vec<f32> = (0..100_000).map(|_| rng.gaussian_f32()).collect();
        let h = Histogram::sigma_range(&g, 2.5, 21);
        let n = h.normalized();
        // center bin is the mode; edges much smaller
        assert_eq!(n[10], 1.0);
        assert!(n[0] < 0.2 && n[20] < 0.2);
    }

    #[test]
    fn occupancy_counts_used_bins() {
        let mut h = Histogram::new(0.0, 4.0, 4);
        h.fill(&[0.5, 2.5]);
        assert_eq!(h.occupancy(), 0.5);
    }

    #[test]
    fn centers_are_midpoints() {
        let h = Histogram::new(0.0, 4.0, 4);
        assert_eq!(h.bin_centers(), vec![0.5, 1.5, 2.5, 3.5]);
    }
}
