//! Crate-wide error type.

/// Unified error for every layer of the stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("json parse error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("codec error: {0}")]
    Codec(String),

    #[error("artifact error: {0} (run `make artifacts`)")]
    Artifact(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("communication error: {0}")]
    Comm(String),

    #[error("invalid argument: {0}")]
    InvalidArg(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
