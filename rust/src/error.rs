//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` offline); the
//! message strings are part of the crate's contract — tests match on them.

use std::fmt;

/// Unified error for every layer of the stack.
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(String),
    Config(String),
    Json { offset: usize, msg: String },
    Codec(String),
    Artifact(String),
    Shape(String),
    Comm(String),
    InvalidArg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(s) => write!(f, "xla/pjrt error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at byte {offset}: {msg}")
            }
            Error::Codec(s) => write!(f, "codec error: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s} (run `make artifacts`)"),
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Comm(s) => write!(f, "communication error: {s}"),
            Error::InvalidArg(s) => write!(f, "invalid argument: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_stable() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(
            Error::Artifact("missing".into()).to_string(),
            "artifact error: missing (run `make artifacts`)"
        );
        assert_eq!(
            Error::Json { offset: 3, msg: "bad".into() }.to_string(),
            "json parse error at byte 3: bad"
        );
    }

    #[test]
    fn io_source_preserved() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().starts_with("io error:"));
    }
}
