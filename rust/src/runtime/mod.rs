//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the
//! CPU PJRT client — the production gradient path.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits protos with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! re-assigns ids (see /opt/xla-example/README.md and DESIGN.md §8).
//!
//! The real engine needs vendored `xla` bindings and is gated behind the
//! `pjrt` cargo feature; without it a stub with the identical API loads
//! manifests fine but errors cleanly on any attempt to execute (so the
//! default offline build stays dependency-free).

pub mod meta;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Engine, LoadedModel, PjrtBackend};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, LoadedModel, PjrtBackend};

#[cfg(test)]
mod tests {
    // The PJRT integration tests live in rust/tests/pjrt_integration.rs —
    // they need built artifacts. Here we only check error paths that do
    // not require a client.
    use super::meta::Manifest;
    use crate::error::Error;

    #[test]
    fn missing_artifacts_dir_errors() {
        let err = Manifest::load("/no/such/dir").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
    }
}
