//! `artifacts/meta.json` manifest — the contract between `python/compile/
//! aot.py` and the Rust runtime.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::model::init::{Init, Section};
use crate::util::json::Json;

/// What kind of model an artifact is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Classifier,
    Lm,
}

/// One model entry of the manifest.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub kind: ModelKind,
    pub param_count: usize,
    pub grad_hlo: String,
    pub fwd_hlo: String,
    pub sections: Vec<Section>,
    /// classifier: (in_dim, classes); lm: (vocab, seq_len)
    pub in_dim: usize,
    pub classes: usize,
    pub batch: usize,
}

/// The whole manifest plus its directory (HLO paths are relative).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    /// Load `<dir>/meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        let json = Json::parse(&text)?;
        let models = json
            .req("models")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("models must be an array".into()))?
            .iter()
            .map(parse_model)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, models })
    }

    pub fn find(&self, name: &str) -> Result<&ModelMeta> {
        self.models.iter().find(|m| m.name == name).ok_or_else(|| {
            Error::Artifact(format!(
                "model {name:?} not in manifest (have: {:?}); rebuild with `make artifacts MODELS=...`",
                self.models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>()
            ))
        })
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_model(j: &Json) -> Result<ModelMeta> {
    let str_field = |k: &str| -> Result<String> {
        Ok(j.req(k)?
            .as_str()
            .ok_or_else(|| Error::Artifact(format!("{k} must be a string")))?
            .to_string())
    };
    let kind = match str_field("kind")?.as_str() {
        "classifier" => ModelKind::Classifier,
        "lm" => ModelKind::Lm,
        other => return Err(Error::Artifact(format!("unknown kind {other:?}"))),
    };
    let cfg = j.req("config")?;
    let cfg_usize = |k: &str| -> Result<usize> {
        cfg.req(k)?
            .as_usize()
            .ok_or_else(|| Error::Artifact(format!("config.{k} must be a number")))
    };
    let (in_dim, classes) = match kind {
        ModelKind::Classifier => (cfg_usize("in_dim")?, cfg_usize("classes")?),
        ModelKind::Lm => (cfg_usize("seq_len")?, cfg_usize("vocab")?),
    };
    let sections = j
        .req("sections")?
        .as_arr()
        .ok_or_else(|| Error::Artifact("sections must be an array".into()))?
        .iter()
        .map(|s| -> Result<Section> {
            let name = s
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Artifact("section name".into()))?
                .to_string();
            let size = s
                .req("size")?
                .as_usize()
                .ok_or_else(|| Error::Artifact("section size".into()))?;
            let fan_in = s
                .req("fan_in")?
                .as_usize()
                .ok_or_else(|| Error::Artifact("section fan_in".into()))?;
            let init_s = s
                .req("init")?
                .as_str()
                .ok_or_else(|| Error::Artifact("section init".into()))?;
            let init = Init::parse(init_s)
                .ok_or_else(|| Error::Artifact(format!("unknown init {init_s:?}")))?;
            Ok(Section { name, size, fan_in, init })
        })
        .collect::<Result<Vec<_>>>()?;
    let param_count = j
        .req("param_count")?
        .as_usize()
        .ok_or_else(|| Error::Artifact("param_count".into()))?;
    let section_total: usize = sections.iter().map(|s| s.size).sum();
    if section_total != param_count {
        return Err(Error::Artifact(format!(
            "sections sum to {section_total} but param_count is {param_count}"
        )));
    }
    Ok(ModelMeta {
        name: str_field("name")?,
        kind,
        param_count,
        grad_hlo: str_field("grad_hlo")?,
        fwd_hlo: str_field("fwd_hlo")?,
        sections,
        in_dim,
        classes,
        batch: cfg_usize("batch")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "models": [{
        "name": "m", "kind": "classifier", "param_count": 10,
        "grad_hlo": "m.grad.hlo.txt", "fwd_hlo": "m.fwd.hlo.txt",
        "sections": [
          {"name": "w0", "shape": [2, 3], "init": "he", "fan_in": 2, "size": 6},
          {"name": "b0", "shape": [4], "init": "zeros", "fan_in": 4, "size": 4}
        ],
        "config": {"in_dim": 2, "classes": 4, "batch": 8, "hidden": [3]}
      }]
    }"#;

    fn write_manifest(text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("orq_meta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), text).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let dir = write_manifest(SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let model = m.find("m").unwrap();
        assert_eq!(model.kind, ModelKind::Classifier);
        assert_eq!(model.param_count, 10);
        assert_eq!(model.sections.len(), 2);
        assert_eq!(model.sections[0].init, Init::He);
        assert_eq!(model.in_dim, 2);
        assert_eq!(model.classes, 4);
        assert_eq!(model.batch, 8);
        assert!(m.find("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_inconsistent_param_count() {
        let bad = SAMPLE.replace("\"param_count\": 10", "\"param_count\": 11");
        let dir = write_manifest(&bad);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_artifact_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(&format!("{dir}/meta.json")).exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(!m.models.is_empty());
            let mlp = m.find("mlp_s").unwrap();
            assert_eq!(mlp.param_count, 445_540);
        }
    }
}
