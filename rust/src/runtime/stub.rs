//! API-compatible stand-in for the PJRT engine when the `pjrt` feature is
//! off (the default offline build).
//!
//! Manifest parsing still works — `orq info` and the meta tests run
//! unchanged — but anything that would execute HLO returns a clean
//! [`Error::Xla`] instead of requiring the vendored `xla` bindings.

use std::path::Path;

use super::meta::{Manifest, ModelMeta};
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::model::Backend;
use crate::tensor::rng::Rng;

fn unavailable() -> Error {
    Error::Xla(
        "PJRT runtime not compiled in (rebuild with `--features pjrt` and vendored xla bindings)"
            .into(),
    )
}

/// Stub PJRT client: construction fails cleanly.
pub struct Engine {
    _priv: (),
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        // Keep the manifest-lookup error behavior of the real engine so
        // "model not found" still beats "pjrt unavailable" in messages.
        let _ = manifest.find(name)?;
        Err(unavailable())
    }
}

/// Stub compiled model. Never constructible through [`Engine`]; the
/// methods exist so callers typecheck identically with the feature off.
pub struct LoadedModel {
    pub meta: ModelMeta,
}

impl LoadedModel {
    pub fn classifier_grad(&self, _params: &[f32], _batch: &Batch) -> Result<(f32, Vec<f32>)> {
        Err(unavailable())
    }

    pub fn classifier_logits(&self, _params: &[f32], _batch: &Batch) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    pub fn lm_grad(&self, _params: &[f32], _tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        Err(unavailable())
    }
}

/// Stub backend adapter; `load` validates the manifest, then reports the
/// missing runtime.
#[derive(Clone)]
pub struct PjrtBackend {
    meta: ModelMeta,
}

impl PjrtBackend {
    pub fn new(model: LoadedModel) -> Self {
        PjrtBackend { meta: model.meta }
    }

    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let _ = manifest.find(model)?;
        Err(unavailable())
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt:{}", self.meta.name)
    }

    fn param_count(&self) -> usize {
        self.meta.param_count
    }

    fn num_classes(&self) -> usize {
        self.meta.classes
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        crate::model::init::init_flat(&self.meta.sections, rng)
    }

    fn loss_grad(&mut self, _params: &[f32], _batch: &Batch, _grad_out: &mut [f32]) -> f32 {
        panic!("{}", unavailable())
    }

    fn logits(&mut self, _params: &[f32], _batch: &Batch) -> Vec<f32> {
        panic!("{}", unavailable())
    }
}
