//! The real PJRT engine (requires the vendored `xla` bindings; compiled
//! only with `--features pjrt`).

use std::path::Path;
use std::sync::{Arc, Mutex};

use super::meta::{Manifest, ModelKind, ModelMeta};
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::model::Backend;
use crate::tensor::rng::Rng;

/// A PJRT client (CPU).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text file.
    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        if !path.exists() {
            return Err(Error::Artifact(format!("missing HLO file {}", path.display())));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Load + compile a manifest model (grad + fwd executables).
    pub fn load_model(&self, manifest: &Manifest, name: &str) -> Result<LoadedModel> {
        let meta = manifest.find(name)?.clone();
        let grad = self.compile_file(&manifest.hlo_path(&meta.grad_hlo))?;
        let fwd = self.compile_file(&manifest.hlo_path(&meta.fwd_hlo))?;
        Ok(LoadedModel { grad, fwd, meta })
    }
}

/// A compiled model: grad + fwd executables and their manifest entry.
pub struct LoadedModel {
    grad: xla::PjRtLoadedExecutable,
    fwd: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
}

// SAFETY: the PJRT C API is thread-safe for execution, and every use in
// this crate goes through `Arc<Mutex<LoadedModel>>`, which serializes
// access anyway. The wrapper types only hold opaque heap pointers owned
// by the XLA runtime; moving them across threads is sound.
unsafe impl Send for LoadedModel {}

impl LoadedModel {
    fn check_params(&self, params: &[f32]) -> Result<()> {
        if params.len() != self.meta.param_count {
            return Err(Error::Shape(format!(
                "params has {} elements, model {} needs {}",
                params.len(),
                self.meta.name,
                self.meta.param_count
            )));
        }
        Ok(())
    }

    /// Classifier step: `(loss, flat_grad)` for one batch. Batch size must
    /// equal the compiled batch (`meta.batch`).
    pub fn classifier_grad(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        self.check_params(params)?;
        if self.meta.kind != ModelKind::Classifier {
            return Err(Error::InvalidArg(format!("{} is not a classifier", self.meta.name)));
        }
        if batch.batch != self.meta.batch || batch.in_dim != self.meta.in_dim {
            return Err(Error::Shape(format!(
                "batch {}×{} does not match compiled {}×{}",
                batch.batch, batch.in_dim, self.meta.batch, self.meta.in_dim
            )));
        }
        let p = xla::Literal::vec1(params);
        let x = xla::Literal::vec1(&batch.x)
            .reshape(&[batch.batch as i64, batch.in_dim as i64])?;
        let y = xla::Literal::vec1(&batch.y);
        let result = self.grad.execute::<xla::Literal>(&[p, x, y])?[0][0].to_literal_sync()?;
        let (loss_lit, grad_lit) = result.to_tuple2()?;
        let loss = loss_lit.get_first_element::<f32>()?;
        let grad = grad_lit.to_vec::<f32>()?;
        Ok((loss, grad))
    }

    /// Classifier logits for one batch (padded internally if short).
    pub fn classifier_logits(&self, params: &[f32], batch: &Batch) -> Result<Vec<f32>> {
        self.check_params(params)?;
        let b = self.meta.batch;
        let d = self.meta.in_dim;
        let mut x = batch.x.clone();
        if batch.batch > b {
            return Err(Error::Shape(format!("batch {} exceeds compiled {b}", batch.batch)));
        }
        x.resize(b * d, 0.0);
        let p = xla::Literal::vec1(params);
        let xl = xla::Literal::vec1(&x).reshape(&[b as i64, d as i64])?;
        let result = self.fwd.execute::<xla::Literal>(&[p, xl])?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(logits[..batch.batch * self.meta.classes].to_vec())
    }

    /// LM step: `(loss, flat_grad)` for a `[batch, seq+1]` token window.
    pub fn lm_grad(&self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        self.check_params(params)?;
        if self.meta.kind != ModelKind::Lm {
            return Err(Error::InvalidArg(format!("{} is not an LM", self.meta.name)));
        }
        let b = self.meta.batch;
        let window = self.meta.in_dim + 1; // seq_len + 1
        if tokens.len() != b * window {
            return Err(Error::Shape(format!(
                "tokens has {} elements, expected {}×{}",
                tokens.len(),
                b,
                window
            )));
        }
        let p = xla::Literal::vec1(params);
        let t = xla::Literal::vec1(tokens).reshape(&[b as i64, window as i64])?;
        let result = self.grad.execute::<xla::Literal>(&[p, t])?[0][0].to_literal_sync()?;
        let (loss_lit, grad_lit) = result.to_tuple2()?;
        Ok((loss_lit.get_first_element::<f32>()?, grad_lit.to_vec::<f32>()?))
    }
}

/// [`Backend`] adapter for classifier artifacts. All clones share one
/// compiled executable behind a mutex (PJRT compile is the expensive part;
/// on a single-core testbed serialized execution costs nothing).
#[derive(Clone)]
pub struct PjrtBackend {
    model: Arc<Mutex<LoadedModel>>,
    name: String,
    param_count: usize,
    classes: usize,
    sections: Vec<crate::model::init::Section>,
}

impl PjrtBackend {
    pub fn new(model: LoadedModel) -> Self {
        let name = format!("pjrt:{}", model.meta.name);
        let param_count = model.meta.param_count;
        let classes = model.meta.classes;
        let sections = model.meta.sections.clone();
        PjrtBackend { model: Arc::new(Mutex::new(model)), name, param_count, classes, sections }
    }

    /// Convenience: load straight from an artifacts dir.
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let engine = Engine::cpu()?;
        Ok(PjrtBackend::new(engine.load_model(&manifest, model)?))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn param_count(&self) -> usize {
        self.param_count
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        crate::model::init::init_flat(&self.sections, rng)
    }

    fn loss_grad(&mut self, params: &[f32], batch: &Batch, grad_out: &mut [f32]) -> f32 {
        let model = self.model.lock().expect("pjrt lock");
        let (loss, grad) = model
            .classifier_grad(params, batch)
            .expect("pjrt classifier_grad failed");
        grad_out.copy_from_slice(&grad);
        loss
    }

    fn logits(&mut self, params: &[f32], batch: &Batch) -> Vec<f32> {
        let model = self.model.lock().expect("pjrt lock");
        model.classifier_logits(params, batch).expect("pjrt logits failed")
    }
}
