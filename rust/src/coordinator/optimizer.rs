//! SGD with momentum + weight decay, PyTorch convention (what the paper's
//! experiments use: momentum 0.9, wd 5e-4 CIFAR / 1e-4 ImageNet):
//!
//! ```text
//! g ← g + wd·p
//! m ← µ·m + g
//! p ← p − lr·m
//! ```

/// SGD + momentum optimizer over a flat parameter vector.
#[derive(Debug, Clone)]
pub struct SgdMomentum {
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<f32>,
}

impl SgdMomentum {
    pub fn new(param_count: usize, momentum: f32, weight_decay: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum));
        SgdMomentum { momentum, weight_decay, velocity: vec![0.0; param_count] }
    }

    /// One update step. `grad` is NOT mutated.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        debug_assert_eq!(params.len(), self.velocity.len());
        debug_assert_eq!(params.len(), grad.len());
        let mu = self.momentum;
        let wd = self.weight_decay;
        for ((p, v), &g) in params.iter_mut().zip(self.velocity.iter_mut()).zip(grad) {
            let g = g + wd * *p;
            *v = mu * *v + g;
            *p -= lr * *v;
        }
    }

    pub fn reset(&mut self) {
        self.velocity.fill(0.0);
    }

    pub fn velocity_norm(&self) -> f32 {
        crate::tensor::norm2(&self.velocity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_when_no_momentum() {
        let mut opt = SgdMomentum::new(2, 0.0, 0.0);
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, 2.05]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = SgdMomentum::new(1, 0.9, 0.0);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        assert_eq!(p, vec![-1.0]);
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_pulls_to_zero() {
        let mut opt = SgdMomentum::new(1, 0.0, 0.1);
        let mut p = vec![10.0f32];
        opt.step(&mut p, &[0.0], 1.0);
        assert_eq!(p, vec![9.0]); // g = 0 + 0.1*10 = 1, p = 10 - 1
    }

    #[test]
    fn matches_pytorch_sequence() {
        // Hand-computed PyTorch SGD(momentum=0.9, wd=0.1, lr=0.1) on p=1,
        // grads [1, 1]:
        // step1: g=1+0.1=1.1, v=1.1, p=1-0.11=0.89
        // step2: g=1+0.089=1.089, v=0.99+1.089=2.079, p=0.89-0.2079=0.6821
        let mut opt = SgdMomentum::new(1, 0.9, 0.1);
        let mut p = vec![1.0f32];
        opt.step(&mut p, &[1.0], 0.1);
        assert!((p[0] - 0.89).abs() < 1e-6, "{}", p[0]);
        opt.step(&mut p, &[1.0], 0.1);
        assert!((p[0] - 0.6821).abs() < 1e-4, "{}", p[0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = SgdMomentum::new(3, 0.9, 0.0);
        let mut p = vec![0.0f32; 3];
        opt.step(&mut p, &[1.0, 1.0, 1.0], 1.0);
        assert!(opt.velocity_norm() > 0.0);
        opt.reset();
        assert_eq!(opt.velocity_norm(), 0.0);
    }
}
