//! Learning-rate schedule: linear warmup from lr/10 (the paper applies a
//! 5-epoch warmup when clipping is enabled) followed by step decay ×0.1
//! at the configured boundaries (paper: epochs 100/150 of 200 on CIFAR,
//! 30/60 of 90 on ImageNet).

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub decay_steps: Vec<usize>,
    pub decay: f32,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: usize, decay_steps: Vec<usize>, decay: f32) -> Self {
        let mut ds = decay_steps;
        ds.sort_unstable();
        LrSchedule { base_lr, warmup_steps, decay_steps: ds, decay }
    }

    /// Learning rate at step `t` (0-based).
    pub fn lr_at(&self, t: usize) -> f32 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            // linear from base/10 to base across warmup
            let frac = t as f32 / self.warmup_steps as f32;
            return self.base_lr * (0.1 + 0.9 * frac);
        }
        let decays = self.decay_steps.iter().filter(|&&d| t >= d).count();
        self.base_lr * self.decay.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_warmup_step_decay() {
        let s = LrSchedule::new(0.1, 0, vec![100, 200], 0.1);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(99), 0.1);
        assert!((s.lr_at(100) - 0.01).abs() < 1e-9);
        assert!((s.lr_at(250) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn warmup_starts_at_tenth() {
        let s = LrSchedule::new(1.0, 10, vec![], 0.1);
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!(s.lr_at(5) > 0.5 && s.lr_at(5) < 0.6);
        assert_eq!(s.lr_at(10), 1.0);
        assert_eq!(s.lr_at(999), 1.0);
    }

    #[test]
    fn warmup_monotone_nondecreasing() {
        let s = LrSchedule::new(0.1, 50, vec![500], 0.1);
        let mut prev = 0.0f32;
        for t in 0..100 {
            let lr = s.lr_at(t);
            assert!(lr >= prev - 1e-9, "t={t}");
            prev = lr;
        }
    }

    #[test]
    fn unsorted_decay_steps_are_sorted() {
        let s = LrSchedule::new(0.1, 0, vec![200, 100], 0.5);
        assert!((s.lr_at(150) - 0.05).abs() < 1e-9);
        assert!((s.lr_at(200) - 0.025).abs() < 1e-9);
    }
}
