//! The training coordinator — paper Algorithm 2 as a runtime.
//!
//! [`trainer::Trainer`] runs synchronous data-parallel SGD: L worker
//! threads each compute a local gradient (native backend or PJRT),
//! solve the quantization levels at runtime, quantize + encode, and ship
//! bytes to the server over the [`crate::comm::ps`] star; the server
//! decodes, averages, (optionally re-quantizes) and broadcasts; every
//! node applies the identical [`optimizer::SgdMomentum`] update so
//! parameters never need to move after initialization.

pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use optimizer::SgdMomentum;
pub use schedule::LrSchedule;
pub use trainer::{Trainer, TrainOutput};
