//! The training coordinator — paper Algorithm 2 as a runtime.
//!
//! [`trainer::Trainer`] runs synchronous data-parallel SGD: L worker
//! threads each compute a local gradient (native backend or PJRT),
//! solve the quantization levels at runtime, quantize + encode, and
//! exchange bytes through a [`crate::comm::Collective`] — the
//! parameter-server star or the decode-reduce-requantize ring all-reduce
//! (`TrainConfig::topology`). Every node applies the identical
//! [`optimizer::SgdMomentum`] update on the identical decoded mean, so
//! parameters never need to move after initialization.

pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use optimizer::SgdMomentum;
pub use schedule::LrSchedule;
pub use trainer::{Trainer, TrainOutput};
