//! Synchronous parameter-server trainer — paper Algorithm 2, threaded.
//!
//! Every worker runs in its own thread with its own [`Backend`] instance,
//! data shard, quantizer RNG stream and optimizer replica. Parameters are
//! initialized identically everywhere (same seed), and because every node
//! applies the identical optimizer update on the identical decoded
//! broadcast Ḡ_t, parameters stay bit-identical across nodes without ever
//! being transmitted — exactly the structure of the paper's Algorithm 2.
//!
//! The server (main thread) gathers the L encoded gradients, decodes and
//! averages them, optionally re-quantizes the downlink (§4 option b), and
//! broadcasts. Wire bytes and simulated comm time come from
//! [`crate::comm`]'s exact accounting.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::codec::{self, Packing};
use crate::comm::link::Link;
use crate::comm::ps::ParameterServer;
use crate::config::TrainConfig;
use crate::coordinator::optimizer::SgdMomentum;
use crate::coordinator::schedule::LrSchedule;
use crate::data::synth::ClassDataset;
use crate::error::{Error, Result};
use crate::metrics::series::SeriesLogger;
use crate::metrics::{RunSummary, StepMetrics};
use crate::model::{topk_accuracy, Backend};
use crate::quant::bucket::BucketQuantizer;
use crate::quant;
use crate::tensor::rng::Rng;

/// Per-step report from one worker (side channel next to the wire path).
struct WorkerReport {
    step: usize,
    loss: f64,
    rel_mse: f64,
    cosine: f64,
}

/// Everything a finished run produces.
pub struct TrainOutput {
    pub summary: RunSummary,
    pub series: SeriesLogger,
    /// Final server-side parameters (identical to every worker's).
    pub params: Vec<f32>,
}

/// The coordinator.
pub struct Trainer<'a> {
    pub cfg: TrainConfig,
    pub link: Link,
    ds: &'a ClassDataset,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: TrainConfig, ds: &'a ClassDataset) -> Result<Self> {
        cfg.validate()?;
        if ds.spec.classes < 5 && cfg.eval_every > 0 {
            // top-5 would be trivially 1.0; allowed, but tables expect ≥5.
        }
        Ok(Trainer { cfg, link: Link::ten_gbps(), ds })
    }

    pub fn with_link(mut self, link: Link) -> Self {
        self.link = link;
        self
    }

    /// Run Algorithm 2 with one backend per node from `make_backend`
    /// (called with worker id 0..L for workers and L for the server's
    /// eval replica).
    pub fn run<F>(&self, make_backend: F) -> Result<TrainOutput>
    where
        F: Fn(usize) -> Box<dyn Backend> + Sync,
    {
        let cfg = &self.cfg;
        let l = cfg.workers;
        let quantizer = quant::from_name(&cfg.method)?;
        let is_fp = quantizer.num_levels() == 0;
        let bucketq = match cfg.clip_factor {
            Some(c) => BucketQuantizer::with_clip(cfg.bucket_size, c),
            None => BucketQuantizer::new(cfg.bucket_size),
        };
        let schedule = LrSchedule::new(
            cfg.lr,
            cfg.warmup_steps,
            cfg.lr_decay_steps.clone(),
            cfg.lr_decay,
        );
        let (mut ps, handles) = ParameterServer::new(l, self.link);
        let (report_tx, report_rx): (Sender<WorkerReport>, Receiver<WorkerReport>) = channel();

        let mut server_backend = make_backend(l);
        let param_count = server_backend.param_count();
        let classes = server_backend.num_classes();
        if classes < self.ds.spec.classes {
            return Err(Error::Shape(format!(
                "model {} has {classes} outputs but dataset has {} classes",
                cfg.model, self.ds.spec.classes
            )));
        }
        let mut server_params = server_backend.init_params(&mut Rng::seed_from(cfg.seed));
        let mut server_opt = SgdMomentum::new(param_count, cfg.momentum, cfg.weight_decay);
        let mut series = SeriesLogger::new();
        let mut out: Result<TrainOutput> = Err(Error::Comm("trainer did not run".into()));

        std::thread::scope(|scope| {
            // ---------------- workers ----------------
            for handle in handles {
                let w = handle.id;
                let cfg = cfg.clone();
                let ds = self.ds;
                let bucketq = bucketq.clone();
                let report_tx = report_tx.clone();
                let make = &make_backend;
                let schedule = schedule.clone();
                scope.spawn(move || {
                    let mut backend = make(w);
                    let quantizer = quant::from_name(&cfg.method).expect("validated");
                    let is_fp = quantizer.num_levels() == 0;
                    let mut params = backend.init_params(&mut Rng::seed_from(cfg.seed));
                    let mut opt =
                        SgdMomentum::new(params.len(), cfg.momentum, cfg.weight_decay);
                    let mut grad = vec![0.0f32; params.len()];
                    let mut rng_data = Rng::stream(cfg.seed, 1_000 + w as u64);
                    let mut rng_q = Rng::stream(cfg.seed, 2_000 + w as u64);
                    let per_worker_batch = cfg.batch / cfg.workers;
                    for t in 0..cfg.steps {
                        let batch = ds.worker_batch(w, cfg.workers, per_worker_batch, &mut rng_data);
                        let loss = backend.loss_grad(&params, &batch, &mut grad);
                        let (bytes, rel_mse, cosine) = if is_fp {
                            (codec::encode_fp(&grad), 0.0, 1.0)
                        } else {
                            let qg = bucketq.quantize(&grad, quantizer.as_ref(), &mut rng_q);
                            let e = crate::quant::error::measure(&grad, &qg);
                            (codec::encode(&qg, &cfg.method, Packing::BaseS), e.rel_mse, e.cosine)
                        };
                        report_tx
                            .send(WorkerReport { step: t, loss: loss as f64, rel_mse, cosine })
                            .expect("server alive");
                        handle.send_grad(bytes).expect("server alive");
                        let bcast = handle.recv_broadcast().expect("server alive");
                        let avg = codec::decode(&bcast).expect("valid broadcast").to_flat();
                        opt.step(&mut params, &avg, schedule.lr_at(t));
                    }
                });
            }
            drop(report_tx);

            // ---------------- server ----------------
            let run_server = || -> Result<TrainOutput> {
                let mut avg = vec![0.0f64; param_count];
                let mut avg32 = vec![0.0f32; param_count];
                let mut rng_down = Rng::stream(cfg.seed, 3_000);
                for t in 0..cfg.steps {
                    let bytes_before = ps.meter.total_bytes();
                    let time_before = ps.sim_time_s;
                    let uploads = ps.gather()?;
                    avg.fill(0.0);
                    for u in &uploads {
                        let flat = codec::decode(u)?.to_flat();
                        if flat.len() != param_count {
                            return Err(Error::Shape(format!(
                                "worker gradient has {} elements, expected {param_count}",
                                flat.len()
                            )));
                        }
                        for (a, v) in avg.iter_mut().zip(flat) {
                            *a += v as f64;
                        }
                    }
                    let inv = 1.0 / l as f64;
                    for (a32, a) in avg32.iter_mut().zip(&avg) {
                        *a32 = (*a * inv) as f32;
                    }
                    let bcast = if cfg.quantize_downlink && !is_fp {
                        let qg = bucketq.quantize(&avg32, quantizer.as_ref(), &mut rng_down);
                        codec::encode(&qg, &cfg.method, Packing::BaseS)
                    } else {
                        codec::encode_fp(&avg32)
                    };
                    ps.broadcast(&bcast)?;
                    // the server applies the decoded broadcast too
                    let applied = codec::decode(&bcast)?.to_flat();
                    server_opt.step(&mut server_params, &applied, schedule.lr_at(t));

                    // drain the L reports for this step
                    let mut loss = 0.0;
                    let mut rel = 0.0;
                    let mut cos = 0.0;
                    for _ in 0..l {
                        let r = report_rx
                            .recv()
                            .map_err(|_| Error::Comm("worker died mid-step".into()))?;
                        debug_assert_eq!(r.step, t);
                        loss += r.loss;
                        rel += r.rel_mse;
                        cos += r.cosine;
                    }
                    series.push(StepMetrics {
                        step: t,
                        train_loss: loss * inv,
                        quant_rel_mse: rel * inv,
                        quant_cosine: cos * inv,
                        wire_bytes: ps.meter.total_bytes() - bytes_before,
                        comm_time_s: ps.sim_time_s - time_before,
                    });

                    if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                        let (t1, t5) =
                            evaluate(server_backend.as_mut(), &server_params, self.ds, classes);
                        series.push_eval(t + 1, t1, t5);
                    }
                }
                let (top1, top5) = evaluate(server_backend.as_mut(), &server_params, self.ds, classes);
                series.push_eval(cfg.steps, top1, top5);
                let ratio = if is_fp {
                    1.0
                } else {
                    codec::compression_ratio(
                        param_count,
                        cfg.bucket_size,
                        quantizer.num_levels(),
                        Packing::BaseS,
                        &cfg.method,
                    )
                };
                let summary = RunSummary {
                    method: cfg.method.clone(),
                    model: cfg.model.clone(),
                    steps: cfg.steps,
                    final_train_loss: series.tail_loss(20),
                    test_top1: top1,
                    test_top5: top5,
                    mean_quant_rel_mse: series.mean_rel_mse(),
                    total_wire_bytes: series.total_wire_bytes(),
                    total_comm_time_s: series.total_comm_time(),
                    compression_ratio: ratio,
                };
                Ok(TrainOutput { summary, series, params: server_params })
            };
            out = run_server();
        });
        // Move the fields back out: run_server consumed them via closure.
        out
    }
}

/// Top-1/top-5 accuracy of `params` on the dataset's test split.
pub fn evaluate(
    backend: &mut dyn Backend,
    params: &[f32],
    ds: &ClassDataset,
    classes: usize,
) -> (f64, f64) {
    let mut top1 = 0.0;
    let mut top5 = 0.0;
    let mut total = 0.0;
    for b in ds.test_batches(64) {
        let logits = backend.logits(params, &b);
        top1 += topk_accuracy(&logits, &b.y, classes, 1) * b.batch as f64;
        top5 += topk_accuracy(&logits, &b.y, classes, 5.min(classes)) * b.batch as f64;
        total += b.batch as f64;
    }
    (top1 / total.max(1.0), top5 / total.max(1.0))
}

/// Convenience: build the native backend named by the config.
pub fn native_backend_factory(model: &str) -> Result<impl Fn(usize) -> Box<dyn Backend> + Sync> {
    use crate::model::native::NativeMlp;
    let dims: Vec<usize> = match model {
        "mlp_s" => vec![256, 512, 512, 100],
        "mlp_m" => vec![256, 1024, 1024, 1024, 100],
        "mlp_l" => vec![512, 2048, 2048, 2048, 200],
        _ if model.starts_with("mlp:") => {
            // "mlp:16-32-4" → custom dims
            let dims: Vec<usize> = model[4..]
                .split('-')
                .map(|p| p.parse().map_err(|_| Error::Config(format!("bad dims {model:?}"))))
                .collect::<Result<_>>()?;
            if dims.len() < 2 {
                return Err(Error::Config("mlp: needs at least 2 dims".into()));
            }
            dims
        }
        _ => {
            return Err(Error::Config(format!(
                "unknown native model {model:?} (use mlp_s/mlp_m/mlp_l or mlp:d0-d1-...)"
            )))
        }
    };
    Ok(move |_id: usize| Box::new(NativeMlp::new(dims.clone())) as Box<dyn Backend>)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetSpec;

    fn tiny_ds() -> ClassDataset {
        ClassDataset::generate(DatasetSpec {
            in_dim: 16,
            classes: 8,
            train_n: 512,
            test_n: 256,
            margin: 3.0,
            noise: 0.6,
            label_noise: 0.0,
            seed: 11,
        })
    }

    fn tiny_cfg(method: &str, workers: usize) -> TrainConfig {
        TrainConfig {
            model: "mlp:16-32-8".into(),
            dataset: "tiny".into(),
            method: method.into(),
            workers,
            batch: 32 * workers,
            steps: 120,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            lr_decay_steps: vec![80],
            lr_decay: 0.1,
            warmup_steps: 0,
            bucket_size: 256,
            clip_factor: None,
            seed: 3,
            eval_every: 0,
            quantize_downlink: false,
        }
    }

    fn run(method: &str, workers: usize) -> TrainOutput {
        let ds = tiny_ds();
        let cfg = tiny_cfg(method, workers);
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
    }

    #[test]
    fn fp_learns_single_worker() {
        let out = run("fp", 1);
        assert!(out.summary.test_top1 > 0.85, "top1={}", out.summary.test_top1);
        assert!(out.summary.final_train_loss < 0.7, "loss={}", out.summary.final_train_loss);
        assert_eq!(out.summary.compression_ratio, 1.0);
    }

    #[test]
    fn orq_learns_and_reports_compression() {
        let out = run("orq-5", 1);
        assert!(out.summary.test_top1 > 0.8, "top1={}", out.summary.test_top1);
        // tiny 808-param model pays heavy per-bucket level-table overhead;
        // large models reach the paper's ×13.8 (see codec tests).
        assert!(out.summary.compression_ratio > 7.0, "{}", out.summary.compression_ratio);
        assert!(out.summary.mean_quant_rel_mse > 0.0);
        assert!(out.summary.total_wire_bytes > 0);
    }

    #[test]
    fn distributed_matches_structure() {
        let out = run("terngrad", 4);
        assert_eq!(out.series.steps.len(), 120);
        assert!(out.summary.test_top1 > 0.6, "top1={}", out.summary.test_top1);
        // 4 uplinks + 1 broadcast per step: bytes > single-worker run
        let single = run("terngrad", 1);
        assert!(out.summary.total_wire_bytes > single.summary.total_wire_bytes);
    }

    #[test]
    fn quantized_uplink_much_smaller_than_fp() {
        let fp = run("fp", 2);
        let q = run("terngrad", 2);
        // FP broadcast dominates the remaining bytes (downlink still FP);
        // with quantize_downlink the gap widens further (separate test).
        assert!(
            (q.summary.total_wire_bytes as f64) < (fp.summary.total_wire_bytes as f64) * 0.5,
            "q={} fp={}",
            q.summary.total_wire_bytes,
            fp.summary.total_wire_bytes
        );
    }

    #[test]
    fn downlink_quantization_shrinks_broadcast() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("orq-3", 2);
        cfg.quantize_downlink = true;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let out = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
        let mut cfg2 = tiny_cfg("orq-3", 2);
        cfg2.quantize_downlink = false;
        let factory2 = native_backend_factory(&cfg2.model).unwrap();
        let out2 = Trainer::new(cfg2, &ds).unwrap().run(factory2).unwrap();
        assert!(out.summary.total_wire_bytes < out2.summary.total_wire_bytes);
        assert!(out.summary.test_top1 > 0.5);
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = run("orq-3", 2);
        let b = run("orq-3", 2);
        assert_eq!(a.params, b.params);
        assert_eq!(a.summary.test_top1, b.summary.test_top1);
    }

    #[test]
    fn rejects_bad_config() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("fp", 3);
        cfg.batch = 32; // not a multiple of 3
        assert!(Trainer::new(cfg, &ds).is_err());
    }
}
