//! Synchronous distributed trainer — paper Algorithm 2, threaded, generic
//! over the gradient-exchange topology.
//!
//! Every worker runs in its own thread with its own [`Backend`] instance,
//! data shard, quantizer RNG stream and optimizer replica. Parameters are
//! initialized identically everywhere (same seed), and because every node
//! applies the identical optimizer update on the identical decoded mean
//! gradient, parameters stay bit-identical across nodes without ever
//! being transmitted — exactly the structure of the paper's Algorithm 2.
//!
//! The exchange itself is behind [`crate::comm::Collective`] /
//! [`crate::comm::WorkerExchange`]: the parameter-server star, the
//! decode-reduce-requantize ring, the two-level hierarchy, or the
//! sharded/bounded-staleness parameter server, chosen by
//! `TrainConfig::topology` (`--topology ps|ring|hier|sharded-ps
//! [--groups N] [--shards S] [--staleness K]`) over the per-edge-class
//! link model of `TrainConfig::links`. With a staleness window `K ≥ 1`
//! every node (coordinator included) applies the round-`t − K` mean at
//! step `t` — replicas still stay bit-identical, just `K` rounds behind
//! the gradients. Wire bytes and simulated comm time come from the
//! collective's exact accounting. Workers can opt into error feedback
//! (`TrainConfig::error_feedback`, any topology, serial or parallel
//! codec): each worker quantizes `g + m` and keeps the residual `m` for
//! its uplink, which rescues the biased schemes (BinGrad-b, signSGD)
//! end-to-end; the flag also arms the collectives' own requantization
//! residuals (one per ring hop position / hierarchy edge, and — with
//! `TrainConfig::quantize_downlink` — a server-side downlink residual).
//! The worker-side residual tracks the *uplink* signal only: the
//! downlink mean arrives already decoded and is applied as-is.
//! The per-round hot loop reuses all of its scratch (quantization
//! buckets, wire messages, decode buffers, and the sort-based level
//! solvers' hoisted sort/prefix scratch): the encode/wire/decode/reduce
//! path performs no per-bucket heap allocation once buffers reach steady
//! state. With `TrainConfig::pool` (the default) all codec shards and
//! sharded-PS reduce loops additionally run on one persistent worker
//! pool (`quant::pool`) shared across the run, so thread spawns and the
//! per-thread solver arenas amortize across *rounds*, not just buckets.
//!
//! With `TrainConfig::overlap` (`--overlap [--sections N]`, quantizing
//! methods) each worker drives its backward through
//! [`crate::comm::overlap::OverlapEncoder`]: the model-section bucket
//! map seeded from [`Backend::layer_spans`] hands every completed
//! section to the worker pool for quantize+encode while the backward
//! tail is still running ([`Backend::loss_grad_sections`]); at
//! `threads == 1` a start-anywhere serial encoder stages the same
//! sections inline on the driver thread under the identical per-bucket
//! RNG discipline. The assembled wire message is byte-identical to the
//! flat *parallel* encode, so overlapped runs train to bit-identical
//! parameters on every topology and error-feedback setting, invariant
//! across thread counts (serial overlap matches parallel overlap, not
//! the legacy single-stream serial encode); under EF the sections stage
//! `g + m` and the residual settles after backward (decode own message
//! → `m ← (g + m) − deq`).
//!
//! With `TrainConfig::stream_sections` (`--stream-sections`, implies
//! `--overlap`) the exchange itself streams: every staged section is
//! pushed into the collective as a standalone
//! [`crate::comm::shard::FrameKind::Section`] frame the moment its
//! encode completes ([`crate::comm::WorkerExchange::push_section`]), so
//! early sections ride the link while the backward tail still computes
//! and the simulated round time shows comm hidden behind compute.
//! ps/hier/sharded-ps reduce section frames in worker order and train
//! bit-identically to the flat overlap exchange; the ring runs one
//! reduce-scatter/all-gather per section — deterministic and
//! thread-count invariant (`threads == 1` *is* the serial replay of the
//! same section schedule), but not bit-identical to the flat ring.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::codec::{self, Packing};
use crate::comm::link::{Link, LinkMap};
use crate::comm::{
    budget_frame_overhead, build_topology, CommStats, ExchangeConfig, GradCodec, OverlapEncoder,
    PoolMode, SectionMap, Topology, WireSpec, SIM_BACKWARD_RATE,
};
use crate::quant::pool::PoolHandle;
use crate::config::TrainConfig;
use crate::coordinator::optimizer::SgdMomentum;
use crate::coordinator::schedule::LrSchedule;
use crate::data::synth::ClassDataset;
use crate::error::{Error, Result};
use crate::metrics::series::SeriesLogger;
use crate::metrics::{RunSummary, StepMetrics};
use crate::model::{topk_accuracy, Backend};
use crate::obs::{MetricsRegistry, TraceRecorder, Track};
use crate::quant;
use crate::quant::bucket::QuantizedGrad;
use crate::tensor::rng::Rng;

/// Per-step report from one worker (side channel next to the wire path).
struct WorkerReport {
    step: usize,
    loss: f64,
    rel_mse: f64,
    cosine: f64,
}

/// Everything a finished run produces.
pub struct TrainOutput {
    pub summary: RunSummary,
    pub series: SeriesLogger,
    /// Final server-side parameters (identical to every worker's).
    pub params: Vec<f32>,
    /// Final cumulative exchange accounting, including the sharded-ps
    /// staleness histogram ([`CommStats::staleness`]).
    pub comm: CommStats,
    /// Exact wire bytes through each server shard (sharded-ps runs;
    /// `None` on the other topologies).
    pub shard_bytes: Option<Vec<u64>>,
    /// Tracing artifacts — the drained span/counter events and the
    /// named-metrics registry. `None` unless
    /// [`TrainConfig::trace_level`] enabled the recorder. Drained after
    /// every thread (workers, shard servers, pool) has quiesced, so all
    /// spans are closed.
    pub obs: Option<ObsReport>,
}

/// The observability payload of a traced run: feed
/// [`ObsReport::events`] to [`crate::obs::chrome_trace_json`] and the
/// registry (with the series) to [`crate::obs::metrics_json`].
pub struct ObsReport {
    /// All recorded events in global record order.
    pub events: Vec<crate::obs::Event>,
    /// Run-wide named counters/gauges (rounds, wire bytes, max staleness
    /// age, setup/train wall seconds).
    pub registry: MetricsRegistry,
}

/// The coordinator.
pub struct Trainer<'a> {
    pub cfg: TrainConfig,
    pub links: LinkMap,
    ds: &'a ClassDataset,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: TrainConfig, ds: &'a ClassDataset) -> Result<Self> {
        cfg.validate()?;
        if ds.spec.classes < 5 && cfg.eval_every > 0 {
            // top-5 would be trivially 1.0; allowed, but tables expect ≥5.
        }
        let links = cfg.link_map();
        Ok(Trainer { cfg, links, ds })
    }

    /// Override the config's link model with a homogeneous link.
    pub fn with_link(mut self, link: Link) -> Self {
        self.links = LinkMap::uniform(link);
        self
    }

    /// Override the config's link model with a per-edge-class map.
    pub fn with_links(mut self, links: LinkMap) -> Self {
        self.links = links;
        self
    }

    /// Run Algorithm 2 with one backend per node from `make_backend`
    /// (called with worker id 0..L for workers and L for the server's
    /// eval replica), over the topology named by the config.
    pub fn run<F>(&self, make_backend: F) -> Result<TrainOutput>
    where
        F: Fn(usize) -> Box<dyn Backend> + Sync,
    {
        let cfg = &self.cfg;
        let l = cfg.workers;
        let quantizer = quant::from_name(&cfg.method)?;
        let is_fp = quantizer.num_levels() == 0;
        let schedule = LrSchedule::new(
            cfg.lr,
            cfg.warmup_steps,
            cfg.lr_decay_steps.clone(),
            cfg.lr_decay,
        );
        // One recorder for the whole run: the WireSpec carries it into
        // every collective end, the pool hands it to its threads, and
        // the worker closures stamp their phase spans through clones.
        // `trace_level = off` (the default) leaves it disabled — one
        // relaxed atomic load per call site, zero allocations, and
        // bit-identical training either way.
        let recorder = TraceRecorder::new(cfg.trace_level);
        let registry = MetricsRegistry::new();
        // One persistent worker pool for the whole run (cfg.pool, the
        // default): every worker's codec, the sharded-PS reduce loops and
        // the parallel decode shards share its threads, so spawn costs
        // and the per-thread level-solver arenas amortize across all
        // steps. `pool = false` keeps the legacy per-round scoped
        // threads (bit-identical results either way).
        let pool_mode = if cfg.pool {
            PoolMode::Shared(PoolHandle::with_recorder(cfg.threads, recorder.clone()))
        } else {
            PoolMode::Scoped
        };
        let spec = WireSpec {
            method: cfg.method.clone(),
            bucket_size: cfg.bucket_size,
            clip_factor: cfg.clip_factor,
            packing: Packing::BaseS,
            seed: cfg.seed,
            threads: cfg.threads,
            pool: pool_mode,
            recorder: recorder.clone(),
        };
        let xcfg = ExchangeConfig {
            topology: cfg.topology,
            groups: cfg.groups,
            shards: cfg.shards,
            staleness: cfg.staleness,
            links: self.links,
            quantize_downlink: cfg.quantize_downlink,
            // Arms the collective-internal residuals (per-hop on
            // ring/hier, server-side downlink with quantize_downlink).
            // The workers' own uplink EF lives in the loop below.
            error_feedback: cfg.error_feedback,
            streaming: cfg.stream_sections,
            sections: cfg.effective_sections(),
        };
        let mut server_backend = make_backend(l);
        let param_count = server_backend.param_count();
        let classes = server_backend.num_classes();
        if cfg.topology == Topology::ShardedPs {
            // Fail early with an actionable message: the worker end would
            // reject this too, but only after the threads have spun up.
            let buckets = param_count.div_ceil(cfg.bucket_size).max(1);
            if cfg.shards > buckets {
                return Err(Error::Config(format!(
                    "shards ({}) exceeds the model's bucket count ({param_count} params \
                     at bucket_size {} = {buckets} buckets); every shard must own at \
                     least one bucket — reduce shards or bucket_size",
                    cfg.shards, cfg.bucket_size
                )));
            }
        }
        if cfg.overlap {
            // Fail early with an actionable message: the worker-side
            // section map would reject this too, but inside a thread.
            let layers = server_backend.layer_spans().len();
            if cfg.effective_sections() > layers {
                return Err(Error::Config(format!(
                    "sections ({}) exceeds the model's layer count ({layers}); every \
                     overlap section needs at least one layer — reduce sections",
                    cfg.effective_sections()
                )));
            }
        }
        // The framing overhead a budgeted uplink pays beyond one flat
        // codec message on this topology (repeated headers on shard
        // slices / ring chunks / hier hops, section frames when
        // streaming). The allocator sees the budget net of this bound,
        // so the wire spend *including every header* stays ≤ the cap.
        let budget_overhead = budget_frame_overhead(
            cfg.topology,
            l,
            cfg.groups,
            cfg.shards,
            cfg.stream_sections.then(|| cfg.effective_sections()),
            &cfg.method,
        );
        if let Some(b) = cfg.byte_budget {
            // Fail early with an actionable message: the cheapest
            // possible round (every bucket at 2 levels) must fit.
            let floor = quant::budget::min_message_bytes(
                param_count,
                cfg.bucket_size,
                Packing::BaseS,
                &cfg.method,
            );
            if (b as usize) < floor + budget_overhead {
                return Err(Error::Config(format!(
                    "byte_budget ({b}) cannot cover the cheapest possible round: \
                     {param_count} params at bucket_size {} need {floor} bytes even \
                     at the 2-level floor, plus {budget_overhead} framing bytes on \
                     this topology — raise --byte-budget to at least {}",
                    cfg.bucket_size,
                    floor + budget_overhead
                )));
            }
        }
        let (mut coll, worker_ends) = build_topology(&xcfg, l, &spec)?;
        let (report_tx, report_rx): (Sender<WorkerReport>, Receiver<WorkerReport>) = channel();
        if classes < self.ds.spec.classes {
            return Err(Error::Shape(format!(
                "model {} has {classes} outputs but dataset has {} classes",
                cfg.model, self.ds.spec.classes
            )));
        }
        let mut server_params = server_backend.init_params(&mut Rng::seed_from(cfg.seed));
        let mut server_opt = SgdMomentum::new(param_count, cfg.momentum, cfg.weight_decay);
        let mut series = SeriesLogger::new();
        // Sharded-PS runs carry the applied-mean age alongside each step.
        series.staleness_column = cfg.topology == Topology::ShardedPs;
        let mut out: Result<TrainOutput> = Err(Error::Comm("trainer did not run".into()));

        std::thread::scope(|scope| {
            // ---------------- workers ----------------
            for (w, mut wx) in worker_ends.into_iter().enumerate() {
                let cfg = cfg.clone();
                let ds = self.ds;
                let spec = spec.clone();
                let report_tx = report_tx.clone();
                let make = &make_backend;
                let schedule = schedule.clone();
                let rec = recorder.clone();
                scope.spawn(move || {
                    // Every phase span this worker emits lands on its own
                    // track — only this thread writes spans there, so
                    // nesting is race-free by construction. (Collectives
                    // only put *instants* on worker tracks.)
                    let track = Track::Worker(w as u16);
                    let on = rec.is_enabled();
                    let mut backend = make(w);
                    // One encoder per worker, built from the same WireSpec
                    // the collective uses — a single quantize+encode path
                    // (parallel across buckets when cfg.threads != 1).
                    let mut gc = GradCodec::new(&spec).expect("validated");
                    // Arm the adaptive byte budget: per-round width
                    // tables minimize quantization variance under the
                    // configured uplink cap net of framing overhead
                    // (validated against the 2-level floor above).
                    if let Some(b) = cfg.byte_budget {
                        let sched = cfg
                            .budget_schedule
                            .as_deref()
                            .map(quant::budget::BudgetSchedule::parse)
                            .transpose()
                            .expect("validated");
                        gc.set_budget(b as usize - budget_overhead, sched)
                            .expect("validated");
                    }
                    let mut params = backend.init_params(&mut Rng::seed_from(cfg.seed));
                    let mut opt =
                        SgdMomentum::new(params.len(), cfg.momentum, cfg.weight_decay);
                    let mut grad = vec![0.0f32; params.len()];
                    let mut rng_data = Rng::stream(cfg.seed, 1_000 + w as u64);
                    let mut rng_q = Rng::stream(cfg.seed, 2_000 + w as u64);
                    // Round-persistent scratch: the exchange path allocates
                    // nothing per bucket once these reach steady state.
                    let mut qg = QuantizedGrad::default();
                    let mut msg: Vec<u8> = Vec::new();
                    let mut mean: Vec<f32> = Vec::new();
                    let mut deq: Vec<f32> = Vec::new();
                    // Opt-in error feedback (validated: any topology
                    // with a quantizing method; serial or parallel
                    // codec): quantize g + m instead of g, keep the
                    // residual m ← (g + m) − Q(g + m). The residual
                    // tracks this worker's own uplink — the exchanged
                    // mean (quantized downlink or not) never feeds it.
                    let mut ef = cfg.error_feedback.then(|| gc.error_feedback());
                    // Overlapped backward+encode (quantizing methods):
                    // sections of the gradient hit the worker pool as
                    // backward completes them; at threads == 1 the
                    // start-anywhere serial encoder stages the same
                    // sections inline on the driver thread (identical
                    // bytes — the per-bucket RNG discipline is
                    // thread-count invariant).
                    let mut overlap = if cfg.overlap && !gc.is_fp() {
                        let map = SectionMap::new(
                            &backend.layer_spans(),
                            cfg.effective_sections(),
                            cfg.bucket_size,
                        )
                        .expect("checked before spawn");
                        let mut ov =
                            OverlapEncoder::new(&spec, map).expect("checked before spawn");
                        ov.set_track(track);
                        Some(ov)
                    } else {
                        None
                    };
                    // Streamed rounds gate each section frame at its
                    // deterministic readiness stamp — the same schedule
                    // on every worker, so the stamps ride in-band and the
                    // coordinator replays the pipeline recurrence.
                    let ready_at = overlap
                        .as_ref()
                        .filter(|_| cfg.stream_sections)
                        .map(|ov| ov.map().ready_schedule(SIM_BACKWARD_RATE));
                    let per_worker_batch = cfg.batch / cfg.workers;
                    for t in 0..cfg.steps {
                        let batch = ds.worker_batch(w, cfg.workers, per_worker_batch, &mut rng_data);
                        // Overlapped rounds interleave backward with
                        // staging/encode on purpose — one fused span;
                        // flat rounds split backward from the encode.
                        if on {
                            rec.begin(
                                track,
                                if overlap.is_some() { "backward_encode" } else { "backward" },
                            );
                        }
                        let loss = match &mut overlap {
                            Some(ov) => {
                                let n = grad.len();
                                // Hand the round's width table (if a
                                // budget is armed) to the overlap
                                // encoder; `None` keeps the fixed-width
                                // encode bit-identical to PR 9.
                                ov.set_widths(gc.round_widths(n))
                                    .expect("table matches the bucket grid");
                                let memory = ef.as_mut().map(|e| e.residual(n));
                                match &ready_at {
                                    Some(ready) => {
                                        // Push every staged section into
                                        // the collective immediately; the
                                        // flat message still assembles
                                        // into `msg` for the EF settle
                                        // and the fidelity figures.
                                        let streamed = ov.encode_streamed(
                                            memory,
                                            &mut rng_q,
                                            &mut msg,
                                            ready,
                                            &mut |sec, payload, r| wx.push_section(sec, payload, r),
                                            |cb| {
                                                backend.loss_grad_sections(
                                                    &params, &batch, &mut grad, cb,
                                                )
                                            },
                                        );
                                        match streamed {
                                            Ok(loss) => loss,
                                            // coordinator gone; it
                                            // reports the error
                                            Err(_) => return,
                                        }
                                    }
                                    None => {
                                        ov.encode_overlapped(memory, &mut rng_q, &mut msg, |cb| {
                                            backend.loss_grad_sections(
                                                &params, &batch, &mut grad, cb,
                                            )
                                        })
                                    }
                                }
                            }
                            None => {
                                let loss = backend.loss_grad(&params, &batch, &mut grad);
                                if on {
                                    rec.end(track, "backward");
                                    rec.begin(track, "quantize_encode");
                                }
                                match &mut ef {
                                    Some(ef) => {
                                        gc.encode_ef_into(ef, &grad, &mut rng_q, &mut qg, &mut msg)
                                    }
                                    None => gc.encode_into(&grad, &mut rng_q, &mut qg, &mut msg),
                                }
                                loss
                            }
                        };
                        if on {
                            rec.end(
                                track,
                                if overlap.is_some() { "backward_encode" } else { "quantize_encode" },
                            );
                        }
                        if overlap.is_some() {
                            // Settle the overlapped round: decode our own
                            // message (exact dequantization of the
                            // transmitted signal) for the figures, and with
                            // EF the residual update m ← (g + m) − deq.
                            if on {
                                rec.begin(track, "ef_settle");
                            }
                            gc.decode_flat_into(&msg, &mut deq)
                                .expect("own encoding always decodes");
                            if let Some(ef) = &mut ef {
                                ef.compensate(&grad);
                                ef.update_residual(&deq);
                            }
                            if on {
                                rec.end(track, "ef_settle");
                            }
                        }
                        // With EF the figures measure Q(g + m) against the
                        // raw g — the transmitted signal's fidelity to the
                        // current gradient, residual included.
                        let (rel_mse, cosine) = if gc.is_fp() {
                            (0.0, 1.0)
                        } else if overlap.is_some() {
                            // deq holds decode(msg) from the settle step —
                            // the same numbers as the flat branches below.
                            let e = quant::error::measure_flat(&grad, &deq);
                            (e.rel_mse, e.cosine)
                        } else if gc.is_parallel() || gc.has_budget() {
                            // The pipeline — and the serial budgeted
                            // encode — never materialize `qg`; measure
                            // via the wire bytes instead
                            // (decode(encode(g)) == dequantize exactly).
                            // With EF the codec already decoded its
                            // own message for the residual — reuse that
                            // buffer instead of decoding twice.
                            let e = if ef.is_some() {
                                let d = gc.ef_dequant().expect("EF codec keeps its dequant");
                                quant::error::measure_flat(&grad, d)
                            } else {
                                gc.decode_flat_into(&msg, &mut deq)
                                    .expect("own encoding always decodes");
                                quant::error::measure_flat(&grad, &deq)
                            };
                            (e.rel_mse, e.cosine)
                        } else {
                            let e = quant::error::measure_into(&grad, &qg, &mut deq);
                            (e.rel_mse, e.cosine)
                        };
                        if report_tx
                            .send(WorkerReport { step: t, loss: loss as f64, rel_mse, cosine })
                            .is_err()
                        {
                            return; // coordinator gone; it reports the error
                        }
                        if on {
                            rec.begin(track, "exchange");
                        }
                        let exchanged = if ready_at.is_some() {
                            // Sections are already on the wire; block for
                            // the round's decoded mean.
                            wx.finish_streamed(&mut mean)
                        } else {
                            wx.exchange(&mut msg, &mut mean)
                        };
                        if on {
                            rec.end(track, "exchange");
                        }
                        if exchanged.is_err() {
                            return; // ditto — avoid deadlocking the scope
                        }
                        // Feed the decoded mean back into the budget
                        // allocator: the mean is bit-identical on every
                        // node, so every node derives the identical
                        // width table for the next round with zero
                        // coordination (a no-op without a budget).
                        gc.observe_mean(&mean);
                        if on {
                            rec.begin(track, "apply");
                        }
                        opt.step(&mut params, &mean, schedule.lr_at(t));
                        if on {
                            rec.end(track, "apply");
                        }
                    }
                });
            }
            drop(report_tx);

            // ---------------- coordinator ----------------
            let run_server = || -> Result<TrainOutput> {
                let on = recorder.is_enabled();
                let ctrack = Track::Coordinator;
                let mut mean: Vec<f32> = Vec::with_capacity(param_count);
                for t in 0..cfg.steps {
                    let before = coll.stats();
                    if on {
                        recorder.counter(ctrack, "round_index", t as f64);
                        recorder.begin(ctrack, "round");
                    }
                    coll.round(&mut mean)?;
                    if mean.len() != param_count {
                        return Err(Error::Shape(format!(
                            "exchange produced {} elements, expected {param_count}",
                            mean.len()
                        )));
                    }
                    if on {
                        recorder.end(ctrack, "round");
                        recorder.begin(ctrack, "apply");
                    }
                    // the coordinator applies the identical decoded mean
                    server_opt.step(&mut server_params, &mean, schedule.lr_at(t));
                    if on {
                        recorder.end(ctrack, "apply");
                    }

                    // drain the L reports for this step
                    let mut loss = 0.0;
                    let mut rel = 0.0;
                    let mut cos = 0.0;
                    for _ in 0..l {
                        let r = report_rx
                            .recv()
                            .map_err(|_| Error::Comm("worker died mid-step".into()))?;
                        debug_assert_eq!(r.step, t);
                        loss += r.loss;
                        rel += r.rel_mse;
                        cos += r.cosine;
                    }
                    let inv = 1.0 / l as f64;
                    let after = coll.stats();
                    series.push(StepMetrics {
                        step: t,
                        train_loss: loss * inv,
                        quant_rel_mse: rel * inv,
                        quant_cosine: cos * inv,
                        wire_bytes: after.wire_bytes - before.wire_bytes,
                        wire_bytes_up: after.wire_bytes_up - before.wire_bytes_up,
                        wire_bytes_down: after.wire_bytes_down - before.wire_bytes_down,
                        comm_time_s: after.sim_time_s - before.sim_time_s,
                        comm_model_time_s: after.model_time_s - before.model_time_s,
                        staleness_max_age: after.staleness.max_age,
                    });
                    if on {
                        registry.add("rounds", 1.0);
                        registry.add(
                            "wire_bytes_total",
                            (after.wire_bytes - before.wire_bytes) as f64,
                        );
                        registry.add("sim_time_s", after.sim_time_s - before.sim_time_s);
                        registry.add("model_time_s", after.model_time_s - before.model_time_s);
                        registry.set_max("staleness_max_age", after.staleness.max_age as f64);
                    }

                    if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                        let (t1, t5) =
                            evaluate(server_backend.as_mut(), &server_params, self.ds, classes);
                        series.push_eval(t + 1, t1, t5);
                    }
                }
                let (top1, top5) = evaluate(server_backend.as_mut(), &server_params, self.ds, classes);
                series.push_eval(cfg.steps, top1, top5);
                let ratio = if is_fp {
                    1.0
                } else {
                    codec::compression_ratio(
                        param_count,
                        cfg.bucket_size,
                        quantizer.num_levels(),
                        Packing::BaseS,
                        &cfg.method,
                    )
                };
                let summary = RunSummary {
                    method: cfg.method.clone(),
                    model: cfg.model.clone(),
                    steps: cfg.steps,
                    final_train_loss: series.tail_loss(20),
                    test_top1: top1,
                    test_top5: top5,
                    mean_quant_rel_mse: series.mean_rel_mse(),
                    total_wire_bytes: series.total_wire_bytes(),
                    total_comm_time_s: series.total_comm_time(),
                    compression_ratio: ratio,
                };
                Ok(TrainOutput {
                    summary,
                    series,
                    params: server_params,
                    comm: coll.stats(),
                    shard_bytes: coll.shard_bytes(),
                    obs: None,
                })
            };
            out = run_server();
            // Tear the collective down before joining workers: if the
            // coordinator erred mid-run, blocked workers see closed
            // channels and exit instead of deadlocking the scope.
            drop(coll);
        });
        // The scope joined every worker (and dropping the collective
        // stopped the shard servers), so all spans are closed — drain
        // the trace only now.
        if let Ok(o) = &mut out {
            if recorder.is_enabled() {
                registry.set("workers", l as f64);
                o.obs = Some(ObsReport { events: recorder.drain(), registry });
            }
        }
        out
    }
}

/// Top-1/top-5 accuracy of `params` on the dataset's test split.
pub fn evaluate(
    backend: &mut dyn Backend,
    params: &[f32],
    ds: &ClassDataset,
    classes: usize,
) -> (f64, f64) {
    let mut top1 = 0.0;
    let mut top5 = 0.0;
    let mut total = 0.0;
    for b in ds.test_batches(64) {
        let logits = backend.logits(params, &b);
        top1 += topk_accuracy(&logits, &b.y, classes, 1) * b.batch as f64;
        top5 += topk_accuracy(&logits, &b.y, classes, 5.min(classes)) * b.batch as f64;
        total += b.batch as f64;
    }
    (top1 / total.max(1.0), top5 / total.max(1.0))
}

/// Convenience: build the native backend named by the config.
pub fn native_backend_factory(model: &str) -> Result<impl Fn(usize) -> Box<dyn Backend> + Sync> {
    use crate::model::native::NativeMlp;
    let dims: Vec<usize> = match model {
        "mlp_s" => vec![256, 512, 512, 100],
        "mlp_m" => vec![256, 1024, 1024, 1024, 100],
        "mlp_l" => vec![512, 2048, 2048, 2048, 200],
        _ if model.starts_with("mlp:") => {
            // "mlp:16-32-4" → custom dims
            let dims: Vec<usize> = model[4..]
                .split('-')
                .map(|p| p.parse().map_err(|_| Error::Config(format!("bad dims {model:?}"))))
                .collect::<Result<_>>()?;
            if dims.len() < 2 {
                return Err(Error::Config("mlp: needs at least 2 dims".into()));
            }
            dims
        }
        _ => {
            return Err(Error::Config(format!(
                "unknown native model {model:?} (use mlp_s/mlp_m/mlp_l or mlp:d0-d1-...)"
            )))
        }
    };
    Ok(move |_id: usize| Box::new(NativeMlp::new(dims.clone())) as Box<dyn Backend>)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::config::LinkConfig;
    use crate::data::synth::DatasetSpec;

    fn tiny_ds() -> ClassDataset {
        ClassDataset::generate(DatasetSpec {
            in_dim: 16,
            classes: 8,
            train_n: 512,
            test_n: 256,
            margin: 3.0,
            noise: 0.6,
            label_noise: 0.0,
            seed: 11,
        })
    }

    fn tiny_cfg(method: &str, workers: usize) -> TrainConfig {
        TrainConfig {
            model: "mlp:16-32-8".into(),
            dataset: "tiny".into(),
            method: method.into(),
            workers,
            batch: 32 * workers,
            steps: 120,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            lr_decay_steps: vec![80],
            lr_decay: 0.1,
            warmup_steps: 0,
            bucket_size: 256,
            clip_factor: None,
            seed: 3,
            eval_every: 0,
            quantize_downlink: false,
            topology: Topology::Ps,
            groups: 1,
            shards: 1,
            staleness: 0,
            error_feedback: false,
            threads: 1,
            pool: true,
            overlap: false,
            sections: None,
            stream_sections: false,
            byte_budget: None,
            budget_schedule: None,
            trace_level: crate::obs::TraceLevel::Off,
            links: LinkConfig::default(),
        }
    }

    fn run(method: &str, workers: usize) -> TrainOutput {
        let ds = tiny_ds();
        let cfg = tiny_cfg(method, workers);
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
    }

    fn run_ring(method: &str, workers: usize) -> TrainOutput {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg(method, workers);
        cfg.topology = Topology::Ring;
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
    }

    fn run_hier(method: &str, workers: usize, groups: usize) -> TrainOutput {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg(method, workers);
        cfg.topology = Topology::Hier;
        cfg.groups = groups;
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
    }

    fn run_sharded(method: &str, workers: usize, shards: usize, staleness: usize) -> TrainOutput {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg(method, workers);
        cfg.topology = Topology::ShardedPs;
        cfg.shards = shards;
        cfg.staleness = staleness;
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
    }

    #[test]
    fn fp_learns_single_worker() {
        let out = run("fp", 1);
        assert!(out.summary.test_top1 > 0.85, "top1={}", out.summary.test_top1);
        assert!(out.summary.final_train_loss < 0.7, "loss={}", out.summary.final_train_loss);
        assert_eq!(out.summary.compression_ratio, 1.0);
    }

    #[test]
    fn orq_learns_and_reports_compression() {
        let out = run("orq-5", 1);
        assert!(out.summary.test_top1 > 0.8, "top1={}", out.summary.test_top1);
        // tiny 808-param model pays heavy per-bucket level-table overhead;
        // large models reach the paper's ×13.8 (see codec tests).
        assert!(out.summary.compression_ratio > 7.0, "{}", out.summary.compression_ratio);
        assert!(out.summary.mean_quant_rel_mse > 0.0);
        assert!(out.summary.total_wire_bytes > 0);
    }

    #[test]
    fn distributed_matches_structure() {
        let out = run("terngrad", 4);
        assert_eq!(out.series.steps.len(), 120);
        assert!(out.summary.test_top1 > 0.6, "top1={}", out.summary.test_top1);
        // 4 uplinks + 1 broadcast per step: bytes > single-worker run
        let single = run("terngrad", 1);
        assert!(out.summary.total_wire_bytes > single.summary.total_wire_bytes);
    }

    #[test]
    fn quantized_uplink_much_smaller_than_fp() {
        let fp = run("fp", 2);
        let q = run("terngrad", 2);
        // FP broadcast dominates the remaining bytes (downlink still FP);
        // with quantize_downlink the gap widens further (separate test).
        assert!(
            (q.summary.total_wire_bytes as f64) < (fp.summary.total_wire_bytes as f64) * 0.5,
            "q={} fp={}",
            q.summary.total_wire_bytes,
            fp.summary.total_wire_bytes
        );
    }

    /// `quantize_downlink` shrinks the mean broadcast on every topology
    /// that has one (ps, hier, sharded-ps) — and precisely the downlink
    /// component of the wire, as the new up/down counters attest.
    #[test]
    fn downlink_quantization_shrinks_broadcast() {
        let ds = tiny_ds();
        let run_dl = |topology: Topology, downlink: bool| {
            let mut cfg = tiny_cfg("orq-3", 2);
            cfg.topology = topology;
            match topology {
                Topology::Hier => cfg.groups = 2,
                Topology::ShardedPs => cfg.shards = 2,
                _ => {}
            }
            cfg.quantize_downlink = downlink;
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        for topology in [Topology::Ps, Topology::Hier, Topology::ShardedPs] {
            let q = run_dl(topology, true);
            let fp = run_dl(topology, false);
            assert!(
                q.summary.total_wire_bytes < fp.summary.total_wire_bytes,
                "{topology:?}: quantized downlink must shrink the wire"
            );
            assert!(q.comm.wire_bytes_down < fp.comm.wire_bytes_down, "{topology:?}");
            assert_eq!(q.comm.wire_bytes_up, fp.comm.wire_bytes_up, "{topology:?}: uplink untouched");
            assert!(q.summary.test_top1 > 0.5, "{topology:?} top1={}", q.summary.test_top1);
        }
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let a = run("orq-3", 2);
        let b = run("orq-3", 2);
        assert_eq!(a.params, b.params);
        assert_eq!(a.summary.test_top1, b.summary.test_top1);
    }

    /// The parallel codec path must learn, and — because encode uses
    /// per-bucket RNG streams and the PS reduce preserves accumulation
    /// order — training must be bit-identical for every thread count.
    #[test]
    fn parallel_codec_threads_learn_and_match_across_counts() {
        let ds = tiny_ds();
        let run_t = |threads: usize| {
            let mut cfg = tiny_cfg("orq-3", 2);
            cfg.threads = threads;
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        let a = run_t(2);
        let b = run_t(4);
        assert_eq!(a.params, b.params, "thread count must not change training");
        assert!(a.summary.test_top1 > 0.6, "top1={}", a.summary.test_top1);
    }

    #[test]
    fn ring_topology_learns_fp() {
        let out = run_ring("fp", 4);
        assert_eq!(out.series.steps.len(), 120);
        assert!(out.summary.test_top1 > 0.8, "ring fp top1={}", out.summary.test_top1);
        assert!(out.summary.total_wire_bytes > 0);
        assert!(out.summary.total_comm_time_s > 0.0);
    }

    #[test]
    fn ring_topology_learns_quantized() {
        let out = run_ring("terngrad", 4);
        assert!(out.summary.test_top1 > 0.5, "ring terngrad top1={}", out.summary.test_top1);
        // per-hop requantization is lossy but must not destroy training
        assert!(out.summary.mean_quant_rel_mse > 0.0);
    }

    #[test]
    fn ring_determinism_same_seed_same_result() {
        let a = run_ring("orq-3", 3);
        let b = run_ring("orq-3", 3);
        assert_eq!(a.params, b.params);
        assert_eq!(a.summary.test_top1, b.summary.test_top1);
    }

    #[test]
    fn ring_single_worker_matches_ps_fp() {
        // With one worker both topologies degenerate to "apply your own
        // gradient"; fp carries it losslessly, so training is identical.
        let ps = run("fp", 1);
        let ring = run_ring("fp", 1);
        assert_eq!(ps.params, ring.params);
        // ...but the ring moves zero bytes while PS pays up + broadcast.
        assert_eq!(ring.summary.total_wire_bytes, 0);
        assert!(ps.summary.total_wire_bytes > 0);
    }

    #[test]
    fn rejects_bad_config() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("fp", 3);
        cfg.batch = 32; // not a multiple of 3
        assert!(Trainer::new(cfg, &ds).is_err());
    }

    #[test]
    fn hier_topology_learns_fp() {
        let out = run_hier("fp", 4, 2);
        assert_eq!(out.series.steps.len(), 120);
        assert!(out.summary.test_top1 > 0.8, "hier fp top1={}", out.summary.test_top1);
        assert!(out.summary.total_wire_bytes > 0);
        assert!(out.summary.total_comm_time_s > 0.0);
    }

    #[test]
    fn hier_topology_learns_quantized() {
        let out = run_hier("terngrad", 4, 2);
        assert!(out.summary.test_top1 > 0.5, "hier terngrad top1={}", out.summary.test_top1);
        // intra-hop + leader requantization is lossy but must not destroy
        // training
        assert!(out.summary.mean_quant_rel_mse > 0.0);
    }

    #[test]
    fn hier_determinism_same_seed_same_result() {
        let a = run_hier("orq-3", 6, 3);
        let b = run_hier("orq-3", 6, 3);
        assert_eq!(a.params, b.params);
        assert_eq!(a.summary.test_top1, b.summary.test_top1);
    }

    #[test]
    fn hier_single_worker_matches_ps_fp() {
        // One worker: every topology degenerates to "apply your own
        // gradient"; fp carries it losslessly, so training is identical,
        // and like the ring, the hierarchy moves zero bytes.
        let ps = run("fp", 1);
        let hier = run_hier("fp", 1, 1);
        assert_eq!(ps.params, hier.params);
        assert_eq!(hier.summary.total_wire_bytes, 0);
    }

    #[test]
    fn hier_rejects_bad_grouping() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("fp", 4);
        cfg.topology = Topology::Hier;
        cfg.groups = 3; // does not divide 4
        assert!(Trainer::new(cfg, &ds).is_err());
    }

    /// The sharded parameter server with S = 1, K = 0 must train
    /// bit-identically to the flat PS — the wire carries the same codec
    /// payloads (framed), the shard reduces in the same worker order, and
    /// every node decodes the same FP mean. Holds for every scheme.
    #[test]
    fn sharded_s1_k0_bit_identical_to_ps() {
        for method in ["fp", "orq-3", "bingrad-b"] {
            let ps = run(method, 2);
            let sh = run_sharded(method, 2, 1, 0);
            assert_eq!(ps.params, sh.params, "{method}");
            assert_eq!(ps.summary.test_top1, sh.summary.test_top1, "{method}");
        }
    }

    /// Shard-count invariance at K = 0: the assembled mean is the same
    /// f64-reduced PS mean regardless of how the bucket grid is
    /// partitioned, so training is bit-identical for every shard count.
    /// (The tiny 808-param model at d = 256 has 4 buckets — S ≤ 4.)
    #[test]
    fn sharded_training_invariant_across_shard_counts() {
        let a = run_sharded("orq-3", 2, 1, 0);
        let b = run_sharded("orq-3", 2, 2, 0);
        let c = run_sharded("orq-3", 2, 4, 0);
        assert_eq!(a.params, b.params);
        assert_eq!(a.params, c.params);
        assert!(a.summary.test_top1 > 0.6, "top1={}", a.summary.test_top1);
        // per-shard byte counters cover the whole wire, and sharding
        // populates them
        let sb = b.shard_bytes.as_ref().expect("sharded runs report per-shard bytes");
        assert_eq!(sb.len(), 2);
        assert!(sb.iter().all(|&b| b > 0));
        assert_eq!(sb.iter().sum::<u64>(), b.comm.wire_bytes);
        assert!(a.shard_bytes.is_some() && run("fp", 1).shard_bytes.is_none());
    }

    /// Bounded staleness K ≥ 1: the run pipelines (first K rounds apply
    /// the zero mean, then every round applies the round-(t − K) mean),
    /// stays deterministic, still learns, and the coordinator's
    /// staleness histogram records exactly the configured lag.
    #[test]
    fn sharded_staleness_window_learns_and_is_deterministic() {
        let a = run_sharded("orq-3", 2, 2, 2);
        let b = run_sharded("orq-3", 2, 2, 2);
        assert_eq!(a.params, b.params, "stale runs must stay reproducible");
        assert!(a.summary.test_top1 > 0.4, "top1={}", a.summary.test_top1);
        let st = a.comm.staleness;
        assert_eq!(st.rounds, 120);
        assert_eq!(st.cold_rounds, 2);
        assert_eq!(st.max_age, 2);
        assert_eq!(st.hist[2], 118);
        // the lag changes the trajectory vs the synchronous run
        let sync = run_sharded("orq-3", 2, 2, 0);
        assert_ne!(a.params, sync.params);
        assert_eq!(sync.comm.staleness.max_age, 0);
        assert_eq!(sync.comm.staleness.cold_rounds, 0);
    }

    /// More shards than gradient buckets is rejected up front with an
    /// actionable error (808 params at d = 256 → 4 buckets).
    #[test]
    fn sharded_rejects_more_shards_than_buckets() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("fp", 2);
        cfg.topology = Topology::ShardedPs;
        cfg.shards = 64;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let err = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap_err();
        assert!(err.to_string().contains("bucket count"), "{err}");
    }

    /// Error feedback end-to-end on the PS path: the biased BinGrad-b
    /// runs compensated, learns, and actually changes the trajectory.
    #[test]
    fn error_feedback_trains_biased_scheme() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("bingrad-b", 2);
        cfg.error_feedback = true;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let ef = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
        assert!(ef.summary.test_top1 > 0.5, "EF top1={}", ef.summary.test_top1);
        let plain = run("bingrad-b", 2);
        assert_ne!(ef.params, plain.params, "EF must alter the transmitted signal");
        // EF composes with the sharded topology too
        let mut cfg = tiny_cfg("bingrad-b", 2);
        cfg.topology = Topology::ShardedPs;
        cfg.shards = 2;
        cfg.error_feedback = true;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let sh = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
        assert_eq!(sh.params, ef.params, "S=2 K=0 EF ≡ flat PS EF");
    }

    /// EF now rides every topology (per-hop residuals on ring/hier);
    /// only fp — where there is no quantization error to compensate —
    /// still rejects the flag.
    #[test]
    fn error_feedback_rejected_only_on_fp() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("fp", 2);
        cfg.error_feedback = true;
        assert!(Trainer::new(cfg, &ds).is_err());
        let mut cfg = tiny_cfg("terngrad", 2);
        cfg.error_feedback = true;
        cfg.topology = Topology::Ring;
        assert!(Trainer::new(cfg, &ds).is_ok());
    }

    /// Per-hop error feedback end-to-end on the decentralized paths:
    /// ring and hier runs with EF learn the biased BinGrad-b, stay
    /// deterministic, and the hop residuals change the trajectory.
    #[test]
    fn error_feedback_trains_on_ring_and_hier() {
        let ds = tiny_ds();
        let run_ef = |topology: Topology, ef: bool| {
            let mut cfg = tiny_cfg("bingrad-b", 4);
            cfg.topology = topology;
            if topology == Topology::Hier {
                cfg.groups = 2;
            }
            cfg.error_feedback = ef;
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        for topology in [Topology::Ring, Topology::Hier] {
            let ef = run_ef(topology, true);
            let ef2 = run_ef(topology, true);
            assert_eq!(ef.params, ef2.params, "{topology:?}: EF runs must stay reproducible");
            assert!(ef.summary.test_top1 > 0.5, "{topology:?} EF top1={}", ef.summary.test_top1);
            let plain = run_ef(topology, false);
            assert_ne!(ef.params, plain.params, "{topology:?}: hop residuals must matter");
        }
    }

    /// EF × quantized downlink: the worker residual tracks the uplink
    /// only, so flipping the downlink codec changes the applied mean
    /// (and the trajectory) but never corrupts the compensation loop —
    /// the biased scheme still learns, bidirectionally compressed.
    #[test]
    fn error_feedback_composes_with_quantized_downlink() {
        let ds = tiny_ds();
        let run_efdl = |downlink: bool| {
            let mut cfg = tiny_cfg("bingrad-b", 2);
            cfg.error_feedback = true;
            cfg.quantize_downlink = downlink;
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        let both = run_efdl(true);
        let up_only = run_efdl(false);
        assert!(both.summary.test_top1 > 0.5, "EF+downlink top1={}", both.summary.test_top1);
        assert_ne!(both.params, up_only.params, "quantized downlink must alter the mean");
        assert!(both.summary.total_wire_bytes < up_only.summary.total_wire_bytes);
        // deterministic under the composition too
        assert_eq!(both.params, run_efdl(true).params);
    }

    /// Error feedback through the parallel codec (the combination PR 4
    /// rejected): learns, carries the residual (trajectory differs from
    /// the memoryless parallel run), and is thread-count invariant.
    #[test]
    fn error_feedback_parallel_codec_learns_and_is_thread_invariant() {
        let ds = tiny_ds();
        let run_ef_t = |threads: usize| {
            let mut cfg = tiny_cfg("bingrad-b", 2);
            cfg.error_feedback = true;
            cfg.threads = threads;
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        let a = run_ef_t(2);
        let b = run_ef_t(4);
        assert_eq!(a.params, b.params, "EF training must be thread-count invariant");
        assert!(a.summary.test_top1 > 0.5, "EF top1={}", a.summary.test_top1);
        // the residual must matter: plain parallel bingrad-b diverges
        let mut cfg = tiny_cfg("bingrad-b", 2);
        cfg.threads = 2;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let plain = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
        assert_ne!(a.params, plain.params, "EF must alter the transmitted signal");
    }

    /// The persistent pool must be invisible in the results: pooled and
    /// scoped execution train bit-identically, serial and parallel, on
    /// the flat and sharded PS topologies.
    #[test]
    fn pooled_and_scoped_training_bit_identical() {
        let ds = tiny_ds();
        let run_mode = |pool: bool, threads: usize, shards: usize| {
            let mut cfg = tiny_cfg("orq-3", 2);
            cfg.pool = pool;
            cfg.threads = threads;
            if shards > 1 {
                cfg.topology = Topology::ShardedPs;
                cfg.shards = shards;
            }
            let factory = native_backend_factory(&cfg.model).unwrap();
            Trainer::new(cfg, &ds).unwrap().run(factory).unwrap()
        };
        for (threads, shards) in [(1usize, 1usize), (2, 1), (2, 2)] {
            let pooled = run_mode(true, threads, shards);
            let scoped = run_mode(false, threads, shards);
            assert_eq!(
                pooled.params, scoped.params,
                "threads={threads} shards={shards}: pool must not change training"
            );
            assert_eq!(pooled.summary.total_wire_bytes, scoped.summary.total_wire_bytes);
        }
    }

    fn run_ov_cfg(
        ds: &ClassDataset,
        topology: Topology,
        threads: usize,
        overlap: bool,
        stream: bool,
        ef: bool,
    ) -> TrainOutput {
        let mut cfg = tiny_cfg(if ef { "bingrad-b" } else { "orq-3" }, 2);
        cfg.topology = topology;
        match topology {
            Topology::Hier => cfg.groups = 2,
            Topology::ShardedPs => cfg.shards = 2,
            _ => {}
        }
        cfg.error_feedback = ef;
        cfg.threads = threads;
        cfg.overlap = overlap;
        cfg.stream_sections = stream;
        if overlap {
            cfg.sections = Some(2); // the tiny 2-layer MLP's maximum
        }
        let factory = native_backend_factory(&cfg.model).unwrap();
        Trainer::new(cfg, ds).unwrap().run(factory).unwrap()
    }

    /// The overlap tentpole guarantee: backward/encode overlap trains
    /// bit-identically to the flat post-backward exchange — same trained
    /// parameters and wire bytes — on every topology and parallel thread
    /// count, with and without error feedback.
    #[test]
    fn overlap_bit_identical_to_flat_exchange_all_topologies() {
        let ds = tiny_ds();
        for topology in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
            for threads in [2usize, 4] {
                for ef in [false, true] {
                    let flat = run_ov_cfg(&ds, topology, threads, false, false, ef);
                    let over = run_ov_cfg(&ds, topology, threads, true, false, ef);
                    assert_eq!(
                        flat.params, over.params,
                        "{topology:?} threads={threads} ef={ef}: overlap changed training"
                    );
                    assert_eq!(
                        flat.summary.total_wire_bytes, over.summary.total_wire_bytes,
                        "{topology:?} threads={threads} ef={ef}: overlap changed wire bytes"
                    );
                }
            }
        }
    }

    /// Serial overlap (PR 8 satellite): at threads = 1 the
    /// start-anywhere encoder stages sections inline instead of
    /// degenerating to the flat path. Its bytes follow the parallel
    /// per-bucket RNG discipline, so the run matches the *parallel*
    /// flat/overlap runs bit for bit — overlap is thread-count invariant
    /// all the way down to one thread.
    #[test]
    fn serial_overlap_matches_parallel_overlap() {
        let ds = tiny_ds();
        for topology in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
            let serial = run_ov_cfg(&ds, topology, 1, true, false, false);
            let parallel = run_ov_cfg(&ds, topology, 2, true, false, false);
            let flat2 = run_ov_cfg(&ds, topology, 2, false, false, false);
            assert_eq!(
                serial.params, parallel.params,
                "{topology:?}: serial overlap diverged from parallel overlap"
            );
            assert_eq!(
                parallel.params, flat2.params,
                "{topology:?}: overlap diverged from the parallel flat exchange"
            );
            assert_eq!(serial.summary.total_wire_bytes, parallel.summary.total_wire_bytes);
        }
        // error feedback composes with the serial overlap path too
        let a = run_ov_cfg(&ds, Topology::Ps, 1, true, false, true);
        let b = run_ov_cfg(&ds, Topology::Ps, 2, true, false, true);
        assert_eq!(a.params, b.params, "EF serial overlap must match parallel");
        assert!(a.summary.test_top1 > 0.5, "top1={}", a.summary.test_top1);
    }

    /// The streaming tentpole at the trainer level: `--stream-sections`
    /// trains bit-identically to the flat overlap exchange on the
    /// PS-family topologies (worker-order f64 accumulation per section),
    /// for serial and parallel codecs, with and without error feedback.
    #[test]
    fn streamed_training_bit_identical_on_ps_family() {
        let ds = tiny_ds();
        for topology in [Topology::Ps, Topology::Hier, Topology::ShardedPs] {
            for threads in [1usize, 2] {
                for ef in [false, true] {
                    let over = run_ov_cfg(&ds, topology, threads, true, false, ef);
                    let st = run_ov_cfg(&ds, topology, threads, true, true, ef);
                    assert_eq!(
                        over.params, st.params,
                        "{topology:?} threads={threads} ef={ef}: streaming changed training"
                    );
                    assert!(st.comm.sim_time_s > 0.0, "{topology:?}: no simulated time");
                }
            }
        }
    }

    /// Ring streaming: one reduce-scatter/all-gather per section is not
    /// bit-identical to the flat ring (section-local chunk grids, more
    /// requantization sites), but it is deterministic, thread-count
    /// invariant (threads = 1 *is* the serial replay of the schedule),
    /// and it still learns.
    #[test]
    fn streamed_ring_training_thread_invariant_and_learns() {
        let ds = tiny_ds();
        let serial = run_ov_cfg(&ds, Topology::Ring, 1, true, true, false);
        let t2 = run_ov_cfg(&ds, Topology::Ring, 2, true, true, false);
        let t4 = run_ov_cfg(&ds, Topology::Ring, 4, true, true, false);
        assert_eq!(serial.params, t2.params, "streamed ring diverged from its serial replay");
        assert_eq!(t2.params, t4.params, "streamed ring must be thread-count invariant");
        let again = run_ov_cfg(&ds, Topology::Ring, 2, true, true, false);
        assert_eq!(t2.params, again.params, "streamed ring runs must stay reproducible");
        assert!(serial.summary.test_top1 > 0.5, "top1={}", serial.summary.test_top1);
        // per-(hop, section) EF composes and stays invariant too
        let ef1 = run_ov_cfg(&ds, Topology::Ring, 1, true, true, true);
        let ef2 = run_ov_cfg(&ds, Topology::Ring, 2, true, true, true);
        assert_eq!(ef1.params, ef2.params, "streamed ring EF must be thread-count invariant");
        assert!(ef1.summary.test_top1 > 0.5, "EF top1={}", ef1.summary.test_top1);
    }

    /// Overlapped runs still learn and report sane figures (not just
    /// match a baseline).
    #[test]
    fn overlap_learns() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("orq-5", 2);
        cfg.threads = 2;
        cfg.overlap = true;
        cfg.sections = Some(2);
        let factory = native_backend_factory(&cfg.model).unwrap();
        let out = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap();
        assert!(out.summary.test_top1 > 0.6, "top1={}", out.summary.test_top1);
        assert!(out.summary.mean_quant_rel_mse > 0.0);
    }

    /// The overlap negative space: sections = 0, sections without
    /// overlap, and overlap-on-fp die in config validation; more
    /// sections than model layers dies in the trainer's pre-spawn check
    /// with an actionable message.
    #[test]
    fn overlap_rejects_bad_shapes() {
        let ds = tiny_ds();
        let mut cfg = tiny_cfg("orq-3", 2);
        cfg.overlap = true;
        cfg.sections = Some(0);
        assert!(Trainer::new(cfg, &ds).is_err(), "sections = 0");
        let mut cfg = tiny_cfg("orq-3", 2);
        cfg.sections = Some(2); // no overlap: silently-ignored knob is an error
        assert!(Trainer::new(cfg, &ds).is_err(), "sections without overlap");
        let mut cfg = tiny_cfg("fp", 2);
        cfg.overlap = true;
        assert!(Trainer::new(cfg, &ds).is_err(), "overlap on fp");
        let mut cfg = tiny_cfg("orq-3", 2);
        cfg.overlap = true;
        cfg.sections = Some(3); // mlp:16-32-8 has 2 layers
        let factory = native_backend_factory(&cfg.model).unwrap();
        let err = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap_err();
        assert!(err.to_string().contains("layer count"), "{err}");
        // streaming inherits the same pre-spawn check via the implied
        // overlap (default 4 sections > 2 layers)
        let mut cfg = tiny_cfg("orq-3", 2);
        cfg.overlap = true;
        cfg.stream_sections = true;
        let factory = native_backend_factory(&cfg.model).unwrap();
        let err = Trainer::new(cfg, &ds).unwrap().run(factory).unwrap_err();
        assert!(err.to_string().contains("layer count"), "{err}");
    }
}
