//! Measurement harness for the `rust/benches/*` binaries (criterion is
//! not vendorable offline — DESIGN.md §3): warmup, timed iterations,
//! mean/σ/p50/p99 and throughput, plus an aligned table printer.

use std::time::Instant;

use crate::tensor::stats::percentile_sorted;
use crate::util::fmt;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// Optional elements-processed-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_s)
    }

    pub fn row(&self) -> Vec<String> {
        let mut r = vec![
            self.name.clone(),
            fmt::duration(self.mean_s),
            format!("±{}", fmt::duration(self.std_s)),
            fmt::duration(self.p50_s),
            fmt::duration(self.p99_s),
        ];
        r.push(match self.throughput() {
            Some(t) if t >= 1e9 => format!("{:.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{:.2} Melem/s", t / 1e6),
            Some(t) => format!("{t:.0} elem/s"),
            None => "-".into(),
        });
        r
    }

    /// Machine-readable form for the `perfbench` `BENCH_*.json`
    /// artifacts (see the schema note in CHANGES.md).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_s".to_string(), Json::Num(self.mean_s));
        m.insert("std_s".to_string(), Json::Num(self.std_s));
        m.insert("min_s".to_string(), Json::Num(self.min_s));
        m.insert("p50_s".to_string(), Json::Num(self.p50_s));
        m.insert("p99_s".to_string(), Json::Num(self.p99_s));
        if let Some(t) = self.throughput() {
            m.insert("elem_s".to_string(), Json::Num(t));
        }
        Json::Obj(m)
    }
}

/// Bench runner configuration.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on measured wall time; iterations stop early past this.
    pub max_seconds: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, iters: 30, max_seconds: 10.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, iters: 10, max_seconds: 5.0 }
    }

    /// Honor `ORQ_BENCH_FAST=1` (CI / smoke runs).
    pub fn from_env() -> Self {
        if std::env::var("ORQ_BENCH_FAST").as_deref() == Ok("1") {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, which performs ONE iteration of the workload per call.
    pub fn measure<F: FnMut()>(&self, name: &str, elements: Option<u64>, mut f: F) -> Measurement {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        let start_all = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if start_all.elapsed().as_secs_f64() > self.max_seconds {
                break;
            }
        }
        summarize(name, &samples, elements)
    }
}

fn summarize(name: &str, samples: &[f64], elements: Option<u64>) -> Measurement {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: sorted.first().copied().unwrap_or(0.0),
        p50_s: percentile_sorted(&sorted, 0.5),
        p99_s: percentile_sorted(&sorted, 0.99),
        elements,
    }
}

/// Print a measurement table with the standard header.
pub fn print_table(title: &str, rows: &[Measurement]) {
    println!("\n== {title} ==");
    let mut table = vec![vec![
        "bench".to_string(),
        "mean".to_string(),
        "std".to_string(),
        "p50".to_string(),
        "p99".to_string(),
        "throughput".to_string(),
    ]];
    table.extend(rows.iter().map(|m| m.row()));
    print!("{}", fmt::table(&table));
}

/// Print an arbitrary results table (for accuracy tables rather than
/// timing benches).
pub fn print_rows(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut table = vec![header.iter().map(|s| s.to_string()).collect::<Vec<_>>()];
    table.extend(rows.iter().cloned());
    print!("{}", fmt::table(&table));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let b = Bench { warmup_iters: 1, iters: 5, max_seconds: 30.0 };
        let mut count = 0;
        let m = b.measure("noop", Some(100), || count += 1);
        assert_eq!(count, 6); // warmup + 5
        assert_eq!(m.iters, 5);
        assert!(m.mean_s >= 0.0);
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn stats_ordering() {
        let m = summarize("x", &[1.0, 2.0, 3.0], None);
        assert_eq!(m.mean_s, 2.0);
        assert_eq!(m.min_s, 1.0);
        assert_eq!(m.p50_s, 2.0);
        assert!(m.p99_s <= 3.0 && m.p99_s >= 2.9);
        assert!(m.throughput().is_none());
    }

    #[test]
    fn measurement_to_json_has_required_fields() {
        let m = summarize("x", &[1.0, 2.0], Some(10));
        let j = m.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert!(j.get("mean_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("elem_s").is_some());
        let txt = j.dump();
        assert_eq!(crate::util::json::Json::parse(&txt).unwrap(), j);
    }

    #[test]
    fn time_cap_stops_early() {
        let b = Bench { warmup_iters: 0, iters: 1000, max_seconds: 0.05 };
        let m = b.measure("sleepy", None, || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(m.iters < 1000, "cap must kick in, ran {}", m.iters);
    }
}

// ---------------------------------------------------------------------
// Shared experiment helpers used by `rust/benches/*` and `examples/*`.
// ---------------------------------------------------------------------

/// Paper-table experiment scale. `ORQ_BENCH_FULL=1` switches every bench
/// from the fast CI models to the paper-scale MLPs (Table 2 sizes).
pub mod suite {
    use crate::config::TrainConfig;
    use crate::coordinator::trainer::{native_backend_factory, Trainer, TrainOutput};
    use crate::data::synth::{ClassDataset, DatasetSpec};
    use crate::error::Result;

    /// True when the paper-scale (slow) configuration is requested.
    pub fn full_scale() -> bool {
        std::env::var("ORQ_BENCH_FULL").as_deref() == Ok("1")
    }

    /// The three Table-2 model columns: (column name, model spec, in_dim).
    /// Fast mode uses shrunk stand-ins with identical depth ordering.
    pub fn table2_models() -> Vec<(&'static str, String, usize)> {
        if full_scale() {
            vec![
                ("ResNet-56→MLP-S", "mlp_s".into(), 256),
                ("ResNet-110→MLP-M", "mlp_m".into(), 256),
                ("GoogLeNet→MLP-L", "mlp_l".into(), 512),
            ]
        } else {
            vec![
                ("ResNet-56→MLP-S", "mlp:64-128-128-100".into(), 64),
                ("ResNet-110→MLP-M", "mlp:64-192-192-192-100".into(), 64),
                ("GoogLeNet→MLP-L", "mlp:128-256-256-100".into(), 128),
            ]
        }
    }

    /// Steps for a "200-epoch CIFAR" style run at the current scale.
    pub fn cifar_steps() -> usize {
        if full_scale() {
            2000
        } else {
            250
        }
    }

    pub fn imagenet_steps() -> usize {
        if full_scale() {
            1500
        } else {
            200
        }
    }

    /// A CIFAR-100-like training config for one method/model column.
    pub fn cifar_cfg(method: &str, model: &str, steps: usize) -> TrainConfig {
        TrainConfig {
            model: model.into(),
            dataset: "cifar100".into(),
            method: method.into(),
            workers: 1,
            batch: 64,
            steps,
            lr: 0.08,
            momentum: 0.9,
            weight_decay: 5e-4,
            lr_decay_steps: vec![steps / 2, steps * 3 / 4],
            lr_decay: 0.1,
            warmup_steps: 0,
            bucket_size: 2048,
            clip_factor: None,
            seed: 42,
            eval_every: 0,
            quantize_downlink: false,
            topology: crate::comm::Topology::Ps,
            groups: 1,
            shards: 1,
            staleness: 0,
            error_feedback: false,
            threads: 1,
            pool: true,
            overlap: false,
            sections: None,
            stream_sections: false,
            byte_budget: None,
            budget_schedule: None,
            trace_level: crate::obs::TraceLevel::Off,
            links: crate::config::LinkConfig::default(),
        }
    }

    /// Dataset matching a model's input dim at the current scale.
    pub fn cifar100_ds(in_dim: usize) -> ClassDataset {
        let mut spec = DatasetSpec::cifar100_like(in_dim);
        if !full_scale() {
            spec.train_n = 8192;
            spec.test_n = 2048;
        }
        ClassDataset::generate(spec)
    }

    pub fn cifar10_ds(in_dim: usize) -> ClassDataset {
        let mut spec = DatasetSpec::cifar10_like(in_dim);
        if !full_scale() {
            spec.train_n = 4096;
            spec.test_n = 1024;
        }
        ClassDataset::generate(spec)
    }

    pub fn imagenet_ds(in_dim: usize) -> ClassDataset {
        let mut spec = DatasetSpec::imagenet_like(in_dim);
        if !full_scale() {
            spec.train_n = 8192;
            spec.test_n = 2048;
            spec.classes = 100;
        }
        ClassDataset::generate(spec)
    }

    /// Run one native-backend training config against a dataset.
    pub fn run_native(cfg: TrainConfig, ds: &ClassDataset) -> Result<TrainOutput> {
        let factory = native_backend_factory(&cfg.model)?;
        Trainer::new(cfg, ds)?.run(factory)
    }
}
