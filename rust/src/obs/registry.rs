//! Named counters/gauges aggregated at round end, and the per-round
//! metrics JSON artifact with its model-drift section.
//!
//! [`MetricsRegistry`] is a thread-safe map of named `f64` values:
//! `add` accumulates (counter semantics), `set` overwrites (gauge
//! semantics). The trainer threads a clone through the round loop so
//! workers, collectives and the pool can all contribute without
//! plumbing dedicated channels; `BTreeMap` keys keep the JSON output
//! deterministically ordered.
//!
//! [`metrics_json`] renders the per-round series plus a **model-drift
//! section**: measured simulated communication seconds vs the
//! closed-form `*_time`/`*_overlap_time`/`*_streamed_time` models, per
//! round and in aggregate. The repo's <1% model-vs-sim invariant —
//! until now only asserted inside the test suite — becomes an
//! observable in every run's artifact.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::metrics::SeriesLogger;
use crate::util::json::Json;

/// Schema tag written into the metrics artifact.
pub const METRICS_SCHEMA: &str = "orq.metrics/v1";

/// Denominator floor for relative error so all-zero rounds report 0.
const DRIFT_TINY: f64 = 1e-12;

/// Thread-safe registry of named counters and gauges.
///
/// Cloning shares the underlying map ([`Arc`]); a poisoned lock is
/// recovered rather than propagated so a panicking worker cannot take
/// the metrics artifact down with it.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry(Arc<Mutex<BTreeMap<String, f64>>>);

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, f64>> {
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Counter semantics: accumulate `v` onto `name` (starts at 0).
    pub fn add(&self, name: &str, v: f64) {
        let mut m = self.lock();
        *m.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Gauge semantics: overwrite `name` with `v`.
    pub fn set(&self, name: &str, v: f64) {
        self.lock().insert(name.to_string(), v);
    }

    /// Gauge semantics keeping the maximum observed value.
    pub fn set_max(&self, name: &str, v: f64) {
        let mut m = self.lock();
        let e = m.entry(name.to_string()).or_insert(f64::NEG_INFINITY);
        if v > *e {
            *e = v;
        }
    }

    pub fn get(&self, name: &str) -> Option<f64> {
        self.lock().get(name).copied()
    }

    /// Point-in-time copy of every (name, value) pair.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        self.lock().clone()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.snapshot().into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

fn drift_rel_err(measured: f64, model: f64) -> f64 {
    if measured.abs() < DRIFT_TINY && model.abs() < DRIFT_TINY {
        0.0
    } else {
        (measured - model).abs() / model.abs().max(DRIFT_TINY)
    }
}

/// Render the per-round metrics artifact: the step series (with the
/// up/down wire split and sharded-PS staleness column), the registry
/// snapshot, and the model-drift section comparing measured simulated
/// communication time against the closed-form models per round.
pub fn metrics_json(series: &SeriesLogger, registry: &MetricsRegistry) -> Json {
    let mut rounds = Vec::with_capacity(series.steps.len());
    let mut drift_rows = Vec::with_capacity(series.steps.len());
    let mut total_measured = 0.0;
    let mut total_model = 0.0;
    let mut max_rel_err = 0.0_f64;
    for m in &series.steps {
        let mut row = BTreeMap::new();
        row.insert("step".to_string(), Json::Num(m.step as f64));
        row.insert("train_loss".to_string(), Json::Num(m.train_loss));
        row.insert("wire_bytes_up".to_string(), Json::Num(m.wire_bytes_up as f64));
        row.insert("wire_bytes_down".to_string(), Json::Num(m.wire_bytes_down as f64));
        row.insert("comm_time_s".to_string(), Json::Num(m.comm_time_s));
        row.insert("comm_model_time_s".to_string(), Json::Num(m.comm_model_time_s));
        row.insert("staleness_max_age".to_string(), Json::Num(m.staleness_max_age as f64));
        rounds.push(Json::Obj(row));

        let rel = drift_rel_err(m.comm_time_s, m.comm_model_time_s);
        max_rel_err = max_rel_err.max(rel);
        total_measured += m.comm_time_s;
        total_model += m.comm_model_time_s;
        let mut d = BTreeMap::new();
        d.insert("step".to_string(), Json::Num(m.step as f64));
        d.insert("measured_s".to_string(), Json::Num(m.comm_time_s));
        d.insert("model_s".to_string(), Json::Num(m.comm_model_time_s));
        d.insert("rel_err".to_string(), Json::Num(rel));
        drift_rows.push(Json::Obj(d));
    }
    let mut drift = BTreeMap::new();
    drift.insert("per_round".to_string(), Json::Arr(drift_rows));
    drift.insert("total_measured_s".to_string(), Json::Num(total_measured));
    drift.insert("total_model_s".to_string(), Json::Num(total_model));
    drift.insert("max_rel_err".to_string(), Json::Num(max_rel_err));

    let mut top = BTreeMap::new();
    top.insert("schema".to_string(), Json::Str(METRICS_SCHEMA.into()));
    top.insert("rounds".to_string(), Json::Arr(rounds));
    top.insert("registry".to_string(), registry.to_json());
    top.insert("model_drift".to_string(), Json::Obj(drift));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StepMetrics;

    #[test]
    fn registry_counters_and_gauges() {
        let r = MetricsRegistry::new();
        r.add("bytes", 10.0);
        r.add("bytes", 5.0);
        r.set("threads", 4.0);
        r.set("threads", 2.0);
        r.set_max("age", 1.0);
        r.set_max("age", 3.0);
        r.set_max("age", 2.0);
        assert_eq!(r.get("bytes"), Some(15.0));
        assert_eq!(r.get("threads"), Some(2.0));
        assert_eq!(r.get("age"), Some(3.0));
        assert_eq!(r.get("missing"), None);
        // clones share state
        let r2 = r.clone();
        r2.add("bytes", 1.0);
        assert_eq!(r.get("bytes"), Some(16.0));
        assert_eq!(r.snapshot().len(), 3);
    }

    #[test]
    fn registry_shared_across_threads() {
        let r = MetricsRegistry::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        r.add("n", 1.0);
                    }
                });
            }
        });
        assert_eq!(r.get("n"), Some(400.0));
    }

    #[test]
    fn metrics_json_reports_drift() {
        let mut series = SeriesLogger::new();
        series.push(StepMetrics {
            step: 0,
            train_loss: 1.5,
            wire_bytes_up: 100,
            wire_bytes_down: 40,
            comm_time_s: 1.0,
            comm_model_time_s: 1.0,
            ..Default::default()
        });
        series.push(StepMetrics {
            step: 1,
            comm_time_s: 1.01,
            comm_model_time_s: 1.0,
            staleness_max_age: 2,
            ..Default::default()
        });
        let reg = MetricsRegistry::new();
        reg.set("workers", 4.0);
        let j = metrics_json(&series, &reg);
        let j = Json::parse(&j.dump()).unwrap();
        assert_eq!(j.req("schema").unwrap().as_str(), Some(METRICS_SCHEMA));
        let rounds = j.req("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[0].req("wire_bytes_up").unwrap().as_f64(), Some(100.0));
        assert_eq!(rounds[1].req("staleness_max_age").unwrap().as_f64(), Some(2.0));
        let drift = j.req("model_drift").unwrap();
        assert_eq!(drift.req("per_round").unwrap().as_arr().unwrap().len(), 2);
        let max_err = drift.req("max_rel_err").unwrap().as_f64().unwrap();
        assert!((max_err - 0.01).abs() < 1e-12, "{max_err}");
        assert_eq!(drift.req("total_model_s").unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.req("registry").unwrap().req("workers").unwrap().as_f64(),
            Some(4.0)
        );
    }

    #[test]
    fn zero_rounds_report_zero_drift() {
        let mut series = SeriesLogger::new();
        series.push(StepMetrics::default());
        let j = metrics_json(&series, &MetricsRegistry::new());
        let max_err =
            j.req("model_drift").unwrap().req("max_rel_err").unwrap().as_f64().unwrap();
        assert_eq!(max_err, 0.0);
    }
}
