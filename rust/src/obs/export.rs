//! Trace export and validation: Chrome trace-event JSON and span
//! well-formedness checks.
//!
//! The export follows the Chrome trace-event *JSON object format*
//! (`{"traceEvents": [...]}` — loads in `chrome://tracing` and
//! Perfetto). Two processes render the recorder's two clocks:
//!
//! * **pid 1, "wall clock"** — every event, `ts` = wall microseconds
//!   since the recorder was constructed;
//! * **pid 2, "simulated link clock"** — only events carrying a finite
//!   [`Event::sim_s`] stamp, `ts` = simulated seconds × 10⁶, so the
//!   link-model timeline the `*_time` closed forms predict can be
//!   inspected next to the real one.
//!
//! Within each process there is one row per [`Track`]: the coordinator,
//! each worker, each sharded-PS shard, each pool thread and the driver,
//! named through `M`-phase `thread_name`/`process_name` metadata.

use std::collections::BTreeMap;

use super::recorder::{Event, Phase, Track};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Schema tag written into the trace artifact (Chrome ignores unknown
/// top-level keys; the obs tests pin it).
pub const TRACE_SCHEMA: &str = "orq.trace/v1";

const WALL_PID: u64 = 1;
const SIM_PID: u64 = 2;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn meta_event(pid: u64, tid: u64, name: &str, value: &str) -> Json {
    obj(vec![
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(value.into()))])),
    ])
}

fn trace_event(e: &Event, pid: u64, ts_us: f64) -> Json {
    let ph = match e.phase {
        Phase::Begin => "B",
        Phase::End => "E",
        Phase::Instant => "i",
        Phase::Counter => "C",
    };
    let mut pairs = vec![
        ("name", Json::Str(e.name.into())),
        ("cat", Json::Str(e.track.kind().into())),
        ("ph", Json::Str(ph.into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(e.track.tid() as f64)),
        ("ts", Json::Num(ts_us)),
    ];
    match e.phase {
        Phase::Counter => pairs.push(("args", obj(vec![("value", Json::Num(e.value))]))),
        // thread-scoped instants render as a tick on their own row
        Phase::Instant => pairs.push(("s", Json::Str("t".into()))),
        _ => {}
    }
    obj(pairs)
}

/// Render recorded events as Chrome trace-event JSON. Events should be
/// in record order (what [`TraceRecorder::drain`](super::TraceRecorder::drain)
/// returns); rows and both clock processes are set up via metadata
/// events, so the artifact opens with readable names.
pub fn chrome_trace_json(events: &[Event]) -> Json {
    let mut rows = Vec::new();
    rows.push(meta_event(WALL_PID, 0, "process_name", "wall clock"));
    rows.push(meta_event(SIM_PID, 0, "process_name", "simulated link clock"));
    // one thread_name per distinct track, on both processes
    let mut seen: BTreeMap<u64, Track> = BTreeMap::new();
    for e in events {
        seen.entry(e.track.tid()).or_insert(e.track);
    }
    for (tid, track) in &seen {
        rows.push(meta_event(WALL_PID, *tid, "thread_name", &track.label()));
        rows.push(meta_event(SIM_PID, *tid, "thread_name", &track.label()));
    }
    for e in events {
        rows.push(trace_event(e, WALL_PID, e.wall_us as f64));
        if e.sim_s.is_finite() {
            rows.push(trace_event(e, SIM_PID, e.sim_s * 1e6));
        }
    }
    obj(vec![
        ("schema", Json::Str(TRACE_SCHEMA.into())),
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(rows)),
    ])
}

/// Span well-formedness: on every track, each [`Phase::End`] must close
/// the innermost open [`Phase::Begin`] of the same name, and no span may
/// be left open at the end. Instants and counters are unconstrained.
/// The recorder's per-track discipline (a thread only begins/ends spans
/// on its own track) makes cross-thread interleave corruption show up
/// here as a name mismatch.
pub fn validate_spans(events: &[Event]) -> Result<()> {
    let mut stacks: BTreeMap<u64, (Track, Vec<&'static str>)> = BTreeMap::new();
    for e in events {
        let entry = stacks.entry(e.track.tid()).or_insert_with(|| (e.track, Vec::new()));
        match e.phase {
            Phase::Begin => entry.1.push(e.name),
            Phase::End => match entry.1.pop() {
                Some(open) if open == e.name => {}
                Some(open) => {
                    return Err(Error::InvalidArg(format!(
                        "span nesting violated on {}: end of {:?} closes open span {:?}",
                        e.track.label(),
                        e.name,
                        open
                    )))
                }
                None => {
                    return Err(Error::InvalidArg(format!(
                        "span nesting violated on {}: end of {:?} with no open span",
                        e.track.label(),
                        e.name
                    )))
                }
            },
            Phase::Instant | Phase::Counter => {}
        }
    }
    for (_, (track, stack)) in stacks {
        if let Some(open) = stack.last() {
            return Err(Error::InvalidArg(format!(
                "span {:?} on {} never ended",
                open,
                track.label()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{TraceLevel, TraceRecorder};

    fn sample_events() -> Vec<Event> {
        let rec = TraceRecorder::new(TraceLevel::Fine);
        rec.begin(Track::Driver, "setup");
        rec.end(Track::Driver, "setup");
        rec.begin(Track::Coordinator, "round");
        rec.begin_sim(Track::Worker(0), "uplink", 0.0);
        rec.instant_sim(Track::Worker(0), "section_ready", 0.125);
        rec.end_sim(Track::Worker(0), "uplink", 0.5);
        rec.counter(Track::Shard(2), "queue_wait_us", 12.0);
        rec.begin(Track::Pool(1), "task");
        rec.end(Track::Pool(1), "task");
        rec.end(Track::Coordinator, "round");
        rec.drain()
    }

    #[test]
    fn export_roundtrips_and_carries_both_clocks() {
        let events = sample_events();
        let j = chrome_trace_json(&events);
        // the artifact round-trips through the repo's own parser
        let j = Json::parse(&j.dump()).unwrap();
        assert_eq!(j.req("schema").unwrap().as_str(), Some(TRACE_SCHEMA));
        let rows = j.req("traceEvents").unwrap().as_arr().unwrap();
        // every row has the Chrome required keys
        for r in rows {
            for key in ["name", "ph", "pid", "tid"] {
                assert!(r.get(key).is_some(), "missing {key} in {}", r.dump());
            }
        }
        // sim-stamped events render on both pids, wall-only on one
        let count = |name: &str, pid: f64| {
            rows.iter()
                .filter(|r| {
                    r.get("name").and_then(Json::as_str) == Some(name)
                        && r.get("pid").and_then(Json::as_f64) == Some(pid)
                })
                .count()
        };
        assert_eq!(count("uplink", 1.0), 2);
        assert_eq!(count("uplink", 2.0), 2);
        assert_eq!(count("round", 1.0), 2);
        assert_eq!(count("round", 2.0), 0);
        // counters carry their value in args
        let c = rows
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some("queue_wait_us"))
            .unwrap();
        assert_eq!(c.req("args").unwrap().req("value").unwrap().as_f64(), Some(12.0));
        // distinct rows for driver/coordinator/worker/shard/pool
        let mut tids: Vec<f64> = rows
            .iter()
            .filter(|r| r.get("ph").and_then(Json::as_str) != Some("M"))
            .filter_map(|r| r.get("tid").and_then(Json::as_f64))
            .collect();
        tids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tids.dedup();
        assert_eq!(tids.len(), 5, "driver, coordinator, worker 0, shard 2, pool 1");
    }

    #[test]
    fn validate_spans_accepts_well_formed() {
        validate_spans(&sample_events()).unwrap();
        validate_spans(&[]).unwrap();
    }

    #[test]
    fn validate_spans_rejects_corruption() {
        let rec = TraceRecorder::new(TraceLevel::Round);
        rec.begin(Track::Worker(0), "backward");
        // interleaved close of a span that was never opened on this track
        rec.end(Track::Worker(0), "encode");
        let err = validate_spans(&rec.drain()).unwrap_err();
        assert!(err.to_string().contains("nesting"), "{err}");

        let rec = TraceRecorder::new(TraceLevel::Round);
        rec.end(Track::Coordinator, "round");
        assert!(validate_spans(&rec.drain()).is_err(), "end with no begin");

        let rec = TraceRecorder::new(TraceLevel::Round);
        rec.begin(Track::Coordinator, "round");
        let err = validate_spans(&rec.drain()).unwrap_err();
        assert!(err.to_string().contains("never ended"), "{err}");

        // same names on different tracks never cross-corrupt
        let rec = TraceRecorder::new(TraceLevel::Round);
        rec.begin(Track::Worker(0), "backward");
        rec.begin(Track::Worker(1), "backward");
        rec.end(Track::Worker(1), "backward");
        rec.end(Track::Worker(0), "backward");
        validate_spans(&rec.drain()).unwrap();
    }
}
