//! Run-wide observability: span tracing, named metrics, and the
//! Chrome-trace / metrics-JSON artifacts.
//!
//! # Recorder design
//!
//! [`TraceRecorder`] is a zero-dependency, sharded span recorder. A
//! cheap `Clone` (it is an `Arc` around shared state) rides along in
//! [`WireSpec`](crate::comm::WireSpec) and the
//! [`WorkerPool`](crate::quant::pool::WorkerPool), so every thread in a
//! run — coordinator, simulated workers, sharded-PS shard servers, and
//! pool threads — writes into the same recorder without new plumbing.
//! Events land in one of a fixed set of mutex-guarded buffers selected
//! by thread-id hash; with the thread counts this simulator runs
//! (≤ tens), contention is negligible and [`TraceRecorder::drain`]
//! restores global record order from a shared atomic sequence number.
//!
//! # Overhead argument
//!
//! Every recording call starts with a single `Relaxed` atomic load of
//! the enabled flag and returns immediately when it is clear — one
//! predictable branch, zero allocations, no lock touched. A disabled
//! recorder is therefore safe to leave compiled into the hot path
//! (quantize/encode/exchange loops). When enabled, the cost per event
//! is one timestamp read, one atomic increment and one short critical
//! section pushing a `Copy` struct; `perfbench`'s `obs_overhead` row
//! gates the end-to-end cost of a fully traced round at ≤ 5% in CI.
//! Tracing never touches any RNG stream, so trained parameters and
//! wire bytes are bit-identical with tracing on or off (asserted in
//! `rust/tests/obs_trace.rs`).
//!
//! # Clock semantics
//!
//! Events carry **two clocks**. The *wall clock* (`wall_us`) is real
//! microseconds since recorder construction — what the host actually
//! spent. The *simulated link clock* (`sim_s`, optional per event) is
//! the virtual network timeline the link model computes — when a
//! section became ready, when its transfer started and finished. The
//! Chrome export renders them as two processes so both timelines can
//! be read side by side; the metrics artifact's model-drift section
//! compares the simulated measurements against the closed-form
//! `*_time`/`*_overlap_time`/`*_streamed_time` models per round.

pub mod export;
pub mod recorder;
pub mod registry;

pub use export::{chrome_trace_json, validate_spans, TRACE_SCHEMA};
pub use recorder::{Event, Phase, TraceLevel, TraceRecorder, Track};
pub use registry::{metrics_json, MetricsRegistry, METRICS_SCHEMA};
