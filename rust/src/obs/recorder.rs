//! The sharded span recorder: [`TraceRecorder`] and its event model.
//!
//! ## Design
//!
//! A [`TraceRecorder`] is a cheaply-cloneable handle (`Arc` inside) that
//! every instrumented component — trainer, worker pool, collectives —
//! holds a clone of. Recording appends a fixed-size [`Event`] to one of
//! a small set of mutex-guarded buffers selected by hashing the calling
//! thread's id, so concurrent workers almost never contend on the same
//! lock and no event ever crosses a thread boundary while hot. The
//! buffers are merged and sorted by a global sequence number at
//! [`TraceRecorder::drain`] time (end of run — never on the hot path).
//!
//! ## Overhead argument
//!
//! The disabled fast path is one relaxed atomic load and a branch:
//! every recording method checks `enabled` before touching the clock,
//! the sequence counter or a buffer, so a run with tracing off performs
//! zero allocations and zero lock acquisitions on behalf of the
//! recorder. Event payloads are `Copy` (names are `&'static str`), so
//! the enabled path is one `Instant::elapsed`, two atomic ops and an
//! amortized `Vec` push under an almost-always-uncontended mutex. The
//! `obs_overhead` perfbench row gates the enabled cost in CI.
//!
//! ## Clock semantics
//!
//! Every event carries *wall* microseconds since the recorder's
//! construction (`wall_us` — real elapsed time, what a profiler wants).
//! Events stamped through the `*_sim` methods additionally carry a
//! position on the **simulated link clock** (`sim_s` — seconds on the
//! [`Link`](crate::comm::link::Link) model's clock, the one the
//! `*_time` closed-form models predict). The Chrome export renders the
//! two clocks as two processes, so a span can be inspected on either
//! timeline. `sim_s` is `NAN` when an event has no simulated position.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};

/// How much the recorder captures. Parsed from `--trace-level` /
/// `trace_level = "..."`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceLevel {
    /// Recording disabled: every recorder call is one atomic load.
    #[default]
    Off,
    /// Round-phase spans only (backward, encode, exchange, apply, …).
    Round,
    /// Everything: per-hop, per-section, per-shard and pool-task events
    /// on top of the round phases.
    Fine,
}

impl std::str::FromStr for TraceLevel {
    type Err = Error;

    fn from_str(s: &str) -> Result<TraceLevel> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "round" => Ok(TraceLevel::Round),
            "fine" => Ok(TraceLevel::Fine),
            other => Err(Error::InvalidArg(format!(
                "unknown trace level {other:?} (expected off | round | fine)"
            ))),
        }
    }
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TraceLevel::Off => "off",
            TraceLevel::Round => "round",
            TraceLevel::Fine => "fine",
        })
    }
}

/// Which timeline row an event belongs to. One row per worker, shard
/// and pool thread, plus the coordinator and the driver (main thread).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// The coordinator / server replica thread.
    Coordinator,
    /// Exchange worker `w`.
    Worker(u16),
    /// Sharded-PS server shard `s`.
    Shard(u16),
    /// Worker-pool thread `i` (the `orq-pool-{i}` spawn index).
    Pool(u16),
    /// The driving thread outside the training loop (setup, teardown).
    Driver,
}

impl Track {
    /// Stable Chrome-trace thread id for this track. Workers, shards and
    /// pool threads get disjoint ranges so rows never collide.
    pub fn tid(self) -> u64 {
        match self {
            Track::Coordinator => 0,
            Track::Worker(w) => 1 + w as u64,
            Track::Shard(s) => 100_001 + s as u64,
            Track::Pool(i) => 200_001 + i as u64,
            Track::Driver => 999_999,
        }
    }

    /// Track-kind name, used as the Chrome event category and in the
    /// per-row thread names.
    pub fn kind(self) -> &'static str {
        match self {
            Track::Coordinator => "coordinator",
            Track::Worker(_) => "worker",
            Track::Shard(_) => "shard",
            Track::Pool(_) => "pool",
            Track::Driver => "driver",
        }
    }

    /// Human-readable row label (`worker 3`, `shard 0`, …).
    pub fn label(self) -> String {
        match self {
            Track::Coordinator => "coordinator".into(),
            Track::Worker(w) => format!("worker {w}"),
            Track::Shard(s) => format!("shard {s}"),
            Track::Pool(i) => format!("pool {i}"),
            Track::Driver => "driver".into(),
        }
    }
}

/// Event kind, mirroring the Chrome trace-event phases the export emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Span start (`ph: "B"`). Must be closed by a matching [`Phase::End`]
    /// on the same track ([`validate_spans`](super::export::validate_spans)).
    Begin,
    /// Span end (`ph: "E"`).
    End,
    /// Point event (`ph: "i"`), e.g. a section becoming ready.
    Instant,
    /// Counter sample (`ph: "C"`) carrying [`Event::value`].
    Counter,
}

/// One recorded trace event. Fixed-size and `Copy`: names are static
/// strings, so recording never allocates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Static event name (span or counter name).
    pub name: &'static str,
    /// Timeline row.
    pub track: Track,
    pub phase: Phase,
    /// Wall-clock microseconds since the recorder was constructed.
    pub wall_us: u64,
    /// Global record order (drain sorts by this — wall clocks of
    /// different threads may tie at microsecond resolution).
    pub seq: u64,
    /// Position on the simulated link clock in seconds, `NAN` when the
    /// event has no simulated-clock position.
    pub sim_s: f64,
    /// Counter value ([`Phase::Counter`] only; 0 otherwise).
    pub value: f64,
}

/// Buffer shard count: enough that concurrent workers hash to distinct
/// locks with high probability, small enough that drain stays trivial.
const BUFFER_SHARDS: usize = 16;

#[derive(Debug)]
struct Inner {
    enabled: AtomicBool,
    level: TraceLevel,
    epoch: Instant,
    seq: AtomicU64,
    buffers: Vec<Mutex<Vec<Event>>>,
}

/// The run-wide span recorder. Clone freely — all clones share one
/// event store. See the module docs for the design and the disabled
/// fast-path argument.
#[derive(Debug, Clone)]
pub struct TraceRecorder(Arc<Inner>);

impl TraceRecorder {
    /// Build a recorder at `level`. `TraceLevel::Off` yields the
    /// zero-cost disabled recorder (same as [`TraceRecorder::off`]).
    pub fn new(level: TraceLevel) -> TraceRecorder {
        TraceRecorder(Arc::new(Inner {
            enabled: AtomicBool::new(level != TraceLevel::Off),
            level,
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            buffers: (0..BUFFER_SHARDS).map(|_| Mutex::new(Vec::new())).collect(),
        }))
    }

    /// The disabled recorder: one atomic load per call, no allocations.
    pub fn off() -> TraceRecorder {
        TraceRecorder::new(TraceLevel::Off)
    }

    /// Whether recording is on (one relaxed load — the fast-path check).
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.enabled.load(Ordering::Relaxed)
    }

    /// Whether fine-grained (per-hop / per-task) events should record.
    #[inline]
    pub fn is_fine(&self) -> bool {
        self.is_enabled() && self.0.level == TraceLevel::Fine
    }

    /// The level this recorder was constructed at.
    pub fn level(&self) -> TraceLevel {
        self.0.level
    }

    /// Wall-clock microseconds since construction. Works whether or not
    /// recording is enabled (the trainer's setup/train split uses it on
    /// disabled recorders too).
    pub fn now_us(&self) -> u64 {
        self.0.epoch.elapsed().as_micros() as u64
    }

    #[inline]
    fn record(&self, name: &'static str, track: Track, phase: Phase, sim_s: f64, value: f64) {
        if !self.is_enabled() {
            return;
        }
        let ev = Event {
            name,
            track,
            phase,
            wall_us: self.now_us(),
            seq: self.0.seq.fetch_add(1, Ordering::Relaxed),
            sim_s,
            value,
        };
        let mut h = DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        let slot = (h.finish() as usize) % BUFFER_SHARDS;
        // The registry holds no cross-event invariant, so a poisoned
        // lock (a panicked recording thread) is safe to recover.
        let mut buf = self.0.buffers[slot].lock().unwrap_or_else(|p| p.into_inner());
        buf.push(ev);
    }

    /// Open a span on `track` (wall clock only).
    #[inline]
    pub fn begin(&self, track: Track, name: &'static str) {
        self.record(name, track, Phase::Begin, f64::NAN, 0.0);
    }

    /// Close the innermost span named `name` on `track`.
    #[inline]
    pub fn end(&self, track: Track, name: &'static str) {
        self.record(name, track, Phase::End, f64::NAN, 0.0);
    }

    /// Point event on `track` (wall clock only).
    #[inline]
    pub fn instant(&self, track: Track, name: &'static str) {
        self.record(name, track, Phase::Instant, f64::NAN, 0.0);
    }

    /// Counter sample on `track` (wall clock only).
    #[inline]
    pub fn counter(&self, track: Track, name: &'static str, value: f64) {
        self.record(name, track, Phase::Counter, f64::NAN, value);
    }

    /// [`Self::begin`] with a simulated-clock position. Pair with
    /// [`Self::end_sim`] so the sim-clock timeline stays well-formed.
    #[inline]
    pub fn begin_sim(&self, track: Track, name: &'static str, sim_s: f64) {
        self.record(name, track, Phase::Begin, sim_s, 0.0);
    }

    /// [`Self::end`] with a simulated-clock position.
    #[inline]
    pub fn end_sim(&self, track: Track, name: &'static str, sim_s: f64) {
        self.record(name, track, Phase::End, sim_s, 0.0);
    }

    /// [`Self::instant`] with a simulated-clock position (e.g. a section
    /// readiness stamp).
    #[inline]
    pub fn instant_sim(&self, track: Track, name: &'static str, sim_s: f64) {
        self.record(name, track, Phase::Instant, sim_s, 0.0);
    }

    /// [`Self::counter`] with a simulated-clock position.
    #[inline]
    pub fn counter_sim(&self, track: Track, name: &'static str, sim_s: f64, value: f64) {
        self.record(name, track, Phase::Counter, sim_s, value);
    }

    /// Take every recorded event, merged across buffers and sorted by
    /// record order. Not a hot-path operation (end of run / of test).
    pub fn drain(&self) -> Vec<Event> {
        let mut out = Vec::new();
        for buf in &self.0.buffers {
            let mut b = buf.lock().unwrap_or_else(|p| p.into_inner());
            out.append(&mut b);
        }
        out.sort_by_key(|e| e.seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = TraceRecorder::off();
        assert!(!rec.is_enabled());
        assert!(!rec.is_fine());
        rec.begin(Track::Coordinator, "round");
        rec.end(Track::Coordinator, "round");
        rec.counter(Track::Worker(0), "bytes", 17.0);
        rec.instant_sim(Track::Worker(0), "ready", 0.5);
        assert!(rec.drain().is_empty(), "disabled recorder must stay empty");
        // the wall clock still runs (the setup/train split needs it)
        let t = rec.now_us();
        assert!(rec.now_us() >= t);
    }

    #[test]
    fn levels_parse_display_and_gate() {
        for (s, lv) in [
            ("off", TraceLevel::Off),
            ("round", TraceLevel::Round),
            ("fine", TraceLevel::Fine),
        ] {
            assert_eq!(s.parse::<TraceLevel>().unwrap(), lv);
            assert_eq!(lv.to_string(), s);
        }
        assert!("verbose".parse::<TraceLevel>().is_err());
        assert_eq!(TraceLevel::default(), TraceLevel::Off);
        assert!(TraceRecorder::new(TraceLevel::Round).is_enabled());
        assert!(!TraceRecorder::new(TraceLevel::Round).is_fine());
        assert!(TraceRecorder::new(TraceLevel::Fine).is_fine());
    }

    #[test]
    fn events_drain_in_record_order_across_threads() {
        let rec = TraceRecorder::new(TraceLevel::Fine);
        rec.begin(Track::Coordinator, "round");
        std::thread::scope(|s| {
            for w in 0..4u16 {
                let rec = rec.clone();
                s.spawn(move || {
                    rec.begin(Track::Worker(w), "backward");
                    rec.counter(Track::Worker(w), "bytes", w as f64);
                    rec.end(Track::Worker(w), "backward");
                });
            }
        });
        rec.end(Track::Coordinator, "round");
        let evs = rec.drain();
        assert_eq!(evs.len(), 2 + 4 * 3);
        // seq is strictly increasing after the merge sort
        for pair in evs.windows(2) {
            assert!(pair[0].seq < pair[1].seq);
            assert!(pair[0].wall_us <= pair[1].wall_us || pair[0].seq < pair[1].seq);
        }
        // a second drain is empty (events are taken, not copied)
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn sim_stamps_ride_along() {
        let rec = TraceRecorder::new(TraceLevel::Round);
        rec.begin_sim(Track::Coordinator, "exchange", 0.0);
        rec.instant_sim(Track::Worker(1), "section_ready", 0.25);
        rec.end_sim(Track::Coordinator, "exchange", 1.5);
        let evs = rec.drain();
        assert_eq!(evs[0].sim_s, 0.0);
        assert_eq!(evs[1].sim_s, 0.25);
        assert_eq!(evs[1].track, Track::Worker(1));
        assert_eq!(evs[2].sim_s, 1.5);
        // wall-only events carry NAN
        rec.begin(Track::Driver, "setup");
        assert!(rec.drain()[0].sim_s.is_nan());
    }

    #[test]
    fn track_ids_are_disjoint() {
        let tracks = [
            Track::Coordinator,
            Track::Worker(0),
            Track::Worker(65_535),
            Track::Shard(0),
            Track::Shard(65_535),
            Track::Pool(0),
            Track::Pool(65_535),
            Track::Driver,
        ];
        for (i, a) in tracks.iter().enumerate() {
            for b in &tracks[i + 1..] {
                assert_ne!(a.tid(), b.tid(), "{a:?} vs {b:?}");
            }
        }
        assert_eq!(Track::Worker(3).label(), "worker 3");
        assert_eq!(Track::Shard(1).kind(), "shard");
    }
}
