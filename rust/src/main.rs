//! `orq` binary — leader entrypoint: train / info / demo subcommands.

use orq::cli::{Args, USAGE};
use orq::codec::Packing;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::error::{Error, Result};
use orq::model::Backend;
use orq::quant;
use orq::quant::bucket::BucketQuantizer;
use orq::tensor::rng::Rng;
use orq::util::fmt;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_str() {
        "train" => run(cmd_train(&args)),
        "info" => run(cmd_info(&args)),
        "demo" => run(cmd_demo(&args)),
        "help" | "" => {
            print!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dataset_for(cfg: &TrainConfig) -> Result<ClassDataset> {
    let in_dim = match cfg.model.as_str() {
        "mlp_l" => 512,
        m if m.starts_with("mlp:") => m[4..]
            .split('-')
            .next()
            .and_then(|d| d.parse().ok())
            .ok_or_else(|| Error::Config(format!("bad model dims {m:?}")))?,
        _ => 256,
    };
    let spec = match cfg.dataset.as_str() {
        "cifar10" => DatasetSpec::cifar10_like(in_dim),
        "cifar100" => DatasetSpec::cifar100_like(in_dim),
        "imagenet" => DatasetSpec::imagenet_like(in_dim),
        other => return Err(Error::Config(format!("unknown dataset {other:?}"))),
    };
    Ok(ClassDataset::generate(spec))
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&[
        "config", "model", "method", "workers", "steps", "batch", "dataset", "bucket",
        "clip", "backend", "artifacts", "out", "seed", "lr", "eval-every", "topology",
        "groups", "shards", "staleness", "error-feedback", "quantize-downlink",
        "threads", "pool", "overlap", "sections", "stream-sections",
        "byte-budget", "budget-schedule",
        "trace", "trace-level",
        "intra-bandwidth", "intra-latency", "inter-bandwidth", "inter-latency",
    ])?;
    let setup_start = std::time::Instant::now();
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::load(path)?,
        None => TrainConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(m) = args.get("method") {
        cfg.method = m.to_string();
    }
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(s) = args.get_parse::<usize>("steps")? {
        cfg.steps = s;
        cfg.lr_decay_steps = vec![s / 2, s * 3 / 4];
    }
    if let Some(b) = args.get_parse::<usize>("batch")? {
        cfg.batch = b;
    }
    if let Some(w) = args.get_parse::<usize>("workers")? {
        cfg.workers = w;
        if cfg.batch % w != 0 {
            cfg.batch = (cfg.batch / w).max(1) * w;
        }
    }
    if let Some(b) = args.get_parse::<usize>("bucket")? {
        cfg.bucket_size = b;
    }
    if let Some(c) = args.get_parse::<f32>("clip")? {
        cfg.clip_factor = Some(c);
        cfg.warmup_steps = cfg.steps / 40; // the paper's 5-of-200-epoch warmup
    }
    if let Some(s) = args.get_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(lr) = args.get_parse::<f32>("lr")? {
        cfg.lr = lr;
    }
    if let Some(e) = args.get_parse::<usize>("eval-every")? {
        cfg.eval_every = e;
    }
    if let Some(t) = args.get_parse::<orq::comm::Topology>("topology")? {
        cfg.topology = t;
    }
    if let Some(g) = args.get_parse::<usize>("groups")? {
        cfg.groups = g;
    }
    if let Some(s) = args.get_parse::<usize>("shards")? {
        cfg.shards = s;
    }
    if let Some(k) = args.get_parse::<usize>("staleness")? {
        cfg.staleness = k;
    }
    if args.flag("error-feedback") {
        cfg.error_feedback = true;
    }
    if args.flag("quantize-downlink") {
        cfg.quantize_downlink = true;
    }
    if let Some(t) = args.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(p) = args.get_parse::<bool>("pool")? {
        cfg.pool = p;
    }
    if args.flag("overlap") {
        cfg.overlap = true;
    }
    if let Some(s) = args.get_parse::<usize>("sections")? {
        cfg.sections = Some(s);
    }
    if args.flag("stream-sections") {
        cfg.stream_sections = true;
        cfg.overlap = true; // same implication as `stream_sections = true` in a config file
    }
    if let Some(b) = args.get_parse::<u64>("byte-budget")? {
        if b == 0 {
            return Err(Error::Config("--byte-budget must be >= 1".into()));
        }
        cfg.byte_budget = Some(b);
    }
    if let Some(s) = args.get("budget-schedule") {
        cfg.budget_schedule = Some(s.to_string());
    }
    if let Some(b) = args.get_parse::<f64>("intra-bandwidth")? {
        cfg.links.intra_bandwidth = b;
    }
    if let Some(l) = args.get_parse::<f64>("intra-latency")? {
        cfg.links.intra_latency = l;
    }
    if let Some(b) = args.get_parse::<f64>("inter-bandwidth")? {
        cfg.links.inter_bandwidth = b;
    }
    if let Some(l) = args.get_parse::<f64>("inter-latency")? {
        cfg.links.inter_latency = l;
    }
    // --trace PATH writes a Chrome trace + metrics JSON after the run;
    // it defaults the level to `fine` so the artifact is useful without
    // a second flag. --trace-level alone just arms the recorder (the
    // spans still reach TrainOutput::obs for programmatic use).
    let trace_path = args.get("trace").map(str::to_string);
    if let Some(lv) = args.get("trace-level") {
        cfg.trace_level = lv.parse()?;
    } else if trace_path.is_some() {
        cfg.trace_level = orq::obs::TraceLevel::Fine;
    }
    if trace_path.is_some() && cfg.trace_level == orq::obs::TraceLevel::Off {
        return Err(Error::Config(
            "--trace with --trace-level off would record nothing".into(),
        ));
    }
    cfg.validate()?;

    let ds = dataset_for(&cfg)?;
    let backend_kind = args.get_or("backend", "native");
    let topo = match cfg.topology {
        orq::comm::Topology::Hier => format!("hier/{} groups", cfg.groups),
        orq::comm::Topology::ShardedPs => {
            format!("sharded-ps/{} shards, staleness {}", cfg.shards, cfg.staleness)
        }
        t => t.to_string(),
    };
    println!(
        "training {} / {} with {} on {} ({} workers, {} steps, d={}, topology={})",
        cfg.model,
        backend_kind,
        cfg.method,
        cfg.dataset,
        cfg.workers,
        cfg.steps,
        cfg.bucket_size,
        topo
    );
    // Setup (config + dataset synthesis) and the train loop are timed
    // separately: dataset generation used to dominate short runs and
    // silently inflate any single end-to-end number.
    let setup_s = setup_start.elapsed().as_secs_f64();
    let train_start = std::time::Instant::now();
    let out = match backend_kind {
        "native" => {
            let factory = native_backend_factory(&cfg.model)?;
            Trainer::new(cfg.clone(), &ds)?.run(factory)?
        }
        "pjrt" => {
            let artifacts = args.get_or("artifacts", "artifacts");
            let backend = orq::runtime::PjrtBackend::load(artifacts, &cfg.model)?;
            let factory = move |_id: usize| Box::new(backend.clone()) as Box<dyn Backend>;
            Trainer::new(cfg.clone(), &ds)?.run(factory)?
        }
        other => return Err(Error::InvalidArg(format!("unknown backend {other:?}"))),
    };
    let train_s = train_start.elapsed().as_secs_f64();

    let s = &out.summary;
    println!("\nmethod      : {}", s.method);
    println!("top-1 acc   : {:.2}%", s.test_top1 * 100.0);
    println!("top-5 acc   : {:.2}%", s.test_top5 * 100.0);
    println!("final loss  : {:.4}", s.final_train_loss);
    println!("quant relMSE: {:.4}", s.mean_quant_rel_mse);
    println!("wire bytes  : {}", fmt::bytes(s.total_wire_bytes));
    println!("comm time   : {} (simulated @10Gbps)", fmt::duration(s.total_comm_time_s));
    println!("compression : ×{:.1}", s.compression_ratio);
    println!("setup time  : {} (wall)", fmt::duration(setup_s));
    println!("train loop  : {} (wall)", fmt::duration(train_s));
    if let Some(sb) = &out.shard_bytes {
        let parts: Vec<String> = sb.iter().map(|b| fmt::bytes(*b)).collect();
        println!("shard bytes : [{}]", parts.join(", "));
        let st = &out.comm.staleness;
        if st.cold_rounds > 0 || st.max_age > 0 {
            println!(
                "staleness   : max age {} rounds, {} cold start rounds",
                st.max_age, st.cold_rounds
            );
        }
    }

    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        out.series.write_csv(&format!("{dir}/{}_{}_series.csv", s.model, s.method))?;
        out.series.write_eval_csv(&format!("{dir}/{}_{}_eval.csv", s.model, s.method))?;
        println!("series written to {dir}/");
    }
    if let Some(path) = &trace_path {
        let obs = out.obs.as_ref().ok_or_else(|| {
            Error::Comm("tracing was armed but the run produced no events".into())
        })?;
        obs.registry.set("setup_wall_s", setup_s);
        obs.registry.set("train_wall_s", train_s);
        std::fs::write(path, orq::obs::chrome_trace_json(&obs.events).dump())?;
        let metrics_path = match path.strip_suffix(".json") {
            Some(stem) => format!("{stem}.metrics.json"),
            None => format!("{path}.metrics.json"),
        };
        let mjson = orq::obs::metrics_json(&out.series, &obs.registry);
        std::fs::write(&metrics_path, mjson.dump())?;
        println!(
            "trace written to {path} ({} events; metrics to {metrics_path})",
            obs.events.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"])?;
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = orq::runtime::meta::Manifest::load(dir)?;
    println!("artifacts at {dir}:");
    for m in &manifest.models {
        println!(
            "  {} ({:?}) — {} params, batch {}, {} sections, grad={}, fwd={}",
            m.name,
            m.kind,
            fmt::commas(m.param_count as u64),
            m.batch,
            m.sections.len(),
            m.grad_hlo,
            m.fwd_hlo
        );
    }
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    args.check_known(&["method", "n", "bucket", "seed"])?;
    let method = args.get_or("method", "orq-9");
    let n = args.get_parse::<usize>("n")?.unwrap_or(1 << 20);
    let bucket = args.get_parse::<usize>("bucket")?.unwrap_or(2048);
    let seed = args.get_parse::<u64>("seed")?.unwrap_or(42);

    let q = quant::from_name(method)?;
    let mut rng = Rng::seed_from(seed);
    let mut g = vec![0.0f32; n];
    rng.fill_gaussian(&mut g, 1e-3);
    let bq = BucketQuantizer::new(bucket);
    let t0 = std::time::Instant::now();
    let qg = bq.quantize(&g, q.as_ref(), &mut rng);
    let quant_t = t0.elapsed().as_secs_f64();
    let bytes = orq::codec::encode(&qg, method, Packing::BaseS);
    let err = quant::error::measure(&g, &qg);
    println!("method        : {method} (s={}, unbiased={})", q.num_levels(), q.is_unbiased());
    println!("elements      : {}", fmt::commas(n as u64));
    println!(
        "quantize time : {} ({:.1} Melem/s)",
        fmt::duration(quant_t),
        n as f64 / quant_t / 1e6
    );
    println!(
        "wire size     : {} (fp32: {})",
        fmt::bytes(bytes.len() as u64),
        fmt::bytes(4 * n as u64)
    );
    println!("compression   : ×{:.1}", 4.0 * n as f64 / bytes.len() as f64);
    println!("rel MSE       : {:.6}", err.rel_mse);
    println!("cosine        : {:.6}", err.cosine);
    Ok(())
}
