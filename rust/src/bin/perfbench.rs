//! perfbench — the repo's machine-readable performance harness.
//!
//! Measures, in one run, both the new fast paths and their retained
//! baselines (so every speedup figure is a same-machine comparison):
//!
//! * **codec kernels** — fixed-width pack/unpack, word-level vs the
//!   scalar reference, and base-s unpack, reciprocal vs the `%`/`/`
//!   scalar reference → `BENCH_codec.json`;
//! * **quantize throughput** — per-scheme Melem/s (level solve +
//!   rounding), plus serial vs parallel quantize+encode through
//!   `GradCodec` → `BENCH_exchange.json`;
//! * **exchange rounds** — end-to-end `comm::run_rounds` wall time for
//!   ps (serial, pooled-parallel and scoped-parallel codec paths), ring,
//!   hier, and the sharded parameter server (synchronous and with a
//!   staleness window) → `BENCH_exchange.json`;
//! * **amortization** — round-1 (pool spawn + arena growth) vs
//!   steady-state cost of the pooled paths, so the cross-round win of
//!   the persistent worker pool is measured, not asserted.
//!
//! ## JSON schema
//!
//! `BENCH_codec.json` (v2): `{ schema: "orq.perfbench.codec/v2", mode,
//! elements, kernels: [{kernel: "fixed"|"base_s"|"round", bits|s, op:
//! "pack"|"unpack"|"round", path: "word"|"scalar"|"recip", mean_s, gb_s,
//! melem_s, wire_bytes}], speedup: {fixed_pack_unpack, base_s_unpack,
//! round_twopass} }`. v2 preserves every v1 field and adds the
//! stochastic-rounding rows: the autovectorization-friendly two-pass
//! kernel (`path: "word"`) vs the retained fused scalar reference
//! (`path: "scalar"`, `quant::random_round_reference`), with
//! `speedup.round_twopass = scalar / two-pass`.
//!
//! `BENCH_exchange.json` (v8): `{ schema: "orq.perfbench.exchange/v8",
//! mode, elements, workers, threads, bucket_size, quantize: [{method,
//! path: "serial"|"parallel"|"parallel-scoped", mean_s, melem_s}],
//! rounds: [{topology, path, mean_s, wire_bytes, sim_time_s, shards,
//! staleness}], amortization: {quantize_encode: {round1_s, steady_s,
//! rounds}, ps_round: {round1_s, steady_s, rounds}}, overlap:
//! {model_params, sections, batch, flat_s, overlap_s, section_bytes,
//! ps_model_err_pct}, downlink: {topology, rounds, fp | quantized |
//! quantized_ef: {wire_bytes_up, wire_bytes_down, mean_s, sim_time_s}},
//! streaming: {topology, sections, ready_last_s, flat_round_sim,
//! streamed_round_sim, flat_s, streamed_s, ps_model_err_pct, timeline:
//! [{section, ready_t, link_start_t, done_t}]}, obs: {topology, path,
//! untraced_s, traced_s, events_per_round, wire_bytes}, budget:
//! {method, elements, fixed_wire_bytes, fixed_variance, points:
//! [{budget_bytes, wire_bytes, variance}]}, speedup:
//! {quantize_encode, ps_round, pooled_round, overlap_round,
//! downlink_compression, streamed_round, obs_overhead, budget_bytes}
//! }`. v3 preserved every v2 field (which
//! preserved every v1 field) and added: the `path: "parallel-scoped"`
//! quantize and ps-round entries — the retained PR 3/4 per-round
//! `std::thread::scope` execution, measured in the same run as the
//! pooled default (`path: "parallel"`) so `speedup.pooled_round =
//! scoped / pooled` is a same-machine figure — and the `amortization`
//! section (first pooled call vs steady-state mean: round 1 pays the
//! thread spawns and the solver-arena growth that steady-state rounds
//! no longer do). Every round entry is a per-round average over the
//! same fixed multi-round window (the largest `K + 1` in the set), so
//! async warm rounds (mean pull + decode) are in the measurement and
//! per-iteration topology setup amortizes identically across entries.
//! v4 added the `overlap` section: backward+encode wall time on a real
//! native MLP, flat (sequential backward then encode) vs overlapped
//! (sections encode on the pool while the backward tail runs,
//! `comm::overlap`), with the assembled messages asserted
//! byte-identical and `speedup.overlap_round = flat / overlapped`;
//! `ps_model_err_pct` verifies the overlapped closed-form PS model
//! against the measured simulated round (degenerate case — every
//! section ready at t = 0 on the zero-latency link sums to the flat
//! model) to < 1%. v5 adds the `downlink` section (the PR 7 tentpole):
//! the same ps round with the mean broadcast FP, requantized once at
//! the server, and requantized with the server-side downlink residual
//! armed (TernGrad-style bidirectional compression) — per-edge-class
//! byte accounting shows the uplink untouched and the downlink shrunk,
//! and `speedup.downlink_compression = fp down bytes / quantized down
//! bytes` is a deterministic codec-accounting ratio the CI floor gates
//! (it catches the downlink silently falling back to FP, not noise).
//! v6 adds the `streaming` section (the PR 8 tentpole): the same ps
//! round flat (the uplink can only start once backward ends) vs
//! section-streamed (`comm::run_rounds_streamed` — each section frame
//! rides the link the moment its encode completes). The per-section
//! `timeline` rows replay the closed-form `ps_streamed_time` recurrence
//! on the real frame byte sizes (`link_start_t = max(prev done_t,
//! ready_t)`), checked against the measured simulated round to < 1%,
//! and `speedup.streamed_round = (ready_last + flat sim) / streamed
//! sim` — deterministic link-model accounting (the streamed clock
//! starts at backward start and includes every readiness wait, so the
//! fair flat baseline is backward end plus the flat round). The CI
//! floor gates it at 0.9: it catches streaming regressing the round,
//! not runner noise. v7 adds the `obs` section (the PR 9 tentpole): the
//! same pooled-parallel ps round untraced (the disabled
//! `obs::TraceRecorder` — one relaxed atomic load per site) vs fully
//! traced at `fine` level (phase spans, collective-interior hops, pool
//! queue-wait counters), with wire bytes asserted identical across the
//! two runs. `speedup.obs_overhead = untraced / traced` and the CI
//! floor gates it at 0.95 — a fully traced round may cost at most ~5%.
//! v8 adds the `budget` section (the PR 10 tentpole): the
//! accuracy-vs-bytes Pareto of the adaptive byte budget
//! (`quant::budget::allocate_widths`) against the fixed-width codec on
//! the same gradient — one point per budget (a rising fraction of the
//! fixed wire bytes), each reporting the actual wire bytes spent
//! (headers and width table included, asserted ≤ the budget) and the
//! total quantization variance `‖g − decode(encode(g))‖²`. The points
//! must be Pareto-monotone: spend non-decreasing and variance
//! non-increasing in the budget. `speedup.budget_bytes = fixed wire
//! bytes / budgeted wire bytes at the 60% point` is deterministic codec
//! accounting the CI floor gates at 1.3 — it catches the allocator
//! silently falling back to fixed widths, not runner noise.
//!
//! `--smoke` runs small sizes, then re-parses both artifacts and asserts
//! the schema plus monotone sanity (sizes and rates positive, fixed-width
//! wire bytes grow with width, base-3 beats 2-bit fixed) — no timing
//! thresholds, so it is CI-safe on noisy runners.
//!
//! `--floors ci/perf_floors.json` compares the exchange speedups against
//! committed floors and exits non-zero below any of them — the CI
//! regression gate (floors are deliberately generous: they catch a lost
//! optimization, not runner noise).

use std::collections::BTreeMap;

use orq::bench::{print_table, Bench, Measurement};
use orq::cli::Args;
use orq::codec::bitpack;
use orq::comm::link::{Link, LinkMap};
use orq::comm::{
    run_rounds, run_rounds_streamed, ExchangeConfig, GradCodec, PoolMode, Topology, WireSpec,
};
use orq::error::{Error, Result};
use orq::quant::bucket::{BucketQuantizer, QuantizedGrad};
use orq::quant::pool::PoolHandle;
use orq::tensor::rng::Rng;
use orq::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("perfbench: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["smoke", "out", "n", "threads", "workers", "floors"])?;
    let smoke = args.flag("smoke");
    let out_dir = args.get_or("out", ".").to_string();
    let n: usize = args
        .get_parse("n")?
        .unwrap_or(if smoke { 1 << 16 } else { 1 << 22 });
    let threads = match args.get_parse("threads")?.unwrap_or(0) {
        0 => orq::quant::pool::auto_threads().min(256),
        t => t.min(256),
    };
    let workers: usize = args.get_parse("workers")?.unwrap_or(2);
    let bench = if smoke {
        Bench { warmup_iters: 1, iters: 5, max_seconds: 2.0 }
    } else {
        Bench::from_env()
    };
    let mode = if smoke { "smoke" } else { "full" };

    let codec_json = bench_codec(&bench, n, mode);
    let exchange_json = bench_exchange(&bench, n, workers, threads, mode, smoke)?;

    std::fs::create_dir_all(&out_dir)?;
    let codec_path = format!("{out_dir}/BENCH_codec.json");
    let exchange_path = format!("{out_dir}/BENCH_exchange.json");
    std::fs::write(&codec_path, codec_json.dump())?;
    std::fs::write(&exchange_path, exchange_json.dump())?;
    println!("\nwrote {codec_path} and {exchange_path}");
    if smoke {
        validate_codec(&codec_json)?;
        validate_exchange(&exchange_json)?;
        println!("smoke validation OK: schema + monotone sanity checks passed");
    }
    if let Some(floors_path) = args.get("floors") {
        check_floors(&exchange_json, floors_path)?;
    }
    Ok(())
}

/// CI regression gate: every speedup named in the floors file must meet
/// its committed floor. Floors are generous by design — they exist to
/// catch a lost optimization (a pooled path silently falling back to
/// spawns, a parallel path serializing), not to measure runner noise.
fn check_floors(exchange: &Json, floors_path: &str) -> Result<()> {
    let text = std::fs::read_to_string(floors_path)?;
    let floors = Json::parse(&text)?;
    let want = floors
        .req("speedup")?
        .as_obj()
        .ok_or_else(|| Error::InvalidArg("floors: speedup is not an object".into()))?;
    let got = exchange.req("speedup")?;
    let mut failures = Vec::new();
    for (key, floor) in want {
        let floor = floor.as_f64().ok_or_else(|| {
            Error::InvalidArg(format!("floors: speedup.{key} is not a number"))
        })?;
        let measured = req_f64(got, key)?;
        let verdict = if measured >= floor { "ok" } else { "BELOW FLOOR" };
        println!("perf gate: speedup.{key} = {measured:.3} (floor {floor:.3}) {verdict}");
        if measured < floor {
            failures.push(format!("speedup.{key} = {measured:.3} < floor {floor:.3}"));
        }
    }
    if failures.is_empty() {
        println!("perf gate OK: all floors met ({floors_path})");
        Ok(())
    } else {
        Err(Error::InvalidArg(format!(
            "perf regression gate failed: {} (floors in {floors_path})",
            failures.join("; ")
        )))
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from(seed);
    let mut g = vec![0.0f32; n];
    rng.fill_gaussian(&mut g, 1e-3);
    g
}

fn rand_indices(n: usize, s: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| rng.below(s as u64) as u8).collect()
}

/// One kernel row: timing + derived throughputs, keyed by kernel family,
/// width parameter, op and path.
fn kernel_entry(
    kernel: &str,
    (param_key, param): (&str, usize),
    op: &str,
    path: &str,
    m: &Measurement,
    wire_bytes: usize,
) -> Json {
    obj(vec![
        ("kernel", Json::Str(kernel.to_string())),
        (param_key, Json::Num(param as f64)),
        ("op", Json::Str(op.to_string())),
        ("path", Json::Str(path.to_string())),
        ("mean_s", Json::Num(m.mean_s)),
        ("melem_s", Json::Num(m.throughput().unwrap_or(0.0) / 1e6)),
        ("gb_s", Json::Num(wire_bytes as f64 / m.mean_s.max(1e-12) / 1e9)),
        ("wire_bytes", Json::Num(wire_bytes as f64)),
    ])
}

fn bench_codec(bench: &Bench, n: usize, mode: &str) -> Json {
    let mut rows = Vec::new();
    let mut kernels = Vec::new();
    let (mut fixed_word, mut fixed_scalar) = (0.0f64, 0.0f64);
    let (mut recip_s, mut scalar_s) = (0.0f64, 0.0f64);

    // ---- fixed-width: word kernels vs scalar reference ----
    for bits in [1u32, 2, 3, 4, 8] {
        let s = 1usize << bits;
        let idx = rand_indices(n, s, bits as u64);
        let wire = (n * bits as usize).div_ceil(8);
        let mut out = Vec::new();
        let mut dec = Vec::new();
        // correctness outside the timers: word == scalar, roundtrip exact
        let packed = bitpack::pack_fixed(&idx, bits);
        let mut scalar_packed = Vec::new();
        bitpack::pack_fixed_scalar_into(&idx, bits, &mut scalar_packed);
        assert_eq!(packed, scalar_packed, "word/scalar pack divergence at bits={bits}");
        assert_eq!(bitpack::unpack_fixed(&packed, n, bits).unwrap(), idx);

        for (path, scalar) in [("word", false), ("scalar", true)] {
            let m = bench.measure(&format!("pack fixed{bits} {path}"), Some(n as u64), || {
                out.clear();
                if scalar {
                    bitpack::pack_fixed_scalar_into(&idx, bits, &mut out);
                } else {
                    bitpack::pack_fixed_into(&idx, bits, &mut out);
                }
                std::hint::black_box(out.len());
            });
            *(if scalar { &mut fixed_scalar } else { &mut fixed_word }) += m.mean_s;
            kernels.push(kernel_entry("fixed", ("bits", bits as usize), "pack", path, &m, wire));
            rows.push(m);
            let m = bench.measure(&format!("unpack fixed{bits} {path}"), Some(n as u64), || {
                let r = if scalar {
                    bitpack::unpack_fixed_scalar_into(&packed, n, bits, &mut dec)
                } else {
                    bitpack::unpack_fixed_into(&packed, n, bits, &mut dec)
                };
                r.expect("exact payload");
                std::hint::black_box(dec.len());
            });
            *(if scalar { &mut fixed_scalar } else { &mut fixed_word }) += m.mean_s;
            kernels.push(kernel_entry("fixed", ("bits", bits as usize), "unpack", path, &m, wire));
            rows.push(m);
        }
    }
    print_table(&format!("Fixed-width kernels — {n} elements, word vs scalar"), &rows);

    // ---- base-s: reciprocal decode vs scalar %// reference ----
    let mut rows = Vec::new();
    for s in [3usize, 5, 9, 255] {
        let idx = rand_indices(n, s, 1000 + s as u64);
        let radix = bitpack::Radix::new(s);
        let wire = n.div_ceil(radix.digits_per_word()) * 8;
        let mut out = Vec::new();
        let mut dec = Vec::new();
        let packed = bitpack::pack_base_s(&idx, s);
        let mut scalar_dec = Vec::new();
        bitpack::unpack_base_s_scalar_into(&packed, n, s, &mut scalar_dec).unwrap();
        assert_eq!(scalar_dec, idx, "recip/scalar unpack divergence at s={s}");

        let m = bench.measure(&format!("pack base{s}"), Some(n as u64), || {
            out.clear();
            radix.pack_into(&idx, &mut out);
            std::hint::black_box(out.len());
        });
        kernels.push(kernel_entry("base_s", ("s", s), "pack", "word", &m, wire));
        rows.push(m);
        for (path, scalar) in [("recip", false), ("scalar", true)] {
            let m = bench.measure(&format!("unpack base{s} {path}"), Some(n as u64), || {
                let r = if scalar {
                    bitpack::unpack_base_s_scalar_into(&packed, n, s, &mut dec)
                } else {
                    radix.unpack_into(&packed, n, &mut dec)
                };
                r.expect("exact payload");
                std::hint::black_box(dec.len());
            });
            *(if scalar { &mut scalar_s } else { &mut recip_s }) += m.mean_s;
            kernels.push(kernel_entry("base_s", ("s", s), "unpack", path, &m, wire));
            rows.push(m);
        }
    }
    print_table(&format!("Base-s kernels — {n} digits, reciprocal vs scalar"), &rows);

    // ---- stochastic rounding: two-pass lane-block kernel vs the
    // retained fused scalar reference ----
    let mut rows = Vec::new();
    let (mut round_twopass, mut round_scalar) = (0.0f64, 0.0f64);
    for s in [3usize, 5, 9] {
        let levels: Vec<f32> =
            (0..s).map(|i| -1.0 + 2.0 * i as f32 / (s - 1) as f32).collect();
        // spread the gaussian across the level table so bracketing is
        // exercised, not just the center bracket
        let g: Vec<f32> = gaussian(n, 40 + s as u64).iter().map(|v| v * 600.0).collect();
        // correctness outside the timers: identical indices, identical
        // RNG consumption
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let mut ra = Rng::seed_from(9);
        let mut rb = Rng::seed_from(9);
        orq::quant::random_round(&g, &levels, &mut ra, &mut a);
        orq::quant::random_round_reference(&g, &levels, &mut rb, &mut b);
        assert_eq!(a, b, "two-pass/scalar rounding divergence at s={s}");
        let wire = n; // one index byte per element, pre-packing
        for (path, scalar) in [("word", false), ("scalar", true)] {
            let mut rng = Rng::seed_from(11);
            let mut out = Vec::new();
            let m = bench.measure(&format!("round s={s} {path}"), Some(n as u64), || {
                if scalar {
                    orq::quant::random_round_reference(&g, &levels, &mut rng, &mut out);
                } else {
                    orq::quant::random_round(&g, &levels, &mut rng, &mut out);
                }
                std::hint::black_box(out.len());
            });
            *(if scalar { &mut round_scalar } else { &mut round_twopass }) += m.mean_s;
            kernels.push(kernel_entry("round", ("s", s), "round", path, &m, wire));
            rows.push(m);
        }
    }
    print_table(&format!("Stochastic rounding — {n} elements, two-pass vs scalar"), &rows);

    let speedup = obj(vec![
        ("fixed_pack_unpack", Json::Num(fixed_scalar / fixed_word.max(1e-12))),
        ("base_s_unpack", Json::Num(scalar_s / recip_s.max(1e-12))),
        ("round_twopass", Json::Num(round_scalar / round_twopass.max(1e-12))),
    ]);
    println!(
        "codec speedups: fixed pack+unpack ×{:.2}, base-s unpack ×{:.2}, \
         stochastic round ×{:.2}",
        fixed_scalar / fixed_word.max(1e-12),
        scalar_s / recip_s.max(1e-12),
        round_scalar / round_twopass.max(1e-12)
    );
    obj(vec![
        ("schema", Json::Str("orq.perfbench.codec/v2".into())),
        ("mode", Json::Str(mode.into())),
        ("elements", Json::Num(n as f64)),
        ("kernels", Json::Arr(kernels)),
        ("speedup", speedup),
    ])
}

fn bench_exchange(
    bench: &Bench,
    n: usize,
    workers: usize,
    threads: usize,
    mode: &str,
    smoke: bool,
) -> Result<Json> {
    let bucket = 512usize;
    let method = "orq-5";
    let g = gaussian(n, 1);
    // One persistent pool for every pooled figure in this run: codecs,
    // shard servers and the run_rounds drivers share it, so repeated
    // bench iterations measure *steady-state* pooled rounds (round-1
    // costs are quantified separately in the amortization section).
    let pool = PoolHandle::new(threads);
    let shared = PoolMode::Shared(pool.clone());

    // ---- per-scheme quantize throughput (serial, d = 2048) ----
    let mut rows = Vec::new();
    let mut quantize = Vec::new();
    let bq = BucketQuantizer::new(2048);
    for m in orq::quant::paper_methods() {
        if m == "fp" {
            continue;
        }
        let q = orq::quant::from_name(m)?;
        let mut qrng = Rng::seed_from(2);
        let mut qg = QuantizedGrad::default();
        let meas = bench.measure(&format!("quantize {m}"), Some(n as u64), || {
            bq.quantize_into(&g, q.as_ref(), &mut qrng, &mut qg);
            std::hint::black_box(qg.buckets.len());
        });
        quantize.push(obj(vec![
            ("method", Json::Str(m.to_string())),
            ("path", Json::Str("serial".into())),
            ("mean_s", Json::Num(meas.mean_s)),
            ("melem_s", Json::Num(meas.throughput().unwrap_or(0.0) / 1e6)),
        ]));
        rows.push(meas);
    }
    print_table(&format!("Quantize throughput — {n} elements, d=2048, serial"), &rows);

    // ---- quantize+encode: serial GradCodec vs parallel pipeline, the
    // parallel path in both execution modes (pooled default vs the
    // retained scoped-thread baseline) ----
    let mut rows = Vec::new();
    let mut qe = [0.0f64; 3]; // [serial, parallel (pooled), parallel-scoped]
    let qe_paths: [(&str, usize, PoolMode); 3] = [
        ("serial", 1, PoolMode::Scoped),
        ("parallel", threads, shared.clone()),
        ("parallel-scoped", threads, PoolMode::Scoped),
    ];
    for (i, (path, t, pm)) in qe_paths.into_iter().enumerate() {
        let spec = WireSpec::new(method, bucket).with_threads(t).with_pool_mode(pm);
        let mut gc = GradCodec::new(&spec)?;
        let mut rng = Rng::seed_from(3);
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();
        let meas = bench.measure(
            &format!("quantize+encode {method} {path} (t={t})"),
            Some(n as u64),
            || {
                gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
                std::hint::black_box(msg.len());
            },
        );
        qe[i] = meas.mean_s;
        quantize.push(obj(vec![
            ("method", Json::Str(method.to_string())),
            ("path", Json::Str(path.to_string())),
            ("mean_s", Json::Num(meas.mean_s)),
            ("melem_s", Json::Num(meas.throughput().unwrap_or(0.0) / 1e6)),
        ]));
        rows.push(meas);
    }
    print_table(
        &format!(
            "Quantize+encode — {method}, d={bucket}, serial vs {threads} threads \
             (pooled and scoped)"
        ),
        &rows,
    );

    // ---- end-to-end exchange rounds ----
    let link = Link::ten_gbps();
    let grads: Vec<Vec<f32>> = (0..workers).map(|w| gaussian(n, 10 + w as u64)).collect();
    let groups = if workers % 2 == 0 { 2 } else { 1 };
    let configs: Vec<(&str, &str, ExchangeConfig, usize, PoolMode)> = vec![
        ("ps", "serial", ExchangeConfig::flat(Topology::Ps, link), 1, shared.clone()),
        ("ps", "parallel", ExchangeConfig::flat(Topology::Ps, link), threads, shared.clone()),
        (
            "ps",
            "parallel-scoped",
            ExchangeConfig::flat(Topology::Ps, link),
            threads,
            PoolMode::Scoped,
        ),
        ("ring", "serial", ExchangeConfig::flat(Topology::Ring, link), 1, shared.clone()),
        (
            "hier",
            "serial",
            ExchangeConfig::hier(groups, LinkMap::uniform(link)),
            1,
            shared.clone(),
        ),
        ("sharded-ps", "serial", ExchangeConfig::sharded(2, 0, link), 1, shared.clone()),
        ("sharded-ps", "async", ExchangeConfig::sharded(2, 2, link), 1, shared.clone()),
    ];
    // One measurement window for EVERY entry — the largest staleness
    // window in the set — so warm async rounds (mean pull + decode) are
    // in the measurement AND the per-iteration topology setup amortizes
    // identically across entries (figures stay comparable). All reported
    // round figures are per-round averages over this window. Pooled
    // entries reuse one persistent pool across iterations — steady
    // state — while `parallel-scoped` re-spawns per round, exactly the
    // cost the pool removes.
    let window = configs.iter().map(|(_, _, c, _, _)| c.staleness + 1).max().unwrap_or(1);
    let inv = 1.0 / window as f64;
    let mut rows = Vec::new();
    let mut round_entries = Vec::new();
    let mut ps_round = [0.0f64; 3]; // [serial, parallel (pooled), parallel-scoped]
    for (topo, path, cfg, t, pm) in configs {
        let spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
            .with_threads(t)
            .with_pool_mode(pm);
        // one validated window outside the timer, for stats + fail-fast
        let (_, stats) = run_rounds(&cfg, &spec, &grads, window)?;
        let meas = bench.measure(&format!("{topo} round {path} (t={t})"), None, || {
            let out = run_rounds(&cfg, &spec, &grads, window).expect("validated above");
            std::hint::black_box(out.1.wire_bytes);
        });
        if topo == "ps" {
            let slot = match path {
                "serial" => 0,
                "parallel" => 1,
                _ => 2,
            };
            ps_round[slot] = meas.mean_s;
        }
        round_entries.push(obj(vec![
            ("topology", Json::Str(topo.to_string())),
            ("path", Json::Str(path.to_string())),
            ("mean_s", Json::Num(meas.mean_s * inv)),
            ("wire_bytes", Json::Num(stats.wire_bytes as f64 * inv)),
            ("sim_time_s", Json::Num(stats.sim_time_s * inv)),
            ("shards", Json::Num(cfg.shards as f64)),
            ("staleness", Json::Num(cfg.staleness as f64)),
        ]));
        rows.push(meas);
    }
    print_table(
        &format!("Exchange rounds — {workers} workers × {n} elements, {method}, d={bucket}"),
        &rows,
    );

    let amortization = bench_amortization(n, threads, workers, bucket, method, &grads, smoke)?;
    let (overlap, overlap_round) =
        bench_overlap(bench, threads, workers, bucket, method, &shared, smoke)?;
    let (downlink, downlink_compression) =
        bench_downlink(bench, workers, bucket, method, &grads)?;
    let (streaming, streamed_round) = bench_streaming(bench, workers, bucket, method, &grads)?;
    let (obs, obs_overhead) = bench_obs_overhead(bench, workers, threads, bucket, method, &grads)?;
    let (budget_section, budget_bytes_ratio) = bench_budget_pareto(n, bucket, method)?;

    let speedup = obj(vec![
        ("quantize_encode", Json::Num(qe[0] / qe[1].max(1e-12))),
        ("ps_round", Json::Num(ps_round[0] / ps_round[1].max(1e-12))),
        // pooled vs scoped on the same parallel ps round — the tentpole
        // figure the CI floor gates (steady-state pooled must not lose
        // to per-round spawns).
        ("pooled_round", Json::Num(ps_round[2] / ps_round[1].max(1e-12))),
        // flat backward→encode vs the section-overlapped driver on the
        // same model, batch and pool — the PR 6 figure the CI floor
        // gates (overlap must not lose the hidden-encode win).
        ("overlap_round", Json::Num(overlap_round)),
        // fp / quantized broadcast bytes on the same ps round — exact
        // codec accounting (deterministic, not timing), so the CI floor
        // catches the downlink silently falling back to FP.
        ("downlink_compression", Json::Num(downlink_compression)),
        // (ready_last + flat sim) / streamed sim on the same ps round —
        // deterministic link-model accounting (the streamed clock
        // starts at backward start), so the CI floor catches streaming
        // regressing the round, not runner noise.
        ("streamed_round", Json::Num(streamed_round)),
        // untraced / fine-traced pooled ps round — the PR 9 observability
        // contract the CI floor gates (a fully traced round may cost at
        // most ~5%; a miss means recording leaked onto the disabled fast
        // path or the traced path grew a hot-loop allocation).
        ("obs_overhead", Json::Num(obs_overhead)),
        // fixed wire bytes / budgeted wire bytes at the 60% budget
        // point — deterministic codec accounting (the PR 10 tentpole
        // figure), so the CI floor catches the byte-budget allocator
        // silently falling back to fixed widths.
        ("budget_bytes", Json::Num(budget_bytes_ratio)),
    ]);
    println!(
        "exchange speedups ({threads} threads): quantize+encode ×{:.2} (serial/pooled), \
         ps round ×{:.2} (serial/pooled), ps round ×{:.2} (scoped/pooled), \
         backward+encode ×{overlap_round:.2} (flat/overlapped), \
         downlink bytes ×{downlink_compression:.2} (fp/quantized broadcast), \
         streamed round ×{streamed_round:.2} (backward-end+flat / streamed, simulated), \
         obs overhead ×{obs_overhead:.2} (untraced/traced)",
        qe[0] / qe[1].max(1e-12),
        ps_round[0] / ps_round[1].max(1e-12),
        ps_round[2] / ps_round[1].max(1e-12)
    );
    Ok(obj(vec![
        ("schema", Json::Str("orq.perfbench.exchange/v8".into())),
        ("mode", Json::Str(mode.into())),
        ("elements", Json::Num(n as f64)),
        ("workers", Json::Num(workers as f64)),
        ("threads", Json::Num(threads as f64)),
        ("bucket_size", Json::Num(bucket as f64)),
        ("quantize", Json::Arr(quantize)),
        ("rounds", Json::Arr(round_entries)),
        ("amortization", amortization),
        ("overlap", overlap),
        ("downlink", downlink),
        ("streaming", streaming),
        ("obs", obs),
        ("budget", budget_section),
        ("speedup", speedup),
    ]))
}

/// Accuracy-vs-bytes Pareto under the adaptive byte budget (the PR 10
/// tentpole figure): encode the same gradient with the fixed-width
/// codec and with `--byte-budget` at a rising fraction of the fixed
/// wire bytes. Every figure is deterministic codec accounting — actual
/// message bytes (header and in-band width table included, asserted ≤
/// the budget) and the total quantization variance
/// `‖g − decode(encode(g))‖²` of the bytes that would hit the wire —
/// so the CI floor catches the allocator silently falling back to
/// fixed widths, not runner noise.
///
/// Returns the `budget` JSON section and `fixed wire bytes / budgeted
/// wire bytes` at the 60% point (`speedup.budget_bytes`).
fn bench_budget_pareto(n: usize, bucket: usize, method: &str) -> Result<(Json, f64)> {
    use orq::codec::Packing;
    use orq::quant::budget;

    // The budget re-spends bit widths per bucket, so it needs a
    // parameterizable scheme; fall back to orq-8 if the bench method is
    // fixed-level (the section is about the allocator, not the method).
    let method = if budget::parse_family(method).is_some() { method } else { "orq-8" };
    let g = gaussian(n, 23);
    let spec = WireSpec { seed: 11, ..WireSpec::new(method, bucket) };
    let measure = |byte_budget: Option<usize>| -> Result<(usize, f64)> {
        let mut gc = GradCodec::new(&spec)?;
        if let Some(b) = byte_budget {
            gc.set_budget(b, None)?;
        }
        let mut rng = Rng::seed_from(13);
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();
        gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
        let mut deq = Vec::new();
        gc.decode_flat_into(&msg, &mut deq)?;
        let variance: f64 =
            g.iter().zip(&deq).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
        Ok((msg.len(), variance))
    };
    let (fixed_bytes, fixed_var) = measure(None)?;
    // Budgets below the all-2 floor cannot be honored — clamp so every
    // point is a real spend target (the trainer rejects such budgets).
    let floor = budget::min_message_bytes(n, bucket, Packing::BaseS, method);
    let fracs = [0.40, 0.60, 0.75, 0.90, 1.00];
    let mut points = Vec::new();
    let mut ratio_at_60 = 0.0f64;
    for f in fracs {
        let b = ((fixed_bytes as f64 * f) as usize).max(floor);
        let (bytes, var) = measure(Some(b))?;
        assert!(
            bytes <= b,
            "budgeted encode spent {bytes} bytes over the {b}-byte budget"
        );
        if f == 0.60 {
            ratio_at_60 = fixed_bytes as f64 / bytes.max(1) as f64;
        }
        points.push(obj(vec![
            ("budget_bytes", Json::Num(b as f64)),
            ("wire_bytes", Json::Num(bytes as f64)),
            ("variance", Json::Num(var)),
        ]));
    }
    println!(
        "budget pareto ({method}, {n} elements): fixed {fixed_bytes} B / var {fixed_var:.3e}; \
         60% budget spends ×{ratio_at_60:.2} fewer bytes"
    );
    let section = obj(vec![
        ("method", Json::Str(method.to_string())),
        ("elements", Json::Num(n as f64)),
        ("fixed_wire_bytes", Json::Num(fixed_bytes as f64)),
        ("fixed_variance", Json::Num(fixed_var)),
        ("points", Json::Arr(points)),
    ]);
    Ok((section, ratio_at_60))
}

/// Tracing overhead (the PR 9 observability contract): the same
/// pooled-parallel ps round with the recorder disabled (one relaxed
/// atomic load per call site — the shipping default) vs recording at
/// `fine` level (worker phase spans, ps gather/uplink interior spans,
/// pool queue-wait counters and task spans). Wire bytes are asserted
/// identical across the two runs outside the timers — tracing must be
/// invisible in the results, not just cheap. The traced recorder is
/// drained after the measurement so the figure includes buffering but
/// not export.
///
/// Returns the `obs` JSON section and the untraced/traced round-time
/// ratio (`speedup.obs_overhead`, CI floor 0.95: a fully traced round
/// may cost at most ~5%).
fn bench_obs_overhead(
    bench: &Bench,
    workers: usize,
    threads: usize,
    bucket: usize,
    method: &str,
    grads: &[Vec<f32>],
) -> Result<(Json, f64)> {
    use orq::obs::{TraceLevel, TraceRecorder};

    let cfg = ExchangeConfig::flat(Topology::Ps, Link::ten_gbps());
    let mut rows = Vec::new();
    let mut mean_s = [0.0f64; 2];
    let mut wire = [0u64; 2];
    let mut events_per_round = 0.0f64;
    for (i, traced) in [false, true].into_iter().enumerate() {
        let recorder = if traced {
            TraceRecorder::new(TraceLevel::Fine)
        } else {
            TraceRecorder::off()
        };
        let spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
            .with_threads(threads)
            .with_pool_mode(PoolMode::Shared(PoolHandle::with_recorder(
                threads,
                recorder.clone(),
            )))
            .with_recorder(recorder.clone());
        // one validated round outside the timer: stats for the
        // bit-identity observable, and an exact per-round event count
        let (_, stats) = run_rounds(&cfg, &spec, grads, 1)?;
        wire[i] = stats.wire_bytes;
        if traced {
            events_per_round = recorder.drain().len() as f64;
        }
        let label = if traced { "ps round pooled traced-fine" } else { "ps round pooled untraced" };
        let m = bench.measure(label, None, || {
            let out = run_rounds(&cfg, &spec, grads, 1).expect("validated above");
            std::hint::black_box(out.1.wire_bytes);
        });
        if traced {
            // free the buffered iterations; export cost is not the figure
            drop(recorder.drain());
        }
        mean_s[i] = m.mean_s;
        rows.push(m);
    }
    assert_eq!(
        wire[0], wire[1],
        "tracing changed the wire bytes — the recorder must be invisible in results"
    );
    print_table(
        &format!("Tracing overhead — ps, {workers} workers, {method}, d={bucket}, t={threads}"),
        &rows,
    );
    let ratio = mean_s[0] / mean_s[1].max(1e-12);
    println!(
        "obs overhead: untraced {:.3e}s vs traced {:.3e}s per round \
         (×{ratio:.3}, {:.0} events/round)",
        mean_s[0], mean_s[1], events_per_round
    );
    let section = obj(vec![
        ("topology", Json::Str("ps".into())),
        ("path", Json::Str("parallel".into())),
        ("untraced_s", Json::Num(mean_s[0])),
        ("traced_s", Json::Num(mean_s[1])),
        ("events_per_round", Json::Num(events_per_round)),
        ("wire_bytes", Json::Num(wire[0] as f64)),
    ]);
    Ok((section, ratio))
}

/// Section-framed streaming (the PR 8 tentpole figure): the same ps
/// round with the flat exchange (the uplink can only start once
/// backward ends) vs the streamed one (`run_rounds_streamed` — each
/// section frame rides the link the moment its encode completes, while
/// the backward tail still computes). Both figures are simulated-clock
/// accounting on the same 10 Gbps link, so the reported speedup is
/// deterministic: the streamed round is measured from backward start
/// and includes every readiness wait, making the fair flat baseline
/// `ready_last + flat round`. The per-section timeline rows replay the
/// closed-form `ps_streamed_time` recurrence (`link_start_t = max(prev
/// done_t, ready_t)`) on the real frame byte sizes and the model is
/// checked against the measured simulated round to < 1% — the same
/// contract the collective tests enforce. The streamed mean is asserted
/// bit-identical to the flat round's outside the timers.
///
/// Returns the `streaming` JSON section and the
/// `(ready_last + flat) / streamed` simulated speedup.
fn bench_streaming(
    bench: &Bench,
    workers: usize,
    bucket: usize,
    method: &str,
    grads: &[Vec<f32>],
) -> Result<(Json, f64)> {
    use orq::comm::shard::{FRAME_HEADER_BYTES, SECTION_STAMP_BYTES};
    use orq::comm::{ps_streamed_time, OverlapEncoder, SectionMap, SIM_BACKWARD_RATE};

    let link = Link::ten_gbps();
    let sections = 4usize;
    let n = grads.first().map_or(0, |g| g.len());
    // The streamed run drives the serial (threads = 1) start-anywhere
    // overlap encoder end to end; its bytes match the flat *parallel*
    // encode by contract (the legacy serial flat encoder's single RNG
    // stream cannot start mid-gradient), so the flat baseline runs the
    // 2-thread codec. Scoped drivers isolate the streaming schedule
    // from pool effects measured elsewhere.
    let flat_spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
        .with_threads(2)
        .with_pool_mode(PoolMode::Scoped);
    let stream_spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
        .with_pool_mode(PoolMode::Scoped);
    let flat_cfg = ExchangeConfig::flat(Topology::Ps, link);
    let stream_cfg = ExchangeConfig::flat(Topology::Ps, link).with_streaming(sections);

    // one validated round per path outside the timers, for stats,
    // fail-fast and the bit-identity assertion
    let (fmean, fstats) = run_rounds(&flat_cfg, &flat_spec, grads, 1)?;
    let (smean, sstats) = run_rounds_streamed(&stream_cfg, &stream_spec, grads, 1)?;
    assert_eq!(smean, fmean, "streamed ps mean must be bit-identical to the flat round");

    let mut rows = Vec::new();
    let flat_m = bench.measure("ps round flat (post-backward)", None, || {
        let out = run_rounds(&flat_cfg, &flat_spec, grads, 1).expect("validated above");
        std::hint::black_box(out.1.wire_bytes);
    });
    rows.push(flat_m.clone());
    let stream_m = bench.measure("ps round streamed", None, || {
        let out =
            run_rounds_streamed(&stream_cfg, &stream_spec, grads, 1).expect("validated above");
        std::hint::black_box(out.1.wire_bytes);
    });
    rows.push(stream_m.clone());
    print_table(
        &format!(
            "Section streaming — ps, {workers} workers, {sections} sections, \
             {method}, d={bucket}"
        ),
        &rows,
    );

    // Worker 0's section frames, replayed exactly as the streamed driver
    // stages them (encoded sizes are a pure function of element count,
    // so every worker's frames match byte-for-byte in size).
    let spans: Vec<std::ops::Range<usize>> =
        (0..sections).map(|i| n * i / sections..n * (i + 1) / sections).collect();
    let map = SectionMap::new(&spans, sections, bucket)?;
    let ready = map.ready_schedule(SIM_BACKWARD_RATE);
    let mut ov = OverlapEncoder::new(&stream_spec, map)?;
    let mut rng = Rng::stream(stream_spec.seed, 2_000);
    let mut out = Vec::new();
    let mut frames = vec![0usize; sections];
    ov.encode_streamed(
        None,
        &mut rng,
        &mut out,
        &ready,
        &mut |s, m, _| {
            frames[s] = FRAME_HEADER_BYTES + SECTION_STAMP_BYTES + m.len();
            Ok(())
        },
        |cb| {
            for s in spans.iter().rev() {
                cb(s.start, &grads[0]);
            }
            0.0
        },
    )?;

    // The per-section timeline is the ps_streamed_time recurrence in
    // send (descending-section) order: a section's transfer starts when
    // both the link is free and its encode is done.
    let ready_send: Vec<f64> = ready.iter().rev().copied().collect();
    let frames_send: Vec<usize> = frames.iter().rev().copied().collect();
    let mut timeline = Vec::new();
    let mut end = 0.0f64;
    for (i, (&r, &fb)) in ready_send.iter().zip(&frames_send).enumerate() {
        let start = end.max(r);
        end = start + link.transfer_time(fb);
        timeline.push(obj(vec![
            ("section", Json::Num((sections - 1 - i) as f64)),
            ("ready_t", Json::Num(r)),
            ("link_start_t", Json::Num(start)),
            ("done_t", Json::Num(end)),
        ]));
    }
    let mut down = Vec::new();
    orq::codec::encode_fp_into(&smean, &mut down);
    let model = ps_streamed_time(&link, &ready_send, &frames_send, down.len());
    let err_pct = (model - sstats.sim_time_s).abs() / sstats.sim_time_s.max(1e-12) * 100.0;
    let ready_last = ready.iter().copied().fold(0.0, f64::max);
    let speedup = (ready_last + fstats.sim_time_s) / sstats.sim_time_s.max(1e-12);
    println!(
        "streaming: backward-end+flat {:.3e}s vs streamed {:.3e}s (×{speedup:.2}); \
         ps_streamed_time model {model:.3e}s ({err_pct:.3}% error)",
        ready_last + fstats.sim_time_s,
        sstats.sim_time_s
    );

    let section = obj(vec![
        ("topology", Json::Str("ps".into())),
        ("sections", Json::Num(sections as f64)),
        ("ready_last_s", Json::Num(ready_last)),
        ("flat_round_sim", Json::Num(fstats.sim_time_s)),
        ("streamed_round_sim", Json::Num(sstats.sim_time_s)),
        ("flat_s", Json::Num(flat_m.mean_s)),
        ("streamed_s", Json::Num(stream_m.mean_s)),
        ("ps_model_err_pct", Json::Num(err_pct)),
        ("timeline", Json::Arr(timeline)),
    ]);
    Ok((section, speedup))
}

/// Quantized mean downlinks (the PR 7 tentpole figure): the same ps
/// round three ways — mean broadcast FP (baseline), requantized once at
/// the server, and requantized with the server-side downlink residual
/// armed (TernGrad-style bidirectional compression, `--error-feedback`
/// + `--quantize-downlink`). Byte figures are exact per-edge-class
/// codec accounting (`CommStats::wire_bytes_up` / `wire_bytes_down`),
/// so the reported compression ratio is deterministic; the wall-time
/// figures show what the extra server-side requantize and the residual
/// upkeep cost per round. Two rounds per window so the EF entry
/// exercises residual reuse, all figures per-round averages.
///
/// Returns the `downlink` JSON section and the fp/quantized broadcast
/// byte ratio.
fn bench_downlink(
    bench: &Bench,
    workers: usize,
    bucket: usize,
    method: &str,
    grads: &[Vec<f32>],
) -> Result<(Json, f64)> {
    let link = Link::ten_gbps();
    let rounds = 2usize;
    let inv = 1.0 / rounds as f64;
    let variants: [(&str, bool, bool); 3] = [
        ("fp", false, false),
        ("quantized", true, false),
        ("quantized_ef", true, true),
    ];
    let mut rows = Vec::new();
    let mut sections: Vec<(&str, Json)> =
        vec![("topology", Json::Str("ps".into())), ("rounds", Json::Num(rounds as f64))];
    let mut down_bytes = [0u64; 2]; // [fp, quantized] broadcast totals
    for (i, (name, dl, ef)) in variants.into_iter().enumerate() {
        let cfg = ExchangeConfig::flat(Topology::Ps, link)
            .with_downlink(dl)
            .with_error_feedback(ef);
        // serial codec, scoped driver: the figure isolates the downlink
        // codec work from pool effects measured elsewhere
        let spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
            .with_pool_mode(PoolMode::Scoped);
        // one validated window outside the timer, for stats + fail-fast
        let (_, stats) = run_rounds(&cfg, &spec, grads, rounds)?;
        let meas = bench.measure(&format!("ps round downlink={name}"), None, || {
            let out = run_rounds(&cfg, &spec, grads, rounds).expect("validated above");
            std::hint::black_box(out.1.wire_bytes);
        });
        if i < 2 {
            down_bytes[i] = stats.wire_bytes_down;
        }
        sections.push((
            name,
            obj(vec![
                ("wire_bytes_up", Json::Num(stats.wire_bytes_up as f64 * inv)),
                ("wire_bytes_down", Json::Num(stats.wire_bytes_down as f64 * inv)),
                ("mean_s", Json::Num(meas.mean_s * inv)),
                ("sim_time_s", Json::Num(stats.sim_time_s * inv)),
            ]),
        ));
        rows.push(meas);
    }
    print_table(
        &format!("Quantized downlink — ps, {workers} workers, {method}, d={bucket}"),
        &rows,
    );
    let compression = down_bytes[0] as f64 / (down_bytes[1] as f64).max(1e-12);
    println!(
        "downlink broadcast: fp {} B/round vs quantized {} B/round (×{compression:.2})",
        down_bytes[0] / rounds as u64,
        down_bytes[1] / rounds as u64
    );
    Ok((obj(sections), compression))
}

/// Backward/encode overlap on a real native MLP: flat (sequential
/// backward, then `GradCodec::encode_into`) vs the overlap driver
/// (`comm::overlap::OverlapEncoder`, sections quantize+encode on the
/// pool while the backward tail runs). The assembled messages are
/// asserted byte-identical outside the timers, and the overlapped
/// closed-form PS model is checked against the simulator's measured
/// round time in its degenerate case (every section ready at t = 0 on
/// the zero-latency link sums to the flat `ps_time` model).
///
/// Returns the `overlap` JSON section and the flat/overlapped speedup.
fn bench_overlap(
    bench: &Bench,
    threads: usize,
    workers: usize,
    bucket: usize,
    method: &str,
    shared: &PoolMode,
    smoke: bool,
) -> Result<(Json, f64)> {
    use orq::comm::{ps_overlap_time, OverlapEncoder, SectionMap};
    use orq::data::synth::{ClassDataset, DatasetSpec};
    use orq::model::native::NativeMlp;
    use orq::model::Backend;

    // overlap needs the parallel codec; a 1-thread run still measures a
    // real (2-thread) overlapped path rather than skipping the figure
    let t = threads.max(2);
    let dims: Vec<usize> =
        if smoke { vec![64, 128, 128, 32] } else { vec![512, 1024, 1024, 256] };
    let sections = 3usize;
    let batch_n = if smoke { 16 } else { 64 };
    let mut backend = NativeMlp::new(dims.clone());
    let mut backend2 = NativeMlp::new(dims.clone());
    let param_count = backend.param_count();
    let ds = ClassDataset::generate(DatasetSpec {
        in_dim: dims[0],
        classes: *dims.last().unwrap(),
        train_n: 256,
        test_n: 1,
        margin: 3.0,
        noise: 0.6,
        label_noise: 0.0,
        seed: 11,
    });
    let batch = ds.worker_batch(0, 1, batch_n, &mut Rng::seed_from(2));
    let params = backend.init_params(&mut Rng::seed_from(1));

    let spec = WireSpec::new(method, bucket).with_threads(t).with_pool_mode(shared.clone());
    let mut gc = GradCodec::new(&spec)?;
    let map = SectionMap::new(&backend.layer_spans(), sections, bucket)?;
    let mut ov = OverlapEncoder::new(&spec, map)?;
    let mut grad = vec![0.0f32; param_count];
    let mut grad2 = vec![0.0f32; param_count];
    let mut qg = QuantizedGrad::default();
    let mut msg = Vec::new();
    let mut msg2 = Vec::new();

    // correctness outside the timers: one overlapped round is
    // byte-identical to the flat backward→encode under the same draw
    {
        let mut ra = Rng::seed_from(7);
        let mut rb = Rng::seed_from(7);
        backend.loss_grad(&params, &batch, &mut grad);
        gc.encode_into(&grad, &mut ra, &mut qg, &mut msg);
        ov.encode_overlapped(None, &mut rb, &mut msg2, |cb| {
            backend2.loss_grad_sections(&params, &batch, &mut grad2, cb)
        });
        assert_eq!(msg, msg2, "overlapped wire bytes diverge from the flat encode");
        assert_eq!(ra.next_u64(), rb.next_u64(), "overlap must consume one round key");
    }

    let mut rows = Vec::new();
    let mut rng_f = Rng::seed_from(21);
    let flat = bench.measure("backward+encode flat", Some(param_count as u64), || {
        backend.loss_grad(&params, &batch, &mut grad);
        gc.encode_into(&grad, &mut rng_f, &mut qg, &mut msg);
        std::hint::black_box(msg.len());
    });
    rows.push(flat.clone());
    let mut rng_o = Rng::seed_from(21);
    let over = bench.measure("backward+encode overlap", Some(param_count as u64), || {
        ov.encode_overlapped(None, &mut rng_o, &mut msg2, |cb| {
            backend2.loss_grad_sections(&params, &batch, &mut grad2, cb)
        });
        std::hint::black_box(msg2.len());
    });
    rows.push(over.clone());
    print_table(
        &format!(
            "Backward/encode overlap — {} params, {sections} sections, {method}, t={t}",
            param_count
        ),
        &rows,
    );

    // Degenerate-model check vs the measured simulated ps round: on the
    // zero-latency link with every section ready at t = 0, the
    // overlapped model's serialized uplink sums to the flat ps model,
    // which must agree with the simulator's accounting to < 1%.
    let link = Link::ten_gbps();
    let sim_grads: Vec<Vec<f32>> =
        (0..workers.max(1)).map(|w| gaussian(param_count, 90 + w as u64)).collect();
    let cfg = ExchangeConfig::flat(Topology::Ps, link);
    let pspec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
        .with_threads(t)
        .with_pool_mode(shared.clone());
    let (mean, stats) = run_rounds(&cfg, &pspec, &sim_grads, 1)?;
    let mut down = Vec::new();
    orq::codec::encode_fp_into(&mean, &mut down);
    // per-section uplink shares from the driver's last round; the
    // common header rides the first section
    let mut up: Vec<usize> = ov.section_bytes().to_vec();
    up[0] += msg2.len() - up.iter().sum::<usize>();
    let ready = vec![0.0f64; up.len()];
    let model = ps_overlap_time(&link, &ready, &up, down.len());
    let err_pct = (model - stats.sim_time_s).abs() / stats.sim_time_s.max(1e-12) * 100.0;
    println!(
        "overlap model check: ps_overlap_time {model:.3e}s vs simulated {:.3e}s \
         ({err_pct:.3}% error)",
        stats.sim_time_s
    );

    let section = obj(vec![
        ("model_params", Json::Num(param_count as f64)),
        ("sections", Json::Num(up.len() as f64)),
        ("batch", Json::Num(batch_n as f64)),
        ("flat_s", Json::Num(flat.mean_s)),
        ("overlap_s", Json::Num(over.mean_s)),
        ("section_bytes", Json::Arr(up.iter().map(|&b| Json::Num(b as f64)).collect())),
        ("ps_model_err_pct", Json::Num(err_pct)),
    ]);
    Ok((section, flat.mean_s / over.mean_s.max(1e-12)))
}

/// Round-1 vs steady-state cost of the pooled paths: a fresh pool's
/// first call pays the thread spawns and the level-solver arena growth;
/// subsequent rounds reuse both. Reported raw (no thresholds — the
/// ratio is machine-dependent), one fresh pool per figure.
fn bench_amortization(
    n: usize,
    threads: usize,
    workers: usize,
    bucket: usize,
    method: &str,
    grads: &[Vec<f32>],
    smoke: bool,
) -> Result<Json> {
    use std::time::Instant;
    let steady_rounds = if smoke { 3usize } else { 10 };
    let g = gaussian(n, 1);

    // quantize+encode through a fresh pooled codec (own pool)
    let spec = WireSpec::new(method, bucket).with_threads(threads);
    let mut gc = GradCodec::new(&spec)?;
    let mut rng = Rng::seed_from(3);
    let mut qg = QuantizedGrad::default();
    let mut msg = Vec::new();
    let t0 = Instant::now();
    gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
    let qe_round1 = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..steady_rounds {
        gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
        std::hint::black_box(msg.len());
    }
    let qe_steady = t0.elapsed().as_secs_f64() / steady_rounds as f64;

    // one ps exchange round on a fresh shared pool
    let cfg = ExchangeConfig::flat(Topology::Ps, Link::ten_gbps());
    let spec = WireSpec { seed: 7, ..WireSpec::new(method, bucket) }
        .with_threads(threads)
        .with_pool_mode(PoolMode::Shared(PoolHandle::new(threads)));
    let t0 = Instant::now();
    run_rounds(&cfg, &spec, grads, 1)?;
    let ps_round1 = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..steady_rounds {
        let out = run_rounds(&cfg, &spec, grads, 1)?;
        std::hint::black_box(out.1.wire_bytes);
    }
    let ps_steady = t0.elapsed().as_secs_f64() / steady_rounds as f64;

    println!(
        "amortization ({workers} workers): quantize+encode round 1 {:.2e}s vs steady {:.2e}s, \
         ps round 1 {:.2e}s vs steady {:.2e}s",
        qe_round1, qe_steady, ps_round1, ps_steady
    );
    let entry = |round1: f64, steady: f64| {
        obj(vec![
            ("round1_s", Json::Num(round1)),
            ("steady_s", Json::Num(steady)),
            ("rounds", Json::Num(steady_rounds as f64)),
        ])
    };
    Ok(obj(vec![
        ("quantize_encode", entry(qe_round1, qe_steady)),
        ("ps_round", entry(ps_round1, ps_steady)),
    ]))
}

// ---------------------------------------------------------------------
// --smoke artifact validation: schema + monotone sanity, no timing
// thresholds.
// ---------------------------------------------------------------------

fn fail(msg: String) -> Error {
    Error::InvalidArg(format!("smoke validation failed: {msg}"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .ok_or_else(|| fail(format!("{key} is not a number")))
}

fn validate_codec(j: &Json) -> Result<()> {
    // the artifact on disk must round-trip through the parser
    let j = &Json::parse(&j.dump())?;
    if j.req("schema")?.as_str() != Some("orq.perfbench.codec/v2") {
        return Err(fail("bad codec schema tag".into()));
    }
    j.req("mode")?;
    let elements = req_f64(j, "elements")?;
    let kernels = j
        .req("kernels")?
        .as_arr()
        .ok_or_else(|| fail("kernels is not an array".into()))?;
    if kernels.is_empty() {
        return Err(fail("kernels is empty".into()));
    }
    let mut fixed_pack_word: Vec<(f64, f64)> = Vec::new(); // (bits, wire_bytes)
    let mut base3_bytes = None;
    for k in kernels {
        for key in ["kernel", "op", "path"] {
            k.req(key)?;
        }
        if req_f64(k, "mean_s")? <= 0.0 || req_f64(k, "wire_bytes")? <= 0.0 {
            return Err(fail(format!("non-positive timing/size in {}", k.dump())));
        }
        if k.get("kernel").and_then(Json::as_str) == Some("fixed")
            && k.get("op").and_then(Json::as_str) == Some("pack")
            && k.get("path").and_then(Json::as_str) == Some("word")
        {
            fixed_pack_word.push((req_f64(k, "bits")?, req_f64(k, "wire_bytes")?));
        }
        if k.get("kernel").and_then(Json::as_str) == Some("base_s")
            && k.get("s").and_then(Json::as_f64) == Some(3.0)
        {
            base3_bytes = Some(req_f64(k, "wire_bytes")?);
        }
    }
    // monotone: wider fixed widths cost more wire bytes
    fixed_pack_word.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for w in fixed_pack_word.windows(2) {
        if w[1].1 <= w[0].1 {
            return Err(fail(format!(
                "fixed wire bytes not monotone in width: {:?}",
                fixed_pack_word
            )));
        }
    }
    // base-3 (1.6 bits/elt) must beat 2-bit fixed for the same n
    let two_bit = fixed_pack_word
        .iter()
        .find(|(b, _)| *b == 2.0)
        .ok_or_else(|| fail("missing 2-bit fixed entry".into()))?
        .1;
    match base3_bytes {
        Some(b3) if b3 < two_bit => {}
        other => return Err(fail(format!("base-3 ({other:?}) must beat 2-bit ({two_bit})"))),
    }
    if two_bit > elements {
        return Err(fail("2-bit packing cannot exceed 1 byte/elt".into()));
    }
    let sp = j.req("speedup")?;
    for key in ["fixed_pack_unpack", "base_s_unpack", "round_twopass"] {
        let v = req_f64(sp, key)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(fail(format!("speedup {key} = {v}")));
        }
    }
    Ok(())
}

fn validate_exchange(j: &Json) -> Result<()> {
    let j = &Json::parse(&j.dump())?;
    if j.req("schema")?.as_str() != Some("orq.perfbench.exchange/v8") {
        return Err(fail("bad exchange schema tag".into()));
    }
    for key in ["mode", "elements", "workers", "threads", "bucket_size"] {
        j.req(key)?;
    }
    let quantize = j
        .req("quantize")?
        .as_arr()
        .ok_or_else(|| fail("quantize is not an array".into()))?;
    if quantize.is_empty() {
        return Err(fail("quantize is empty".into()));
    }
    for q in quantize {
        q.req("method")?;
        q.req("path")?;
        if req_f64(q, "melem_s")? <= 0.0 {
            return Err(fail(format!("non-positive throughput in {}", q.dump())));
        }
    }
    let rounds = j
        .req("rounds")?
        .as_arr()
        .ok_or_else(|| fail("rounds is not an array".into()))?;
    let mut seen_ps = (false, false, false);
    let mut seen_sharded = (false, false);
    for r in rounds {
        let topo = r.req("topology")?.as_str().unwrap_or_default().to_string();
        let path = r.req("path")?.as_str().unwrap_or_default().to_string();
        if req_f64(r, "mean_s")? <= 0.0
            || req_f64(r, "wire_bytes")? <= 0.0
            || req_f64(r, "sim_time_s")? <= 0.0
        {
            return Err(fail(format!("non-positive figures in {}", r.dump())));
        }
        // v2 columns: every round entry declares its shard count and
        // staleness window (1 / 0 on the unsharded topologies).
        let shards = req_f64(r, "shards")?;
        let staleness = req_f64(r, "staleness")?;
        if shards < 1.0 || staleness < 0.0 {
            return Err(fail(format!("bad shards/staleness in {}", r.dump())));
        }
        match (topo.as_str(), path.as_str()) {
            ("ps", "serial") => seen_ps.0 = true,
            ("ps", "parallel") => seen_ps.1 = true,
            ("ps", "parallel-scoped") => seen_ps.2 = true,
            ("sharded-ps", "serial") => {
                if shards < 2.0 || staleness != 0.0 {
                    return Err(fail("sharded-ps serial must run S ≥ 2, K = 0".into()));
                }
                seen_sharded.0 = true;
            }
            ("sharded-ps", "async") => {
                if shards < 2.0 || staleness < 1.0 {
                    return Err(fail("sharded-ps async must run S ≥ 2, K ≥ 1".into()));
                }
                seen_sharded.1 = true;
            }
            _ => {}
        }
    }
    if seen_ps != (true, true, true) {
        return Err(fail(
            "ps serial, ps parallel (pooled) and ps parallel-scoped rounds are all required"
                .into(),
        ));
    }
    if seen_sharded != (true, true) {
        return Err(fail(
            "both sharded-ps serial and sharded-ps async rounds are required".into(),
        ));
    }
    // v3: the amortization section quantifies round-1 (spawns + arena
    // growth) vs steady state for both pooled figures.
    let am = j.req("amortization")?;
    for section in ["quantize_encode", "ps_round"] {
        let s = am.req(section)?;
        for key in ["round1_s", "steady_s", "rounds"] {
            let v = req_f64(s, key)?;
            if !v.is_finite() || v <= 0.0 {
                return Err(fail(format!("amortization {section}.{key} = {v}")));
            }
        }
    }
    // v4: the overlap section measures flat vs section-overlapped
    // backward+encode and verifies the overlapped closed-form ps model
    // against the simulator in its degenerate (all-ready-at-0) case.
    let ov = j.req("overlap")?;
    for key in ["model_params", "sections", "batch", "flat_s", "overlap_s"] {
        let v = req_f64(ov, key)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(fail(format!("overlap {key} = {v}")));
        }
    }
    let sections = ov
        .req("section_bytes")?
        .as_arr()
        .ok_or_else(|| fail("overlap section_bytes is not an array".into()))?;
    if sections.is_empty() || sections.len() != req_f64(ov, "sections")? as usize {
        return Err(fail("overlap section_bytes/sections mismatch".into()));
    }
    let err_pct = req_f64(ov, "ps_model_err_pct")?;
    if !err_pct.is_finite() || err_pct >= 1.0 {
        return Err(fail(format!(
            "overlapped ps model disagrees with the simulator: {err_pct}% (must be < 1%)"
        )));
    }
    // v5: the downlink section compares the fp broadcast against the
    // server-requantized one (plain and with the downlink residual
    // armed) — same uplink bytes, strictly smaller downlink bytes.
    let dl = j.req("downlink")?;
    dl.req("topology")?;
    for name in ["fp", "quantized", "quantized_ef"] {
        let s = dl.req(name)?;
        for key in ["wire_bytes_up", "wire_bytes_down", "mean_s", "sim_time_s"] {
            let v = req_f64(s, key)?;
            if !v.is_finite() || v <= 0.0 {
                return Err(fail(format!("downlink {name}.{key} = {v}")));
            }
        }
    }
    let (fp, q) = (dl.req("fp")?, dl.req("quantized")?);
    if req_f64(q, "wire_bytes_down")? >= req_f64(fp, "wire_bytes_down")? {
        return Err(fail("quantized downlink must shrink the broadcast".into()));
    }
    if req_f64(q, "wire_bytes_up")? != req_f64(fp, "wire_bytes_up")? {
        return Err(fail("quantized downlink must leave the uplink untouched".into()));
    }
    // v6: the streaming section compares the same ps round flat vs
    // section-streamed on the simulated clock; the per-section timeline
    // must replay the ps_streamed_time recurrence (transfers gate on
    // readiness and link-free, done times strictly increase) and the
    // closed-form model must agree with the simulator to < 1%.
    let st = j.req("streaming")?;
    st.req("topology")?;
    let nsec = req_f64(st, "sections")?;
    if nsec < 2.0 {
        return Err(fail("streaming needs at least 2 sections to overlap anything".into()));
    }
    for key in ["ready_last_s", "flat_round_sim", "streamed_round_sim", "flat_s", "streamed_s"] {
        let v = req_f64(st, key)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(fail(format!("streaming {key} = {v}")));
        }
    }
    let st_err = req_f64(st, "ps_model_err_pct")?;
    if !st_err.is_finite() || st_err >= 1.0 {
        return Err(fail(format!(
            "streamed ps model disagrees with the simulator: {st_err}% (must be < 1%)"
        )));
    }
    if req_f64(st, "streamed_round_sim")?
        >= req_f64(st, "ready_last_s")? + req_f64(st, "flat_round_sim")?
    {
        return Err(fail(
            "streamed round must strictly beat backward-end + flat round".into(),
        ));
    }
    let timeline = st
        .req("timeline")?
        .as_arr()
        .ok_or_else(|| fail("streaming timeline is not an array".into()))?;
    if timeline.len() != nsec as usize {
        return Err(fail("streaming timeline/sections mismatch".into()));
    }
    let mut prev_done = 0.0f64;
    for row in timeline {
        let (ready, start, done) =
            (req_f64(row, "ready_t")?, req_f64(row, "link_start_t")?, req_f64(row, "done_t")?);
        if req_f64(row, "section")? < 0.0 {
            return Err(fail("negative section index in timeline".into()));
        }
        if start < ready || start < prev_done || done <= start {
            return Err(fail(format!(
                "timeline row breaks the streaming recurrence: {}",
                row.dump()
            )));
        }
        prev_done = done;
    }
    // v7: the obs section measures the same pooled ps round untraced vs
    // fine-traced; a traced round must actually record something, and
    // both figures must be real timings.
    let ob = j.req("obs")?;
    ob.req("topology")?;
    ob.req("path")?;
    for key in ["untraced_s", "traced_s", "wire_bytes"] {
        let v = req_f64(ob, key)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(fail(format!("obs {key} = {v}")));
        }
    }
    if req_f64(ob, "events_per_round")? < 1.0 {
        return Err(fail("obs events_per_round < 1 — the traced round recorded nothing".into()));
    }
    // v8: the budget section's accuracy-vs-bytes points must be a real
    // Pareto front — spend never above its budget and monotone
    // non-decreasing in the budget, variance monotone non-increasing.
    let bg = j.req("budget")?;
    bg.req("method")?;
    let fixed_bytes = req_f64(bg, "fixed_wire_bytes")?;
    let fixed_var = req_f64(bg, "fixed_variance")?;
    if fixed_bytes <= 0.0 || !fixed_var.is_finite() || fixed_var < 0.0 {
        return Err(fail("bad budget fixed-width baseline figures".into()));
    }
    let points = bg
        .req("points")?
        .as_arr()
        .ok_or_else(|| fail("budget points is not an array".into()))?;
    if points.len() < 3 {
        return Err(fail("budget pareto needs at least 3 points".into()));
    }
    let mut prev_budget = 0.0f64;
    let mut prev_bytes = 0.0f64;
    let mut prev_var = f64::INFINITY;
    for p in points {
        let (b, bytes, var) = (
            req_f64(p, "budget_bytes")?,
            req_f64(p, "wire_bytes")?,
            req_f64(p, "variance")?,
        );
        if bytes <= 0.0 || !var.is_finite() || var < 0.0 {
            return Err(fail(format!("bad budget point {}", p.dump())));
        }
        if bytes > b {
            return Err(fail(format!(
                "budget point overspent: {bytes} wire bytes over the {b}-byte budget"
            )));
        }
        if b < prev_budget || bytes < prev_bytes || var > prev_var {
            return Err(fail(format!(
                "budget pareto is not monotone at {}",
                p.dump()
            )));
        }
        (prev_budget, prev_bytes, prev_var) = (b, bytes, var);
    }
    let sp = j.req("speedup")?;
    for key in [
        "quantize_encode",
        "ps_round",
        "pooled_round",
        "overlap_round",
        "downlink_compression",
        "streamed_round",
        "obs_overhead",
        "budget_bytes",
    ] {
        let v = req_f64(sp, key)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(fail(format!("speedup {key} = {v}")));
        }
    }
    Ok(())
}
