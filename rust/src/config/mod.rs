//! Experiment configuration: typed configs + a TOML-subset parser
//! (serde is unavailable offline — DESIGN.md §3).
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"x"`), bool, integer, float and flat arrays (`[1, 2, 3]`), `#`
//! comments. Exactly what experiment files need, nothing more.

use std::collections::BTreeMap;

use crate::comm::{Link, LinkMap, Topology};
use crate::error::{Error, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key` -> value map.
pub type ConfigMap = BTreeMap<String, Value>;

/// Largest admissible `lr_decay_steps` entry: any real schedule decays
/// within the run, and the bound rejects `i64 → usize` wrap-arounds from
/// negative config values.
const MAX_LR_DECAY_STEP: i64 = 100_000_000;

/// Parse TOML-subset text into a flat `section.key` map.
pub fn parse(text: &str) -> Result<ConfigMap> {
    let mut out = ConfigMap::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: unterminated section", ln + 1)))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected key = value", ln + 1)))?;
        let full_key = if section.is_empty() {
            key.trim().to_string()
        } else {
            format!("{section}.{}", key.trim())
        };
        out.insert(full_key, parse_value(val.trim(), ln + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ln: usize) -> Result<Value> {
    let err = |m: &str| Error::Config(format!("line {ln}: {m}: {s:?}"));
    if s.is_empty() {
        return Err(err("empty value"));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| err("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| err("unterminated array"))?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, ln)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err("unrecognized value"))
}

/// Per-edge-class link settings: bandwidth in bits/s, one-way latency in
/// seconds, for the fast intra-group and slow inter-group edge classes.
/// Flat topologies (ps/ring) only use the inter values; defaults
/// reproduce the paper's homogeneous 10 Gbps zero-latency testbed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkConfig {
    /// Config key `intra_bandwidth` (bits per second).
    pub intra_bandwidth: f64,
    /// Config key `intra_latency` (seconds, one-way).
    pub intra_latency: f64,
    /// Config key `inter_bandwidth` (bits per second).
    pub inter_bandwidth: f64,
    /// Config key `inter_latency` (seconds, one-way).
    pub inter_latency: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            intra_bandwidth: 10e9,
            intra_latency: 0.0,
            inter_bandwidth: 10e9,
            inter_latency: 0.0,
        }
    }
}

impl LinkConfig {
    pub fn validate(&self) -> Result<()> {
        for (key, bw) in [
            ("intra_bandwidth", self.intra_bandwidth),
            ("inter_bandwidth", self.inter_bandwidth),
        ] {
            if !(bw.is_finite() && bw > 0.0) {
                return Err(Error::Config(format!(
                    "{key} must be a finite positive bit rate, got {bw}"
                )));
            }
        }
        for (key, lat) in [
            ("intra_latency", self.intra_latency),
            ("inter_latency", self.inter_latency),
        ] {
            if !(lat.is_finite() && lat >= 0.0) {
                return Err(Error::Config(format!(
                    "{key} must be a finite non-negative duration in seconds, got {lat}"
                )));
            }
        }
        Ok(())
    }

    /// Instantiate the simulated [`LinkMap`]. Call [`Self::validate`]
    /// first — [`Link::new`] asserts on non-positive bandwidth.
    pub fn link_map(&self) -> LinkMap {
        LinkMap::new(
            Link::new(self.intra_bandwidth, self.intra_latency),
            Link::new(self.inter_bandwidth, self.inter_latency),
        )
    }
}

/// Full training-run configuration (defaults follow the paper's §5 setup,
/// scaled to the synthetic substrate).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Model: `mlp_s`/`mlp_m`/`mlp_l` (native) or a `meta.json` model name
    /// prefixed with `pjrt:` (e.g. `pjrt:mlp_s`).
    pub model: String,
    /// Dataset preset: `cifar10` | `cifar100` | `imagenet`.
    pub dataset: String,
    /// Quantizer name (see `quant::from_name`).
    pub method: String,
    pub workers: usize,
    /// Global batch size, split evenly across workers (paper §5.2).
    pub batch: usize,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Steps at which lr is multiplied by `lr_decay` (paper: epochs 100/150
    /// of 200 → fractions 0.5/0.75 of total steps).
    pub lr_decay_steps: Vec<usize>,
    pub lr_decay: f32,
    /// Linear warmup steps from lr/10 (paper: 5 epochs when clipping).
    pub warmup_steps: usize,
    pub bucket_size: usize,
    pub clip_factor: Option<f32>,
    pub seed: u64,
    pub eval_every: usize,
    /// Quantize the coordinator->worker mean downlink too (paper §4
    /// option (b), TernGrad-style bidirectional compression): the PS
    /// broadcast, the hier root multicast and the sharded-ps per-shard
    /// mean frames. The encoder quantizes the mean once and every node
    /// decodes the same bytes, so replicas stay bit-identical. The ring
    /// has no broadcast downlink and rejects the flag.
    pub quantize_downlink: bool,
    /// Gradient-exchange topology: parameter-server star, decentralized
    /// ring all-reduce, the two-level hierarchy, or the sharded/async
    /// parameter server
    /// (`topology = "ps" | "ring" | "hier" | "sharded-ps"`).
    pub topology: Topology,
    /// Worker groups for the hierarchical topology (`groups = N`; must
    /// divide `workers`). Flat topologies require 1.
    pub groups: usize,
    /// Server shards for the sharded-ps topology (`shards = S`; every
    /// shard must own at least one gradient bucket). Other topologies
    /// require 1.
    pub shards: usize,
    /// Bounded staleness window for the sharded-ps topology
    /// (`staleness = K`): workers run up to K rounds ahead of the
    /// slowest shard and apply the round-`r − K` mean at round `r`.
    /// `0` (required on every synchronous topology) disables the lag.
    pub staleness: usize,
    /// Wrap every quantization site in error feedback
    /// (`error_feedback = true`): quantize `g + m`, keep the residual
    /// `m ← (g + m) − Q(g + m)`. On the PS paths (ps / sharded-ps) the
    /// worker uplink carries the residual; on ring/hier every
    /// decode→reduce→requantize hop keeps its own per-hop residual, so
    /// biased schemes no longer compound bias with hop count. Needs a
    /// quantizing method; works with the serial codec (residual from the
    /// materialized quantized gradient) and the parallel codec
    /// (pipeline-side residual via wire dequantization). Combined with
    /// `quantize_downlink`, the downlink encoder keeps a server-side
    /// residual too (bidirectional EF).
    pub error_feedback: bool,
    /// Codec threads per node (`threads = N`): 1 = serial legacy path,
    /// 0 = auto-detect cores, N ≥ 2 = parallel per-bucket
    /// quantize+encode / decode+reduce pipeline. Wire bytes and training
    /// results are identical for every parallel thread count.
    pub threads: usize,
    /// Run codec shards, sharded-PS reduce loops and exchange drivers on
    /// one persistent worker pool shared across the whole run
    /// (`pool = true`, the default: thread spawns and level-solver
    /// arenas amortize across rounds). `pool = false` keeps the legacy
    /// per-round scoped threads — same results bit for bit, retained as
    /// the perf baseline.
    pub pool: bool,
    /// Overlap backward compute with section quantize+encode
    /// (`overlap = true`, `--overlap`): a model-section bucket map seeded
    /// from the backend's layer structure hands each completed gradient
    /// section to the worker pool while the backward tail still runs
    /// ([`crate::comm::overlap`]). Needs a quantizing method; training is
    /// bit-identical to the flat exchange at every thread count
    /// (`threads = 1` degenerates to the flat path outright).
    pub overlap: bool,
    /// Overlap section count (`sections = N`, `--sections N`): contiguous
    /// layer groups, balanced to within one layer, cut on the codec's
    /// bucket grid. Must not exceed the model's layer count. `None`
    /// means "not set" ([`Self::effective_sections`] supplies the
    /// default); setting it without `overlap` is a config error — the
    /// knob would otherwise be silently ignored.
    pub sections: Option<usize>,
    /// Stream the exchange section by section
    /// (`stream_sections = true`, `--stream-sections`; implies
    /// `overlap`): each staged overlap section is pushed into the
    /// collective as a standalone section frame the moment its encode
    /// completes, so early sections ride the link while the backward
    /// tail still computes. ps/hier/sharded-ps stay bit-identical to
    /// the flat overlap exchange; the ring runs one
    /// reduce-scatter/all-gather per section (deterministic, equivalent
    /// to its serial replay, but not bit-identical to flat). Requires a
    /// synchronous exchange (`staleness = 0`).
    pub stream_sections: bool,
    /// Per-round uplink byte budget (`byte_budget = BYTES`,
    /// `--byte-budget BYTES`): every worker's full-gradient uplink —
    /// all headers and frames included — must fit in this many bytes
    /// per round. The budget allocator
    /// ([`crate::quant::budget::allocate_widths`]) re-spends the
    /// method's bit width per bucket each round, minimizing total
    /// quantization variance; the chosen widths ride in-band in the
    /// wire header so every hop decodes them from the frame. Needs a
    /// parameterizable method (`orq-S` / `qsgd-S` / `linear-S`).
    /// `None` = fixed-width (bit-identical to the pre-budget encoder).
    pub byte_budget: Option<u64>,
    /// Budget ramp schedule (`budget_schedule = "coarse-to-fine"`,
    /// `--budget-schedule coarse-to-fine`): spend half the budget in
    /// round 0 and ramp linearly to the full budget by round
    /// [`crate::quant::budget::COARSE_TO_FINE_RAMP`]. Requires
    /// `byte_budget`; the per-round spend never exceeds the configured
    /// budget.
    pub budget_schedule: Option<String>,
    /// Run-wide tracing level (`trace_level = "off" | "round" | "fine"`,
    /// `--trace-level`): `off` (default) records nothing and leaves the
    /// hot path at one relaxed atomic load per site; `round` records the
    /// coordinator/worker phase spans per training round; `fine` adds
    /// collective-interior spans, pool queue-wait counters and streamed
    /// section instants. Wire bytes and trained parameters are
    /// bit-identical at every level.
    pub trace_level: crate::obs::TraceLevel,
    /// Per-edge-class simulated link model (`intra_bandwidth`,
    /// `intra_latency`, `inter_bandwidth`, `inter_latency`).
    pub links: LinkConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "mlp_s".into(),
            dataset: "cifar100".into(),
            method: "fp".into(),
            workers: 1,
            batch: 128,
            steps: 600,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 5e-4,
            lr_decay_steps: vec![300, 450],
            lr_decay: 0.1,
            warmup_steps: 0,
            bucket_size: 2048,
            clip_factor: None,
            seed: 42,
            eval_every: 100,
            quantize_downlink: false,
            topology: Topology::Ps,
            groups: 1,
            shards: 1,
            staleness: 0,
            error_feedback: false,
            threads: 1,
            pool: true,
            overlap: false,
            sections: None,
            stream_sections: false,
            byte_budget: None,
            budget_schedule: None,
            trace_level: crate::obs::TraceLevel::Off,
            links: LinkConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Read overrides from a parsed `[train]` section.
    pub fn from_map(map: &ConfigMap) -> Result<Self> {
        let mut c = TrainConfig::default();
        let get = |k: &str| map.get(&format!("train.{k}")).or_else(|| map.get(k));
        macro_rules! set {
            ($field:ident, $conv:ident, $name:expr) => {
                if let Some(v) = get($name) {
                    c.$field = v.$conv().ok_or_else(|| {
                        Error::Config(format!("bad type for {}", $name))
                    })? as _;
                }
            };
        }
        if let Some(v) = get("model") {
            c.model = v.as_str().ok_or_else(|| Error::Config("model".into()))?.to_string();
        }
        if let Some(v) = get("dataset") {
            c.dataset = v.as_str().ok_or_else(|| Error::Config("dataset".into()))?.to_string();
        }
        if let Some(v) = get("method") {
            c.method = v.as_str().ok_or_else(|| Error::Config("method".into()))?.to_string();
        }
        set!(workers, as_i64, "workers");
        set!(batch, as_i64, "batch");
        set!(steps, as_i64, "steps");
        set!(lr, as_f64, "lr");
        set!(momentum, as_f64, "momentum");
        set!(weight_decay, as_f64, "weight_decay");
        set!(lr_decay, as_f64, "lr_decay");
        set!(warmup_steps, as_i64, "warmup_steps");
        set!(bucket_size, as_i64, "bucket_size");
        set!(seed, as_i64, "seed");
        set!(eval_every, as_i64, "eval_every");
        set!(groups, as_i64, "groups");
        set!(shards, as_i64, "shards");
        set!(staleness, as_i64, "staleness");
        set!(threads, as_i64, "threads");
        if let Some(v) = get("sections") {
            let s = v
                .as_i64()
                .ok_or_else(|| Error::Config("bad type for sections".into()))?;
            c.sections = Some(s as usize);
        }
        if let Some(v) = get("byte_budget") {
            let b = v
                .as_i64()
                .ok_or_else(|| Error::Config("bad type for byte_budget".into()))?;
            // Bounds-check before the u64 cast: a negative budget would
            // wrap to an absurd byte count and silently disable the cap.
            if b <= 0 {
                return Err(Error::Config(format!("byte_budget ({b}) must be >= 1")));
            }
            c.byte_budget = Some(b as u64);
        }
        if let Some(v) = get("budget_schedule") {
            c.budget_schedule = Some(
                v.as_str()
                    .ok_or_else(|| Error::Config("budget_schedule must be a string".into()))?
                    .to_string(),
            );
        }
        macro_rules! set_link {
            ($field:ident, $name:expr) => {
                if let Some(v) = get($name) {
                    c.links.$field = v.as_f64().ok_or_else(|| {
                        Error::Config(format!("bad type for {} (expected a number)", $name))
                    })?;
                }
            };
        }
        set_link!(intra_bandwidth, "intra_bandwidth");
        set_link!(intra_latency, "intra_latency");
        set_link!(inter_bandwidth, "inter_bandwidth");
        set_link!(inter_latency, "inter_latency");
        if let Some(v) = get("quantize_downlink") {
            c.quantize_downlink =
                v.as_bool().ok_or_else(|| Error::Config("quantize_downlink".into()))?;
        }
        if let Some(v) = get("error_feedback") {
            c.error_feedback =
                v.as_bool().ok_or_else(|| Error::Config("error_feedback".into()))?;
        }
        if let Some(v) = get("pool") {
            c.pool = v
                .as_bool()
                .ok_or_else(|| Error::Config("pool must be a bool (true = pooled)".into()))?;
        }
        if let Some(v) = get("overlap") {
            c.overlap = v
                .as_bool()
                .ok_or_else(|| Error::Config("overlap must be a bool".into()))?;
        }
        if let Some(v) = get("stream_sections") {
            c.stream_sections = v
                .as_bool()
                .ok_or_else(|| Error::Config("stream_sections must be a bool".into()))?;
            // Streaming is an overlap mode: the flag implies overlap so
            // users don't have to pass both.
            if c.stream_sections {
                c.overlap = true;
            }
        }
        if let Some(v) = get("trace_level") {
            c.trace_level = v
                .as_str()
                .ok_or_else(|| Error::Config("trace_level must be a string".into()))?
                .parse()
                .map_err(|e: crate::error::Error| Error::Config(e.to_string()))?;
        }
        if let Some(v) = get("topology") {
            c.topology = Topology::parse(
                v.as_str().ok_or_else(|| Error::Config("topology must be a string".into()))?,
            )
            .map_err(|e| Error::Config(e.to_string()))?;
        }
        if let Some(v) = get("clip_factor") {
            c.clip_factor = Some(
                v.as_f64().ok_or_else(|| Error::Config("clip_factor".into()))? as f32
            );
        }
        if let Some(v) = get("lr_decay_steps") {
            match v {
                Value::Arr(items) => {
                    c.lr_decay_steps = items
                        .iter()
                        .map(|i| {
                            // Bounds-check before the usize cast: `-1 as
                            // usize` wraps to a huge step count (the
                            // `threads`/`shards` wrap bug, applied to the
                            // schedule).
                            let x = i.as_i64().ok_or_else(|| {
                                Error::Config("lr_decay_steps must be ints".into())
                            })?;
                            if !(0..=MAX_LR_DECAY_STEP).contains(&x) {
                                return Err(Error::Config(format!(
                                    "lr_decay_steps entry {x} must be in \
                                     [0, {MAX_LR_DECAY_STEP}] (negative values \
                                     would wrap to absurd step counts)"
                                )));
                            }
                            Ok(x as usize)
                        })
                        .collect::<Result<_>>()?;
                }
                _ => return Err(Error::Config("lr_decay_steps must be an array".into())),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if self.batch == 0 || self.batch % self.workers != 0 {
            return Err(Error::Config(format!(
                "batch {} must be a positive multiple of workers {}",
                self.batch, self.workers
            )));
        }
        if self.bucket_size == 0 {
            return Err(Error::Config("bucket_size must be >= 1".into()));
        }
        // Catches negative config values too: the i64 → usize cast wraps
        // them to huge counts.
        if self.threads > 1024 {
            return Err(Error::Config(format!(
                "threads ({}) must be in [0, 1024] (0 = auto-detect cores)",
                self.threads
            )));
        }
        if !(0.0..1.0).contains(&(self.momentum as f64)) {
            return Err(Error::Config("momentum must be in [0,1)".into()));
        }
        if let Some(&s) = self.lr_decay_steps.iter().find(|&&s| s > MAX_LR_DECAY_STEP as usize) {
            return Err(Error::Config(format!(
                "lr_decay_steps entry {s} must be at most {MAX_LR_DECAY_STEP} \
                 (absurd values are usually wrapped negatives)"
            )));
        }
        if self.quantize_downlink && self.topology == Topology::Ring {
            return Err(Error::Config(
                "quantize_downlink quantizes the coordinator's mean broadcast; \
                 the ring topology has no broadcast downlink — the final \
                 all-gather chunks already ride the ring encoded (drop it or \
                 pick topology = \"ps\", \"hier\" or \"sharded-ps\")"
                    .into(),
            ));
        }
        // Catches negative config values too: the i64 → usize cast wraps
        // them to huge counts (the `threads` hardening, applied to the
        // sharded-ps knobs).
        if self.shards == 0 || self.shards > 4096 {
            return Err(Error::Config(format!(
                "shards ({}) must be in [1, 4096] (1 degenerates to the flat \
                 parameter server)",
                self.shards
            )));
        }
        if self.staleness > 1024 {
            return Err(Error::Config(format!(
                "staleness ({}) must be in [0, 1024] (0 = fully synchronous)",
                self.staleness
            )));
        }
        if self.topology != Topology::ShardedPs {
            if self.shards != 1 {
                return Err(Error::Config(format!(
                    "shards ({}) only applies to topology = \"sharded-ps\"",
                    self.shards
                )));
            }
            if self.staleness != 0 {
                return Err(Error::Config(format!(
                    "staleness ({}) requires the asynchronous topology = \"sharded-ps\"; \
                     the {} topology is synchronous by construction",
                    self.staleness, self.topology
                )));
            }
        }
        match self.topology {
            Topology::Hier => {
                if self.groups == 0 || self.workers % self.groups != 0 {
                    return Err(Error::Config(format!(
                        "groups ({}) must be a positive divisor of workers ({})",
                        self.groups, self.workers
                    )));
                }
            }
            Topology::Ps | Topology::Ring | Topology::ShardedPs => {
                if self.groups != 1 {
                    return Err(Error::Config(format!(
                        "groups ({}) only applies to topology = \"hier\"",
                        self.groups
                    )));
                }
            }
        }
        // error_feedback composes with every topology: the PS paths keep
        // the worker-side residual, and the ring/hier requantize-per-hop
        // sites carry one residual per hop position (per-hop EF).
        // threads != 1 composes too, since the parallel codec has a
        // pipeline-side residual (BucketPipeline::encode_ef_into).
        if self.error_feedback && self.method == "fp" {
            return Err(Error::Config(
                "error_feedback compensates quantization error; method = \"fp\" \
                 has none (drop error_feedback or pick a quantizing method)"
                    .into(),
            ));
        }
        if let Some(s) = self.sections {
            // Catches negative config values too (the `threads`
            // hardening, applied to the overlap knob).
            if s == 0 || s > 1024 {
                return Err(Error::Config(format!("sections ({s}) must be in [1, 1024]")));
            }
            if !self.overlap {
                return Err(Error::Config(format!(
                    "sections ({s}) only shapes the overlapped encode and would be \
                     silently ignored without it — add overlap = true (--overlap) \
                     or stream_sections = true (--stream-sections), or drop sections"
                )));
            }
        }
        if self.stream_sections && !self.overlap {
            return Err(Error::Config(
                "stream_sections is an overlap mode and implies overlap = true; \
                 a config with stream_sections set but overlap cleared is \
                 contradictory"
                    .into(),
            ));
        }
        if self.stream_sections && self.staleness != 0 {
            return Err(Error::Config(format!(
                "stream_sections needs a synchronous exchange: the streamed round \
                 reduces section frames of the current round only, but staleness \
                 ({}) lets workers run ahead (drop one of the two)",
                self.staleness
            )));
        }
        if let Some(b) = self.byte_budget {
            if b == 0 {
                return Err(Error::Config("byte_budget must be >= 1".into()));
            }
            if crate::quant::budget::parse_family(&self.method).is_none() {
                return Err(Error::Config(format!(
                    "byte_budget re-spends the method's bit width per bucket; \
                     method = \"{}\" cannot vary its level count (pick a \
                     parameterizable scheme: orq-S, qsgd-S or linear-S)",
                    self.method
                )));
            }
        }
        if let Some(s) = &self.budget_schedule {
            crate::quant::budget::BudgetSchedule::parse(s)?;
            if self.byte_budget.is_none() {
                return Err(Error::Config(
                    "budget_schedule shapes the byte-budget ramp and would be \
                     silently ignored without a budget — add byte_budget = BYTES \
                     (--byte-budget) or drop it"
                        .into(),
                ));
            }
        }
        if self.overlap && self.method == "fp" {
            return Err(Error::Config(
                "overlap pipelines section quantize+encode behind backward; \
                 method = \"fp\" has no bucket grid to pipeline (drop overlap \
                 or pick a quantizing method)"
                    .into(),
            ));
        }
        self.links.validate()?;
        Ok(())
    }

    /// The overlap section count actually in force: the configured
    /// value, or 4 (the historical default) when `sections` is unset.
    pub fn effective_sections(&self) -> usize {
        self.sections.unwrap_or(4)
    }

    /// The simulated per-edge-class link map for this run.
    pub fn link_map(&self) -> LinkMap {
        self.links.link_map()
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_map(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars_and_sections() {
        let m = parse(
            r#"
            # experiment
            top = 1
            [train]
            model = "mlp_m"
            lr = 0.05
            workers = 4
            clip = true
            decay = [300, 450]  # comment
            "#,
        )
        .unwrap();
        assert_eq!(m["top"], Value::Int(1));
        assert_eq!(m["train.model"], Value::Str("mlp_m".into()));
        assert_eq!(m["train.lr"], Value::Float(0.05));
        assert_eq!(m["train.clip"], Value::Bool(true));
        assert_eq!(
            m["train.decay"],
            Value::Arr(vec![Value::Int(300), Value::Int(450)])
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("novalue =").is_err());
        assert!(parse("[unterminated").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("bare line").is_err());
    }

    #[test]
    fn hash_inside_string_ok() {
        let m = parse("x = \"a#b\"").unwrap();
        assert_eq!(m["x"], Value::Str("a#b".into()));
    }

    #[test]
    fn train_config_from_map() {
        let m = parse(
            r#"
            [train]
            model = "mlp_l"
            method = "orq-9"
            workers = 4
            batch = 256
            clip_factor = 2.5
            lr_decay_steps = [100, 200]
            quantize_downlink = true
            topology = "hier"
            groups = 2
            "#,
        )
        .unwrap();
        let c = TrainConfig::from_map(&m).unwrap();
        assert_eq!(c.model, "mlp_l");
        assert_eq!(c.method, "orq-9");
        assert_eq!(c.workers, 4);
        assert_eq!(c.clip_factor, Some(2.5));
        assert_eq!(c.lr_decay_steps, vec![100, 200]);
        assert!(c.quantize_downlink);
        assert_eq!(c.topology, Topology::Hier);
        assert_eq!(c.groups, 2);
        // defaults preserved
        assert_eq!(c.momentum, 0.9);
    }

    #[test]
    fn topology_defaults_to_ps_and_rejects_unknown() {
        let c = TrainConfig::from_map(&parse("[train]\nworkers = 2\nbatch = 64").unwrap()).unwrap();
        assert_eq!(c.topology, Topology::Ps);
        let bad = parse("[train]\ntopology = \"mesh\"").unwrap();
        assert!(TrainConfig::from_map(&bad).is_err());
        let wrong_type = parse("[train]\ntopology = 3").unwrap();
        assert!(TrainConfig::from_map(&wrong_type).is_err());
        // the ring has no broadcast downlink to quantize
        let c = TrainConfig {
            topology: Topology::Ring,
            quantize_downlink: true,
            ..TrainConfig::default()
        };
        assert!(c.validate().is_err());
        let c = TrainConfig { topology: Topology::Ring, ..TrainConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn lr_decay_steps_reject_negative_and_absurd_entries() {
        let base = "[train]\nworkers = 2\nbatch = 64\n";
        let from = |toml: &str| TrainConfig::from_map(&parse(toml).unwrap());
        // a sane schedule parses
        let c = from(&format!("{base}lr_decay_steps = [100, 200]")).unwrap();
        assert_eq!(c.lr_decay_steps, vec![100, 200]);
        // negatives must not wrap through the i64 → usize cast
        let err = from(&format!("{base}lr_decay_steps = [100, -1]")).unwrap_err();
        assert!(err.to_string().contains("wrap"), "{err}");
        // absurd entries are rejected with the bound in the message
        let err = from(&format!("{base}lr_decay_steps = [999999999999]")).unwrap_err();
        assert!(err.to_string().contains("100000000"), "{err}");
        // non-integer entries keep the type error
        assert!(from(&format!("{base}lr_decay_steps = [1.5]")).is_err());
        // direct construction is caught by validate() too
        let c = TrainConfig { lr_decay_steps: vec![usize::MAX], ..TrainConfig::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn threads_key_parses_and_defaults_serial() {
        assert_eq!(TrainConfig::default().threads, 1);
        let c = TrainConfig::from_map(
            &parse("[train]\nworkers = 2\nbatch = 64\nthreads = 4").unwrap(),
        )
        .unwrap();
        assert_eq!(c.threads, 4);
        // 0 = auto-detect is a valid setting
        let c = TrainConfig::from_map(
            &parse("[train]\nworkers = 2\nbatch = 64\nthreads = 0").unwrap(),
        )
        .unwrap();
        assert_eq!(c.threads, 0);
        assert!(c.validate().is_ok());
        // negative values wrap to huge usize counts and must be rejected
        let bad = parse("[train]\nworkers = 2\nbatch = 64\nthreads = -1").unwrap();
        assert!(TrainConfig::from_map(&bad).is_err());
        let bad = parse("[train]\nworkers = 2\nbatch = 64\nthreads = 100000").unwrap();
        assert!(TrainConfig::from_map(&bad).is_err());
    }

    #[test]
    fn pool_key_parses_and_defaults_pooled() {
        assert!(TrainConfig::default().pool, "pooled execution is the default");
        let c = TrainConfig::from_map(
            &parse("[train]\nworkers = 2\nbatch = 64\npool = false").unwrap(),
        )
        .unwrap();
        assert!(!c.pool);
        let c = TrainConfig::from_map(
            &parse("[train]\nworkers = 2\nbatch = 64\npool = true\nthreads = 4").unwrap(),
        )
        .unwrap();
        assert!(c.pool);
        // wrong value types are errors, not silent defaults
        assert!(TrainConfig::from_map(&parse("[train]\npool = 1").unwrap()).is_err());
        assert!(TrainConfig::from_map(&parse("[train]\npool = \"yes\"").unwrap()).is_err());
    }

    #[test]
    fn overlap_keys_parse_and_validate() {
        let d = TrainConfig::default();
        assert!(!d.overlap, "flat exchange is the default");
        assert_eq!(d.sections, None);
        assert_eq!(d.effective_sections(), 4);
        let c = TrainConfig::from_map(
            &parse("[train]\nmethod = \"orq-5\"\noverlap = true\nsections = 8\nthreads = 4")
                .unwrap(),
        )
        .unwrap();
        assert!(c.overlap);
        assert_eq!(c.sections, Some(8));
        assert_eq!(c.effective_sections(), 8);
        // wrong value types are errors, not silent defaults
        assert!(TrainConfig::from_map(&parse("[train]\noverlap = 1").unwrap()).is_err());
        // sections = 0 and wrapped negatives are rejected
        let overlapped = "[train]\nmethod = \"orq-5\"\noverlap = true\n";
        assert!(TrainConfig::from_map(
            &parse(&format!("{overlapped}sections = 0")).unwrap()
        )
        .is_err());
        assert!(TrainConfig::from_map(
            &parse(&format!("{overlapped}sections = -2")).unwrap()
        )
        .is_err());
        // sections without overlap was silently ignored before PR 8 —
        // now it is an actionable config error
        let err =
            TrainConfig::from_map(&parse("[train]\nsections = 4").unwrap()).unwrap_err();
        assert!(err.to_string().contains("silently ignored"), "{err}");
        assert!(err.to_string().contains("--overlap"), "{err}");
        // overlap needs a quantizing method: fp has no bucket grid
        let bad = parse("[train]\nmethod = \"fp\"\noverlap = true").unwrap();
        let err = TrainConfig::from_map(&bad).unwrap_err();
        assert!(err.to_string().contains("quantizing method"), "{err}");
        // overlap at threads = 1 is allowed — the serial start-anywhere
        // encoder stages sections inline on the driver thread
        let c = TrainConfig { method: "terngrad".into(), overlap: true, ..TrainConfig::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn stream_sections_key_parses_and_validates() {
        assert!(!TrainConfig::default().stream_sections, "flat exchange is the default");
        // the flag implies overlap, so users pass it alone
        let c = TrainConfig::from_map(
            &parse("[train]\nmethod = \"orq-5\"\nstream_sections = true\nsections = 2")
                .unwrap(),
        )
        .unwrap();
        assert!(c.stream_sections);
        assert!(c.overlap, "stream_sections must imply overlap");
        assert_eq!(c.sections, Some(2));
        // wrong value types are errors, not silent defaults
        assert!(TrainConfig::from_map(&parse("[train]\nstream_sections = 1").unwrap()).is_err());
        // fp has no bucket grid to stream (via the implied overlap)
        let bad = parse("[train]\nmethod = \"fp\"\nstream_sections = true").unwrap();
        assert!(TrainConfig::from_map(&bad).is_err());
        // streaming reduces current-round frames only: staleness rejects
        let bad = parse(
            "[train]\nworkers = 2\nbatch = 64\nmethod = \"orq-3\"\n\
             topology = \"sharded-ps\"\nshards = 2\nstaleness = 1\n\
             stream_sections = true",
        )
        .unwrap();
        let err = TrainConfig::from_map(&bad).unwrap_err();
        assert!(err.to_string().contains("synchronous"), "{err}");
        // ...but synchronous sharded-ps streams fine
        let ok = parse(
            "[train]\nworkers = 2\nbatch = 64\nmethod = \"orq-3\"\n\
             topology = \"sharded-ps\"\nshards = 2\nstream_sections = true",
        )
        .unwrap();
        assert!(TrainConfig::from_map(&ok).is_ok());
        // direct construction with the implication broken is rejected
        let c = TrainConfig {
            method: "terngrad".into(),
            stream_sections: true,
            overlap: false,
            ..TrainConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn byte_budget_keys_parse_and_validate() {
        let d = TrainConfig::default();
        assert_eq!(d.byte_budget, None, "fixed-width is the default");
        assert_eq!(d.budget_schedule, None);
        let c = TrainConfig::from_map(
            &parse(
                "[train]\nmethod = \"orq-8\"\nbyte_budget = 4096\n\
                 budget_schedule = \"coarse-to-fine\"",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.byte_budget, Some(4096));
        assert_eq!(c.budget_schedule.as_deref(), Some("coarse-to-fine"));
        let rejects = |toml: &str| TrainConfig::from_map(&parse(toml).unwrap()).is_err();
        // wrong value types are errors, not silent defaults
        assert!(rejects("[train]\nmethod = \"orq-8\"\nbyte_budget = \"lots\""));
        assert!(rejects("[train]\nmethod = \"orq-8\"\nbudget_schedule = 3"));
        // zero and wrapped negatives are rejected before the u64 cast
        assert!(rejects("[train]\nmethod = \"orq-8\"\nbyte_budget = 0"));
        assert!(rejects("[train]\nmethod = \"orq-8\"\nbyte_budget = -4096"));
        // the budget re-spends bit widths: fixed-level schemes reject
        let err = TrainConfig::from_map(
            &parse("[train]\nmethod = \"terngrad\"\nbyte_budget = 4096").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("parameterizable"), "{err}");
        let err = TrainConfig::from_map(
            &parse("[train]\nmethod = \"fp\"\nbyte_budget = 4096").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("orq-S"), "{err}");
        // a schedule without a budget would be silently ignored — reject
        let err = TrainConfig::from_map(
            &parse("[train]\nmethod = \"orq-8\"\nbudget_schedule = \"coarse-to-fine\"")
                .unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("byte_budget"), "{err}");
        // unknown schedule names name the supported set
        let err = TrainConfig::from_map(
            &parse(
                "[train]\nmethod = \"orq-8\"\nbyte_budget = 4096\n\
                 budget_schedule = \"fine-to-coarse\"",
            )
            .unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("coarse-to-fine"), "{err}");
        // budgets compose with EF, overlap and every topology at the
        // config layer — spot-check the overlap + streaming combination
        let ok = parse(
            "[train]\nworkers = 2\nbatch = 64\nmethod = \"qsgd-8\"\n\
             byte_budget = 8192\nstream_sections = true\nthreads = 2",
        )
        .unwrap();
        assert!(TrainConfig::from_map(&ok).is_ok());
    }

    #[test]
    fn sharded_ps_keys_parse_and_validate() {
        let c = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 4\nbatch = 64\ntopology = \"sharded-ps\"\n\
                 shards = 3\nstaleness = 2",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.topology, Topology::ShardedPs);
        assert_eq!(c.shards, 3);
        assert_eq!(c.staleness, 2);
        // defaults: one shard, synchronous
        let d = TrainConfig::default();
        assert_eq!((d.shards, d.staleness), (1, 0));
        assert!(!d.error_feedback);
        let rejects = |toml: &str| TrainConfig::from_map(&parse(toml).unwrap()).is_err();
        let sharded = "[train]\nworkers = 2\nbatch = 64\ntopology = \"sharded-ps\"\n";
        // shards = 0, negative and absurd counts are rejected
        assert!(rejects(&format!("{sharded}shards = 0")));
        assert!(rejects(&format!("{sharded}shards = -2")));
        assert!(rejects(&format!("{sharded}shards = 100000")));
        // staleness must be non-negative and bounded
        assert!(rejects(&format!("{sharded}staleness = -1")));
        assert!(rejects(&format!("{sharded}staleness = 100000")));
        // sharding/staleness on a synchronous topology is an error
        assert!(rejects("[train]\nworkers = 2\nbatch = 64\nshards = 2"));
        assert!(rejects("[train]\nworkers = 2\nbatch = 64\nstaleness = 1"));
        assert!(rejects(
            "[train]\nworkers = 2\nbatch = 64\ntopology = \"ring\"\nstaleness = 1"
        ));
        // the per-shard mean downlink quantizes too
        let c = TrainConfig::from_map(
            &parse(&format!("{sharded}quantize_downlink = true")).unwrap(),
        )
        .unwrap();
        assert!(c.quantize_downlink);
    }

    #[test]
    fn error_feedback_key_parses_and_validates() {
        let c = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 2\nbatch = 64\nmethod = \"bingrad-b\"\n\
                 error_feedback = true",
            )
            .unwrap(),
        )
        .unwrap();
        assert!(c.error_feedback);
        let rejects = |toml: &str| TrainConfig::from_map(&parse(toml).unwrap()).is_err();
        // fp has no quantization error to compensate
        assert!(rejects("[train]\nworkers = 2\nbatch = 64\nerror_feedback = true"));
        // ring/hier compose via per-hop residuals
        let ok = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n\
                 topology = \"ring\"\nerror_feedback = true",
            )
            .unwrap(),
        );
        assert!(ok.is_ok(), "per-hop EF lifts the ring restriction");
        let ok = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 4\nbatch = 64\nmethod = \"bingrad-b\"\n\
                 topology = \"hier\"\ngroups = 2\nerror_feedback = true",
            )
            .unwrap(),
        );
        assert!(ok.is_ok(), "per-hop EF lifts the hier restriction");
        // the parallel codec composes with EF (pipeline-side residual)
        let ok = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n\
                 threads = 4\nerror_feedback = true",
            )
            .unwrap(),
        );
        assert!(ok.is_ok(), "EF + parallel codec is now supported");
        // sharded-ps accepts EF
        let ok = TrainConfig::from_map(
            &parse(
                "[train]\nworkers = 2\nbatch = 64\nmethod = \"terngrad\"\n\
                 topology = \"sharded-ps\"\nshards = 2\nerror_feedback = true",
            )
            .unwrap(),
        );
        assert!(ok.is_ok());
    }

    #[test]
    fn hier_groups_and_links_from_map() {
        let m = parse(
            r#"
            [train]
            workers = 6
            batch = 60
            topology = "hier"
            groups = 3
            intra_bandwidth = 100e9
            intra_latency = 1e-6
            inter_bandwidth = 1e9
            inter_latency = 0.01
            "#,
        )
        .unwrap();
        let c = TrainConfig::from_map(&m).unwrap();
        assert_eq!(c.topology, Topology::Hier);
        assert_eq!(c.groups, 3);
        let lm = c.link_map();
        assert_eq!(lm.intra.bandwidth_bps, 100e9);
        assert_eq!(lm.intra.latency_s, 1e-6);
        assert_eq!(lm.inter.bandwidth_bps, 1e9);
        assert_eq!(lm.inter.latency_s, 0.01);
    }

    #[test]
    fn hier_rejects_bad_groups_and_links() {
        let rejects = |toml: &str| TrainConfig::from_map(&parse(toml).unwrap()).is_err();
        let base = "[train]\nworkers = 4\nbatch = 4\n";
        // groups must divide workers
        assert!(rejects(&format!("{base}topology = \"hier\"\ngroups = 3")));
        // groups on a flat topology is an error, not silently ignored
        assert!(rejects(&format!("{base}groups = 2")));
        // hier's root multicast quantizes like the PS broadcast
        let q = format!("{base}topology = \"hier\"\ngroups = 2\nquantize_downlink = true");
        let c = TrainConfig::from_map(&parse(&q).unwrap()).unwrap();
        assert!(c.quantize_downlink);
        // link keys must be numbers…
        assert!(rejects("[train]\ninter_bandwidth = \"fast\""));
        // …and physically meaningful (no zero/negative bandwidth, no
        // negative latency) — errors, not Link::new panics
        assert!(rejects("[train]\ninter_bandwidth = 0"));
        assert!(rejects("[train]\nintra_bandwidth = -1e9"));
        assert!(rejects("[train]\ninter_latency = -0.5"));
    }

    #[test]
    fn validation_catches_bad_combos() {
        let mut c = TrainConfig::default();
        c.workers = 3;
        c.batch = 128; // not a multiple of 3
        assert!(c.validate().is_err());
        c.batch = 129;
        assert!(c.validate().is_ok());
        c.workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn default_matches_paper_setup() {
        let c = TrainConfig::default();
        assert_eq!(c.momentum, 0.9);
        assert_eq!(c.lr, 0.1);
        assert_eq!(c.bucket_size, 2048);
        assert_eq!(c.lr_decay, 0.1);
        assert!(c.clip_factor.is_none(), "CIFAR default: no clipping (§5.1)");
    }
}
