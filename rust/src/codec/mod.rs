//! Wire codec: `QuantizedGrad` ⇄ bytes, with exact byte accounting.
//!
//! Message layout (little-endian):
//! ```text
//! magic      u32   0x3151_524F ("ORQ1")
//! version    u8    1
//! flags      u8    bit0 = raw FP32 payload, bit1 = base-s packing,
//!                  bit2 = per-bucket width table present
//! s          u8    number of levels (0 for FP; with a width table, the
//!                  maximum width in the table)
//! name_len   u8    scheme name length
//! bucket     u32   bucket size d
//! total      u64   total element count
//! name       [u8]  scheme name (ASCII)
//! widths     [u8]  (bit2 only) ceil(total/bucket) level counts, one per
//!                  bucket, each in 2..=s with max == s
//! payload:
//!   FP   : total × f32
//!   else : per bucket — sᵢ × f32 level table, then packed indices
//! ```
//! The width table is how adaptive byte-budget allocation travels
//! in-band (`quant::budget`): each bucket carries its own level count
//! sᵢ, so a decoder never assumes a run-wide width. It is validated
//! like every other header field — entries outside `2..=s`, a maximum
//! that disagrees with the header `s`, a table on an FP or empty
//! message, or a payload that does not sum to exactly
//! Σ [`per_bucket_bytes`]`(lenᵢ, sᵢ)` all return `Err`. Messages
//! without bit2 are byte-identical to the PR 9 wire format.
//! The per-bucket f32 level table is exactly the "sending floating-point
//! to represent quantization levels" overhead the paper discusses for
//! bucket-size selection (Table 3).
//!
//! Hot-path entry points: every encoder has an `_into` form writing into a
//! reused buffer, [`decode_flat_into`] dequantizes straight into a flat
//! f32 buffer through a [`DecodeScratch`] (no `QuantizedGrad`
//! materialization, no per-bucket allocation), and
//! [`slice_elements_into`] cuts a bucket-aligned element range out of an
//! encoded message as a standalone message — the ring all-reduce uses it
//! to ship each node's original quantized chunks without requantizing,
//! and [`slice_elements_append`] lands the same cut behind an existing
//! envelope header (the sharded-ps versioned frames) in one copy.
//! For the parallel bucket pipeline (`quant::parallel`),
//! [`encode_quantized_header_into`] + [`BucketEncoder`] let shards append
//! payload segments that concatenate byte-identically to [`encode`], and
//! [`decode_slice_into`] decodes a bucket-aligned element range into a
//! disjoint slice of a shared output buffer. Packing state (fixed width,
//! radix reciprocal) is precomputed once per message, not per bucket.
//! Every decode path is fallible end to end: malformed wire bytes —
//! truncated headers or payloads, bad scheme names, length lies — return
//! `Err`, never panic.

pub mod bitpack;

use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::QuantizedBucket;

const MAGIC: u32 = 0x3151_524F;
const VERSION: u8 = 1;
const FLAG_FP: u8 = 1;
const FLAG_BASE_S: u8 = 2;
const FLAG_WIDTHS: u8 = 4;

/// Index packing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packing {
    /// `ceil(log2 s)` bits/element.
    Fixed,
    /// Radix packing at ~`log2 s` bits/element (paper's ratios).
    BaseS,
}

/// Encode a full-precision gradient into a reused buffer (cleared first).
pub fn encode_fp_into(g: &[f32], out: &mut Vec<u8>) {
    out.clear();
    write_header(out, FLAG_FP, 0, "fp", g.len() as u64, g.len().max(1) as u32);
    out.reserve(g.len() * 4);
    for v in g {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a full-precision gradient (the ×1 baseline wire format).
pub fn encode_fp(g: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    encode_fp_into(g, &mut out);
    out
}

/// Encode a quantized gradient into a reused buffer (cleared first).
/// The hot path: no per-bucket allocation.
pub fn encode_into(qg: &QuantizedGrad, scheme: &str, packing: Packing, out: &mut Vec<u8>) {
    let s = qg.buckets.first().map(|b| b.levels.len()).unwrap_or(0);
    out.clear();
    encode_quantized_header_into(s, scheme, packing, qg.total_len, qg.bucket_size, out);
    encode_buckets_into(&qg.buckets, s, packing, out);
}

/// Append the wire header of a quantized message (the parallel pipeline
/// writes the header once, then has its shards append bucket payloads
/// via [`BucketEncoder`]).
pub fn encode_quantized_header_into(
    s: usize,
    scheme: &str,
    packing: Packing,
    total: usize,
    bucket: usize,
    out: &mut Vec<u8>,
) {
    let flags = if packing == Packing::BaseS { FLAG_BASE_S } else { 0 };
    write_header(out, flags, s as u8, scheme, total as u64, bucket as u32);
}

/// Append the payload bytes (level table + packed indices) of a run of
/// buckets to `out`. Byte-identical to the corresponding span of
/// [`encode`]'s payload.
pub fn encode_buckets_into(
    buckets: &[QuantizedBucket],
    s: usize,
    packing: Packing,
    out: &mut Vec<u8>,
) {
    if buckets.is_empty() {
        return;
    }
    let enc = BucketEncoder::new(s, packing);
    for b in buckets {
        enc.encode_bucket_into(b, out);
    }
}

/// Per-message packing state (radix reciprocal, fixed width) hoisted out
/// of the per-bucket encode loop; `Copy` so pipeline shards share it.
#[derive(Debug, Clone, Copy)]
pub struct BucketEncoder {
    s: usize,
    bits: u32,
    radix: Option<bitpack::Radix>,
}

impl BucketEncoder {
    pub fn new(s: usize, packing: Packing) -> BucketEncoder {
        debug_assert!(s >= 2, "quantized buckets need at least 2 levels");
        BucketEncoder {
            s,
            bits: bits_for(s),
            radix: (packing == Packing::BaseS).then(|| bitpack::Radix::new(s)),
        }
    }

    /// Append one bucket's level table + packed indices to `out`.
    pub fn encode_bucket_into(&self, b: &QuantizedBucket, out: &mut Vec<u8>) {
        debug_assert_eq!(b.levels.len(), self.s, "all buckets must share s");
        for lv in &b.levels {
            out.extend_from_slice(&lv.to_le_bytes());
        }
        match &self.radix {
            Some(r) => r.pack_into(&b.indices, out),
            None => bitpack::pack_fixed_into(&b.indices, self.bits, out),
        }
    }
}

/// Encode a quantized gradient.
pub fn encode(qg: &QuantizedGrad, scheme: &str, packing: Packing) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(qg, scheme, packing, &mut out);
    out
}

/// Decoded message.
#[derive(Debug)]
pub enum Decoded {
    Fp(Vec<f32>),
    Quantized { grad: QuantizedGrad, scheme: String },
}

impl Decoded {
    /// Dequantize either variant to a flat vector.
    pub fn to_flat(&self) -> Vec<f32> {
        match self {
            Decoded::Fp(v) => v.clone(),
            Decoded::Quantized { grad, .. } => grad.dequantize(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Decoded::Fp(v) => v.len(),
            Decoded::Quantized { grad, .. } => grad.total_len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Validated view of an encoded message: header fields + payload slice.
/// Every byte-level check (magic, version, exact payload length against
/// the closed-form [`wire_size`]) happens here, shared by all decoders.
struct Wire<'a> {
    flags: u8,
    s: usize,
    bucket: usize,
    total: usize,
    scheme: &'a str,
    /// Per-bucket level counts when the message carries a width table
    /// (`FLAG_WIDTHS`); `None` on uniform-width messages.
    widths: Option<&'a [u8]>,
    payload: &'a [u8],
}

impl<'a> Wire<'a> {
    fn is_fp(&self) -> bool {
        self.flags & FLAG_FP != 0
    }

    fn packing(&self) -> Packing {
        if self.flags & FLAG_BASE_S != 0 {
            Packing::BaseS
        } else {
            Packing::Fixed
        }
    }
}

fn parse(bytes: &[u8]) -> Result<Wire<'_>> {
    let mut r = Reader { bytes, pos: 0 };
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#x}")));
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(Error::Codec(format!("unsupported version {version}")));
    }
    let flags = r.u8()?;
    let s = r.u8()? as usize;
    let name_len = r.u8()? as usize;
    let bucket = r.u32()? as usize;
    let total = r.u64()? as usize;
    let name_bytes = r.take(name_len)?;
    let scheme = std::str::from_utf8(name_bytes)
        .map_err(|_| Error::Codec("non-utf8 scheme name".into()))?;

    // Every encoder frames bucket ≥ 1 (FP uses len.max(1)), so a zero
    // here is corruption; rejecting it for FP too keeps the parallel
    // decode's bucket-grid sharding from degenerating to empty ranges.
    if bucket == 0 {
        return Err(Error::Codec("bucket size 0".into()));
    }
    if flags & FLAG_FP != 0 {
        if flags & FLAG_WIDTHS != 0 {
            return Err(Error::Codec("width table on an FP message".into()));
        }
        let remaining = bytes.len() - r.pos;
        let need = total
            .checked_mul(4)
            .ok_or_else(|| Error::Codec("total overflows".into()))?;
        if need != remaining {
            return Err(Error::Codec(format!(
                "fp payload is {remaining} bytes, header claims {need}"
            )));
        }
        return Ok(Wire { flags, s, bucket, total, scheme, widths: None, payload: &bytes[r.pos..] });
    }
    if s < 2 {
        return Err(Error::Codec(format!("quantized message with s={s}")));
    }
    let widths = if flags & FLAG_WIDTHS != 0 {
        // The table length is ceil(total/bucket); empty slices drop the
        // flag, so a table on a zero-element message is corruption.
        if total == 0 {
            return Err(Error::Codec("width table on an empty message".into()));
        }
        let n_buckets = total.div_ceil(bucket);
        // `take` bounds the table against the actual bytes, so a lying
        // `total` cannot make us index past the end (or overflow `pos`).
        let table = r.take(n_buckets)?;
        let mut max = 0u8;
        for (i, &w) in table.iter().enumerate() {
            if (w as usize) < 2 || (w as usize) > s {
                return Err(Error::Codec(format!(
                    "width table entry {i} is {w}, outside 2..={s}"
                )));
            }
            max = max.max(w);
        }
        if max as usize != s {
            return Err(Error::Codec(format!(
                "width table maximum {max} disagrees with header s={s}"
            )));
        }
        Some(table)
    } else {
        None
    };
    // Guard against length lies in corrupted headers: the exact payload
    // size is computable up front — reject before any allocation sized by
    // attacker-controlled fields (found by the byte-corruption fuzz test).
    let remaining = bytes.len() - r.pos;
    let packing = if flags & FLAG_BASE_S != 0 { Packing::BaseS } else { Packing::Fixed };
    // Coarse bound first: ≥1 bit per element, so total can never exceed
    // 8× the payload bytes — rejects absurd headers before the exact
    // (multiplication-bearing) computation below can overflow.
    if total > remaining.saturating_mul(8).saturating_add(bucket) {
        return Err(Error::Codec(format!(
            "header claims {total} elements for a {remaining}-byte payload"
        )));
    }
    let expected = match widths {
        None => wire_size(total, bucket, s, packing, scheme)
            .checked_sub(r.pos)
            .ok_or_else(|| Error::Codec("header size underflow".into()))?,
        Some(table) => {
            let mut sum = 0usize;
            for (i, &w) in table.iter().enumerate() {
                let len = if i + 1 == table.len() { tail_len(total, bucket) } else { bucket };
                sum = sum
                    .checked_add(per_bucket_bytes(len, w as usize, packing))
                    .ok_or_else(|| Error::Codec("width payload size overflows".into()))?;
            }
            sum
        }
    };
    if expected != remaining {
        return Err(Error::Codec(format!(
            "payload is {remaining} bytes, header claims {expected}"
        )));
    }
    Ok(Wire { flags, s, bucket, total, scheme, widths, payload: &bytes[r.pos..] })
}

/// Length of the final (possibly ragged) bucket.
fn tail_len(total: usize, bucket: usize) -> usize {
    if total % bucket == 0 {
        bucket
    } else {
        total % bucket
    }
}

/// Decode a wire message.
pub fn decode(bytes: &[u8]) -> Result<Decoded> {
    let w = parse(bytes)?;
    let mut r = Reader { bytes: w.payload, pos: 0 };
    if w.is_fp() {
        let mut out = Vec::with_capacity(w.total);
        for _ in 0..w.total {
            out.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        return Ok(Decoded::Fp(out));
    }
    let radix = match w.packing() {
        Packing::BaseS => Some(bitpack::Radix::new(w.s)),
        Packing::Fixed => None,
    };
    let n_buckets = w.total.div_ceil(w.bucket);
    let mut buckets = Vec::with_capacity(n_buckets);
    for bi in 0..n_buckets {
        // With a width table each bucket has its own level count (and
        // its own radix); without one, every bucket shares the header s.
        let s = w.widths.map(|t| t[bi] as usize).unwrap_or(w.s);
        let radix_b = match (&radix, w.widths) {
            (Some(_), Some(_)) => Some(bitpack::Radix::new(s)),
            (Some(rx), None) => Some(*rx),
            (None, _) => None,
        };
        let len = if bi + 1 == n_buckets { tail_len(w.total, w.bucket) } else { w.bucket };
        let mut levels = Vec::with_capacity(s);
        for _ in 0..s {
            levels.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        let payload_len = packed_len(len, s, w.packing());
        let payload = r.take(payload_len)?;
        let mut indices = Vec::new();
        match &radix_b {
            Some(rx) => rx.unpack_into(payload, len, &mut indices)?,
            None => bitpack::unpack_fixed_into(payload, len, bits_for(s), &mut indices)?,
        }
        if indices.iter().any(|&i| (i as usize) >= s) {
            return Err(Error::Codec("index out of level range".into()));
        }
        buckets.push(QuantizedBucket { levels, indices });
    }
    Ok(Decoded::Quantized {
        grad: QuantizedGrad { bucket_size: w.bucket, total_len: w.total, buckets },
        scheme: w.scheme.to_string(),
    })
}

/// Reusable decoder scratch: one level table + one index buffer, recycled
/// across buckets and rounds.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    levels: Vec<f32>,
    indices: Vec<u8>,
}

/// Decode a wire message straight into a flat f32 buffer (cleared and
/// refilled) — the exchange hot path. Performs the same validation as
/// [`decode`] but never materializes per-bucket vectors: level tables and
/// unpacked indices live in `scratch`.
pub fn decode_flat_into(bytes: &[u8], out: &mut Vec<f32>, scratch: &mut DecodeScratch) -> Result<()> {
    let w = parse(bytes)?;
    out.clear();
    if w.is_fp() {
        out.reserve(w.total);
        for chunk in w.payload.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        return Ok(());
    }
    out.resize(w.total, 0.0);
    let n_buckets = w.total.div_ceil(w.bucket);
    decode_bucket_run(&w, 0, n_buckets, out, scratch)
}

/// Decode elements `[e0, e1)` of an encoded message into `out`
/// (`out.len() == e1 − e0`). Quantized cuts must be aligned to the
/// message's bucket grid (`e % bucket == 0` or `e == total` at both
/// ends); FP messages slice at any element boundary. Disjoint ranges can
/// be decoded concurrently into disjoint slices of one output buffer —
/// the parallel decode path of `quant::parallel::BucketPipeline`.
pub fn decode_slice_into(
    bytes: &[u8],
    e0: usize,
    e1: usize,
    out: &mut [f32],
    scratch: &mut DecodeScratch,
) -> Result<()> {
    let w = parse(bytes)?;
    if e0 > e1 || e1 > w.total {
        return Err(Error::Codec(format!(
            "slice {e0}..{e1} out of range for {} elements",
            w.total
        )));
    }
    if out.len() != e1 - e0 {
        return Err(Error::Shape(format!(
            "slice {e0}..{e1} decoded into a {}-element buffer",
            out.len()
        )));
    }
    if w.is_fp() {
        let src = w.payload[e0 * 4..e1 * 4].chunks_exact(4);
        for (o, chunk) in out.iter_mut().zip(src) {
            *o = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        return Ok(());
    }
    let d = w.bucket;
    let aligned = |e: usize| e % d == 0 || e == w.total;
    if !aligned(e0) || !aligned(e1) {
        return Err(Error::Codec(format!(
            "slice {e0}..{e1} not aligned to bucket size {d}"
        )));
    }
    if e0 == e1 {
        return Ok(());
    }
    decode_bucket_run(&w, e0 / d, e1.div_ceil(d), out, scratch)
}

/// Shared quantized decode loop over buckets `[b0, b1)` of a validated
/// message, writing the dequantized values into `out` (whose length must
/// equal the covered element count). `parse()` validated the exact
/// payload length, so the offset reads cannot run past the end.
fn decode_bucket_run(
    w: &Wire<'_>,
    b0: usize,
    b1: usize,
    out: &mut [f32],
    scratch: &mut DecodeScratch,
) -> Result<()> {
    if w.widths.is_some() {
        return decode_bucket_run_widths(w, b0, b1, out, scratch);
    }
    let s = w.s;
    let radix = match w.packing() {
        Packing::BaseS => Some(bitpack::Radix::new(s)),
        Packing::Fixed => None,
    };
    let bits = bits_for(s.max(2));
    let n_buckets = w.total.div_ceil(w.bucket);
    // Hoisted per-bucket byte counts: only the final bucket can be ragged.
    let tail = tail_len(w.total, w.bucket);
    let full_packed = packed_len(w.bucket, s, w.packing());
    let tail_packed = packed_len(tail, s, w.packing());
    let mut pos = b0 * (s * 4 + full_packed);
    let mut outpos = 0usize;
    for bi in b0..b1 {
        let is_tail = bi + 1 == n_buckets;
        let len = if is_tail { tail } else { w.bucket };
        scratch.levels.clear();
        for _ in 0..s {
            scratch
                .levels
                .push(f32::from_le_bytes(w.payload[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        let payload_len = if is_tail { tail_packed } else { full_packed };
        let packed = &w.payload[pos..pos + payload_len];
        pos += payload_len;
        match &radix {
            Some(r) => r.unpack_into(packed, len, &mut scratch.indices)?,
            None => bitpack::unpack_fixed_into(packed, len, bits, &mut scratch.indices)?,
        }
        for &i in &scratch.indices {
            let lv = scratch
                .levels
                .get(i as usize)
                .ok_or_else(|| Error::Codec("index out of level range".into()))?;
            out[outpos] = *lv;
            outpos += 1;
        }
    }
    debug_assert_eq!(outpos, out.len());
    Ok(())
}

/// [`decode_bucket_run`] for width-table messages: each bucket carries
/// its own level count, so byte offsets are prefix sums over the table
/// and the unpacker is rebuilt per bucket. `parse()` validated the table
/// entries and the exact payload length, so the offset reads cannot run
/// past the end.
fn decode_bucket_run_widths(
    w: &Wire<'_>,
    b0: usize,
    b1: usize,
    out: &mut [f32],
    scratch: &mut DecodeScratch,
) -> Result<()> {
    let table = w.widths.expect("caller checked widths");
    let packing = w.packing();
    let n_buckets = w.total.div_ceil(w.bucket);
    let tail = tail_len(w.total, w.bucket);
    let blen = |bi: usize| if bi + 1 == n_buckets { tail } else { w.bucket };
    let mut pos = 0usize;
    for (bi, &wd) in table.iter().enumerate().take(b0) {
        pos += per_bucket_bytes(blen(bi), wd as usize, packing);
    }
    let mut outpos = 0usize;
    for bi in b0..b1 {
        let s = table[bi] as usize;
        let len = blen(bi);
        scratch.levels.clear();
        for _ in 0..s {
            scratch
                .levels
                .push(f32::from_le_bytes(w.payload[pos..pos + 4].try_into().unwrap()));
            pos += 4;
        }
        let payload_len = packed_len(len, s, packing);
        let packed = &w.payload[pos..pos + payload_len];
        pos += payload_len;
        match packing {
            Packing::BaseS => {
                bitpack::Radix::new(s).unpack_into(packed, len, &mut scratch.indices)?
            }
            Packing::Fixed => {
                bitpack::unpack_fixed_into(packed, len, bits_for(s), &mut scratch.indices)?
            }
        }
        for &i in &scratch.indices {
            let lv = scratch
                .levels
                .get(i as usize)
                .ok_or_else(|| Error::Codec("index out of level range".into()))?;
            out[outpos] = *lv;
            outpos += 1;
        }
    }
    debug_assert_eq!(outpos, out.len());
    Ok(())
}

/// Cheap header peek: `(total element count, bucket size)` of an encoded
/// message, with the full O(1) header/length validation of the decoders
/// but no payload work. FP messages report their framing bucket size.
pub fn peek_shape(bytes: &[u8]) -> Result<(usize, usize)> {
    let w = parse(bytes)?;
    Ok((w.total, w.bucket))
}

/// Cut elements `[e0, e1)` out of an encoded message as a standalone
/// message with the same scheme, flags and bucket size — a pure payload
/// byte copy, no requantization. For quantized messages the cut must be
/// aligned to the message's bucket grid (`e % bucket == 0` or `e ==
/// total` at both ends); FP messages slice at any element boundary.
pub fn slice_elements_into(bytes: &[u8], e0: usize, e1: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    slice_elements_append(bytes, e0, e1, out)
}

/// [`slice_elements_into`] appended to `out`'s existing tail instead of
/// clearing it — so an outer envelope (the sharded-ps versioned frame)
/// can write its header first and have the sliced message land directly
/// behind it, one copy, one owned buffer. On `Err` the tail is
/// unspecified (callers discard the buffer).
pub fn slice_elements_append(bytes: &[u8], e0: usize, e1: usize, out: &mut Vec<u8>) -> Result<()> {
    let w = parse(bytes)?;
    if e0 > e1 || e1 > w.total {
        return Err(Error::Codec(format!(
            "slice {e0}..{e1} out of range for {} elements",
            w.total
        )));
    }
    let n = e1 - e0;
    if w.is_fp() {
        write_header(out, w.flags, 0, w.scheme, n as u64, n.max(1) as u32);
        out.extend_from_slice(&w.payload[e0 * 4..e1 * 4]);
        return Ok(());
    }
    let d = w.bucket;
    let aligned = |e: usize| e % d == 0 || e == w.total;
    if !aligned(e0) || !aligned(e1) {
        return Err(Error::Codec(format!(
            "slice {e0}..{e1} not aligned to bucket size {d}"
        )));
    }
    if let Some(table) = w.widths {
        // Width-table slice: byte offsets are prefix sums over the table,
        // the sub-table rides along, and the slice's header s is the
        // sub-table maximum (the invariant parse() enforces). An empty
        // slice has no buckets to describe, so it drops the flag.
        if n == 0 {
            write_header(out, w.flags & !FLAG_WIDTHS, w.s as u8, w.scheme, 0, d as u32);
            return Ok(());
        }
        let n_buckets = w.total.div_ceil(d);
        let tail = tail_len(w.total, d);
        let packing = w.packing();
        let (b0, b1) = (e0 / d, e1.div_ceil(d));
        let mut off = [0usize; 2];
        let mut pos = 0usize;
        for (bi, &wd) in table.iter().enumerate() {
            if bi == b0 {
                off[0] = pos;
            }
            if bi == b1 {
                break;
            }
            let len = if bi + 1 == n_buckets { tail } else { d };
            pos += per_bucket_bytes(len, wd as usize, packing);
        }
        off[1] = if b1 == n_buckets { w.payload.len() } else { pos };
        let sub = &table[b0..b1];
        let s_sub = sub.iter().copied().max().expect("non-empty slice");
        write_header(out, w.flags, s_sub, w.scheme, n as u64, d as u32);
        out.extend_from_slice(sub);
        out.extend_from_slice(&w.payload[off[0]..off[1]]);
        return Ok(());
    }
    let pb_full = per_bucket_bytes(d, w.s, w.packing());
    let offset = |e: usize| -> usize {
        if e == w.total {
            w.payload.len()
        } else {
            (e / d) * pb_full
        }
    };
    write_header(out, w.flags, w.s as u8, w.scheme, n as u64, d as u32);
    out.extend_from_slice(&w.payload[offset(e0)..offset(e1)]);
    Ok(())
}

/// Reassemble contiguous sub-messages (as produced by
/// [`slice_elements_into`] over adjacent ranges, or arriving as
/// streaming section frames) into one flat message covering their
/// concatenation — the exact inverse of slicing: the result is
/// byte-identical to slicing the original message over the union range,
/// and to the flat parallel encode when the parts are a full section
/// tiling. All parts must agree on scheme, flags and level count;
/// quantized parts must share the bucket size and every part except the
/// last must cover a whole number of buckets (only the globally-final
/// bucket may be ragged). A pure byte copy — no requantization.
pub fn concat_messages_into(parts: &[&[u8]], out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    let first = match parts.first() {
        Some(p) => parse(p)?,
        None => return Err(Error::Codec("concat of zero messages".into())),
    };
    // Width-table mode: empty slices drop the widths flag (they have no
    // buckets to describe), so flags are compared modulo that bit and
    // only non-empty parts must carry a table. The reassembled header s
    // is the maximum over the concatenated table — each part's own s was
    // its sub-table maximum, so this reproduces the flat encode exactly.
    let widths_mode = {
        let mut any = false;
        for p in parts.iter() {
            if parse(p)?.widths.is_some() {
                any = true;
                break;
            }
        }
        any
    };
    let base_flags = first.flags & !FLAG_WIDTHS;
    let mut cat_widths: Vec<u8> = Vec::new();
    let mut total = 0usize;
    for (i, p) in parts.iter().enumerate() {
        let w = parse(p)?;
        let flags_cmp = if widths_mode { w.flags & !FLAG_WIDTHS } else { w.flags };
        let s_agrees = if widths_mode { true } else { w.s == first.s };
        if w.scheme != first.scheme || flags_cmp != base_flags || !s_agrees {
            return Err(Error::Codec(format!(
                "concat part {i} disagrees on scheme/flags/levels with part 0"
            )));
        }
        if !w.is_fp() {
            if w.bucket != first.bucket {
                return Err(Error::Codec(format!(
                    "concat part {i} has bucket size {}, part 0 has {}",
                    w.bucket, first.bucket
                )));
            }
            if i + 1 != parts.len() && w.total % w.bucket != 0 {
                return Err(Error::Codec(format!(
                    "concat part {i} covers {} elements — not a multiple of bucket \
                     {}, only the final part may end ragged",
                    w.total, w.bucket
                )));
            }
        }
        if widths_mode && w.total > 0 {
            match w.widths {
                Some(t) => cat_widths.extend_from_slice(t),
                None => {
                    return Err(Error::Codec(format!(
                        "concat part {i} has no width table but part(s) do"
                    )))
                }
            }
        }
        total += w.total;
    }
    if widths_mode {
        let s_out = cat_widths.iter().copied().max().unwrap_or(first.s as u8);
        let flags = base_flags | if cat_widths.is_empty() { 0 } else { FLAG_WIDTHS };
        write_header(out, flags, s_out, first.scheme, total as u64, first.bucket as u32);
        out.extend_from_slice(&cat_widths);
    } else {
        // FP slices carry their own length as the framing bucket size, so
        // the reassembled header re-derives it the way `encode_fp_into`
        // does.
        let bucket = if first.is_fp() { total.max(1) } else { first.bucket };
        write_header(out, first.flags, first.s as u8, first.scheme, total as u64, bucket as u32);
    }
    for p in parts {
        let w = parse(p)?;
        out.extend_from_slice(w.payload);
    }
    Ok(())
}

/// Packed index bytes for one bucket of `len` elements.
fn packed_len(len: usize, s: usize, packing: Packing) -> usize {
    match packing {
        Packing::Fixed => (len * bits_for(s) as usize).div_ceil(8),
        Packing::BaseS => len.div_ceil(bitpack::digits_per_word(s)) * 8,
    }
}

/// On-wire bytes of one bucket of `len` elements at `s` levels: level
/// table + packed indices. The cost model the byte-budget allocator
/// (`quant::budget`) optimizes against — public so spend accounting and
/// the codec can never disagree.
pub fn per_bucket_bytes(len: usize, s: usize, packing: Packing) -> usize {
    s * 4 + packed_len(len, s, packing)
}

/// Header bytes of a message with scheme `scheme` (everything before the
/// optional width table and the payload).
pub fn header_bytes(scheme: &str) -> usize {
    4 + 1 + 1 + 1 + 1 + 4 + 8 + scheme.len()
}

/// Exact wire size in bytes without materializing the message (closed
/// form — O(1), also used as the decoder's pre-allocation validator).
pub fn wire_size(total: usize, bucket: usize, s: usize, packing: Packing, scheme: &str) -> usize {
    let hdr = header_bytes(scheme);
    if s == 0 {
        return hdr + total * 4;
    }
    let n_buckets = total.div_ceil(bucket);
    if n_buckets == 0 {
        return hdr;
    }
    hdr + (n_buckets - 1) * per_bucket_bytes(bucket, s, packing)
        + per_bucket_bytes(tail_len(total, bucket), s, packing)
}

/// Exact wire size of a width-table message: header + one table byte per
/// bucket + per-bucket payloads at each bucket's own width. The budget
/// allocator's spend accounting — by construction it can never disagree
/// with what [`encode_widths_into`] emits.
pub fn wire_size_widths(
    total: usize,
    bucket: usize,
    widths: &[u8],
    packing: Packing,
    scheme: &str,
) -> usize {
    debug_assert_eq!(widths.len(), total.div_ceil(bucket.max(1)));
    let mut size = header_bytes(scheme) + widths.len();
    for (bi, &w) in widths.iter().enumerate() {
        let len = if bi + 1 == widths.len() { tail_len(total, bucket) } else { bucket };
        size += per_bucket_bytes(len, w as usize, packing);
    }
    size
}

/// The in-band per-bucket width table of an encoded message, if it
/// carries one (`None` on uniform-width and FP messages). Fully
/// validates the message first — the entry point hops use to *read* the
/// widths they must re-encode at, never assuming them.
pub fn message_widths(bytes: &[u8]) -> Result<Option<&[u8]>> {
    Ok(parse(bytes)?.widths)
}

/// Copy the width table of `bytes` (if any) into a reusable scratch
/// buffer, returning whether one was present. Borrow-friendly form of
/// [`message_widths`] for hops that decode a message and re-encode into
/// the same buffer.
pub fn capture_widths(bytes: &[u8], scratch: &mut Vec<u8>) -> Result<bool> {
    scratch.clear();
    match parse(bytes)?.widths {
        Some(t) => {
            scratch.extend_from_slice(t);
            Ok(true)
        }
        None => Ok(false),
    }
}

/// Append the wire header + width table of a per-bucket-width message
/// (the adaptive-budget twin of [`encode_quantized_header_into`]): the
/// header `s` is the table maximum, per the format invariant. Shards or
/// sections then append bucket payloads at each bucket's own width.
pub fn encode_quantized_header_widths_into(
    widths: &[u8],
    scheme: &str,
    packing: Packing,
    total: usize,
    bucket: usize,
    out: &mut Vec<u8>,
) {
    debug_assert!(!widths.is_empty(), "width tables describe at least one bucket");
    debug_assert_eq!(widths.len(), total.div_ceil(bucket.max(1)));
    let s = widths.iter().copied().max().unwrap_or(0);
    let flags =
        FLAG_WIDTHS | if packing == Packing::BaseS { FLAG_BASE_S } else { 0 };
    write_header(out, flags, s, scheme, total as u64, bucket as u32);
    out.extend_from_slice(widths);
}

/// Encode a quantized gradient whose buckets carry per-bucket level
/// counts (`b.levels.len()` is bucket `b`'s width) as a width-table
/// message into a reused buffer (cleared first).
pub fn encode_widths_into(
    qg: &QuantizedGrad,
    scheme: &str,
    packing: Packing,
    out: &mut Vec<u8>,
) {
    out.clear();
    let widths: Vec<u8> = qg.buckets.iter().map(|b| b.levels.len() as u8).collect();
    encode_quantized_header_widths_into(
        &widths,
        scheme,
        packing,
        qg.total_len,
        qg.bucket_size,
        out,
    );
    for b in &qg.buckets {
        BucketEncoder::new(b.levels.len(), packing).encode_bucket_into(b, out);
    }
}

/// Compression ratio vs 32-bit FP for a gradient of `total` elements.
pub fn compression_ratio(
    total: usize,
    bucket: usize,
    s: usize,
    packing: Packing,
    scheme: &str,
) -> f64 {
    let fp = wire_size(total, bucket.max(1), 0, packing, "fp");
    let q = wire_size(total, bucket, s, packing, scheme);
    fp as f64 / q as f64
}

fn bits_for(s: usize) -> u32 {
    (usize::BITS - (s - 1).leading_zeros()).max(1)
}

fn write_header(out: &mut Vec<u8>, flags: u8, s: u8, name: &str, total: u64, bucket: u32) {
    out.reserve(20 + name.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(flags);
    out.push(s);
    out.push(name.len() as u8);
    out.extend_from_slice(&bucket.to_le_bytes());
    out.extend_from_slice(&total.to_le_bytes());
    out.extend_from_slice(name.as_bytes());
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // saturating: `n` can be header-derived (e.g. a lying width-table
        // length), so the bound check must not overflow before it rejects
        if n > self.bytes.len().saturating_sub(self.pos) {
            return Err(Error::Codec(format!(
                "truncated message: need {n} bytes at offset {}",
                self.pos
            )));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bucket::BucketQuantizer;
    use crate::quant::from_name;
    use crate::tensor::rng::Rng;

    fn sample_grad(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    #[test]
    fn fp_roundtrip() {
        let g = sample_grad(1000, 1);
        let bytes = encode_fp(&g);
        match decode(&bytes).unwrap() {
            Decoded::Fp(v) => assert_eq!(v, g),
            _ => panic!("expected FP"),
        }
        assert_eq!(bytes.len(), wire_size(1000, 1000, 0, Packing::Fixed, "fp"));
    }

    #[test]
    fn quantized_roundtrip_all_schemes() {
        let g = sample_grad(1500, 2);
        for scheme in crate::quant::paper_methods() {
            if scheme == "fp" {
                continue;
            }
            let q = from_name(scheme).unwrap();
            let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut Rng::seed_from(3));
            for packing in [Packing::Fixed, Packing::BaseS] {
                let bytes = encode(&qg, scheme, packing);
                assert_eq!(
                    bytes.len(),
                    wire_size(1500, 512, q.num_levels().max(2), packing, scheme),
                    "{scheme} {packing:?} size"
                );
                match decode(&bytes).unwrap() {
                    Decoded::Quantized { grad, scheme: name } => {
                        assert_eq!(name, scheme);
                        assert_eq!(grad.dequantize(), qg.dequantize(), "{scheme} {packing:?}");
                    }
                    _ => panic!("expected quantized"),
                }
            }
        }
    }

    #[test]
    fn flat_decode_matches_decode() {
        let g = sample_grad(1301, 3);
        let mut scratch = DecodeScratch::default();
        let mut flat = Vec::new();
        // FP path
        let bytes = encode_fp(&g);
        decode_flat_into(&bytes, &mut flat, &mut scratch).unwrap();
        assert_eq!(flat, g);
        // Quantized path, both packings, reusing the same scratch
        for scheme in ["terngrad", "orq-5", "bingrad-b"] {
            let q = from_name(scheme).unwrap();
            let qg = BucketQuantizer::new(256).quantize(&g, q.as_ref(), &mut Rng::seed_from(4));
            for packing in [Packing::Fixed, Packing::BaseS] {
                let bytes = encode(&qg, scheme, packing);
                decode_flat_into(&bytes, &mut flat, &mut scratch).unwrap();
                assert_eq!(flat, decode(&bytes).unwrap().to_flat(), "{scheme} {packing:?}");
            }
        }
    }

    #[test]
    fn flat_decode_rejects_what_decode_rejects() {
        let g = sample_grad(400, 5);
        let q = from_name("terngrad").unwrap();
        let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut Rng::seed_from(6));
        let bytes = encode(&qg, "terngrad", Packing::BaseS);
        let mut scratch = DecodeScratch::default();
        let mut flat = Vec::new();
        for n in 0..bytes.len() {
            assert!(
                decode_flat_into(&bytes[..n], &mut flat, &mut scratch).is_err(),
                "prefix {n} must not flat-decode"
            );
        }
        assert!(decode_flat_into(&bytes, &mut flat, &mut scratch).is_ok());
    }

    #[test]
    fn slice_fp_any_range() {
        let g = sample_grad(100, 7);
        let bytes = encode_fp(&g);
        let mut out = Vec::new();
        slice_elements_into(&bytes, 13, 77, &mut out).unwrap();
        match decode(&out).unwrap() {
            Decoded::Fp(v) => assert_eq!(v, &g[13..77]),
            _ => panic!("expected FP"),
        }
        // empty slice decodes to nothing
        slice_elements_into(&bytes, 100, 100, &mut out).unwrap();
        assert!(decode(&out).unwrap().is_empty());
        // the append variant lands the identical message behind an
        // existing prefix and leaves the prefix untouched
        let mut framed = vec![0xAB, 0xCD];
        slice_elements_append(&bytes, 13, 77, &mut framed).unwrap();
        slice_elements_into(&bytes, 13, 77, &mut out).unwrap();
        assert_eq!(&framed[..2], &[0xAB, 0xCD]);
        assert_eq!(&framed[2..], &out[..]);
    }

    #[test]
    fn slice_quantized_bucket_aligned() {
        let g = sample_grad(1000, 8); // d=128 → 8 buckets, ragged tail of 104
        let q = from_name("orq-5").unwrap();
        let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut Rng::seed_from(9));
        let full = qg.dequantize();
        for packing in [Packing::Fixed, Packing::BaseS] {
            let bytes = encode(&qg, "orq-5", packing);
            let mut out = Vec::new();
            // interior chunk, tail chunk, empty chunk
            for (e0, e1) in [(0usize, 256usize), (256, 1000), (1000, 1000), (0, 1000)] {
                slice_elements_into(&bytes, e0, e1, &mut out).unwrap();
                let dec = decode(&out).unwrap();
                assert_eq!(dec.to_flat(), &full[e0..e1], "{packing:?} {e0}..{e1}");
                // sliced size matches the closed form for an independent message
                assert_eq!(
                    out.len(),
                    wire_size(e1 - e0, 128, 5, packing, "orq-5"),
                    "{packing:?} {e0}..{e1} size"
                );
            }
            // misaligned cut is rejected
            assert!(slice_elements_into(&bytes, 64, 256, &mut out).is_err());
            assert!(slice_elements_into(&bytes, 0, 999, &mut out).is_err());
        }
    }

    /// Slicing a message into contiguous bucket-aligned pieces and
    /// concatenating them back must reproduce the original bytes — the
    /// hier streaming path depends on this inverse exactly.
    #[test]
    fn concat_inverts_slice() {
        let g = sample_grad(1000, 12); // d=128 → ragged 104-element tail
        let q = from_name("orq-5").unwrap();
        let qg = BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut Rng::seed_from(13));
        for packing in [Packing::Fixed, Packing::BaseS] {
            let bytes = encode(&qg, "orq-5", packing);
            let cuts = [0usize, 256, 512, 1000];
            let mut parts = Vec::new();
            for w in cuts.windows(2) {
                let mut p = Vec::new();
                slice_elements_into(&bytes, w[0], w[1], &mut p).unwrap();
                parts.push(p);
            }
            let views: Vec<&[u8]> = parts.iter().map(|p| p.as_slice()).collect();
            let mut back = Vec::new();
            concat_messages_into(&views, &mut back).unwrap();
            assert_eq!(back, bytes, "{packing:?} concat ∘ slice = id");
            // an empty middle part is absorbed
            let mut empty = Vec::new();
            slice_elements_into(&bytes, 256, 256, &mut empty).unwrap();
            let views = [parts[0].as_slice(), empty.as_slice(), parts[1].as_slice(),
                parts[2].as_slice()];
            concat_messages_into(&views, &mut back).unwrap();
            assert_eq!(back, bytes, "{packing:?} empty part absorbed");
            // a ragged non-final part is rejected
            let views = [parts[2].as_slice(), parts[0].as_slice()];
            assert!(concat_messages_into(&views, &mut back).is_err());
            // mixed wire parameters are rejected
            let fp = encode_fp(&g[..256]);
            let views = [parts[0].as_slice(), fp.as_slice()];
            assert!(concat_messages_into(&views, &mut back).is_err());
        }
        // FP slices reassemble to the flat FP encode
        let bytes = encode_fp(&g);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        slice_elements_into(&bytes, 0, 300, &mut a).unwrap();
        slice_elements_into(&bytes, 300, 1000, &mut b).unwrap();
        let mut back = Vec::new();
        concat_messages_into(&[&a, &b], &mut back).unwrap();
        assert_eq!(back, bytes);
        // zero parts is an error, one part is the identity
        assert!(concat_messages_into(&[], &mut back).is_err());
        concat_messages_into(&[&bytes], &mut back).unwrap();
        assert_eq!(back, bytes);
    }

    #[test]
    fn ragged_tail_roundtrip() {
        let g = sample_grad(1001, 4);
        let q = from_name("orq-9").unwrap();
        let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut Rng::seed_from(5));
        let bytes = encode(&qg, "orq-9", Packing::BaseS);
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.to_flat().len(), 1001);
    }

    #[test]
    fn rejects_corrupt() {
        let g = sample_grad(256, 6);
        let q = from_name("terngrad").unwrap();
        let qg = BucketQuantizer::new(256).quantize(&g, q.as_ref(), &mut Rng::seed_from(7));
        let mut bytes = encode(&qg, "terngrad", Packing::Fixed);
        // corrupt magic
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).is_err());
        bytes[0] ^= 0xFF;
        // truncate
        let n = bytes.len();
        assert!(decode(&bytes[..n - 3]).is_err());
        // bad version
        bytes[4] = 99;
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn empty_message() {
        let bytes = encode_fp(&[]);
        assert!(decode(&bytes).unwrap().is_empty());
    }

    #[test]
    fn compression_ratios_match_paper_bands() {
        // 25.6M-element gradient (ResNet-50), d=2048 (paper's CIFAR bucket),
        // base-s packing. Paper ratios ignore header/level-table overhead;
        // with d=2048 our *exact* wire accounting lands within ~6%.
        let n = 25_600_000;
        let r3 = compression_ratio(n, 2048, 3, Packing::BaseS, "terngrad");
        let r5 = compression_ratio(n, 2048, 5, Packing::BaseS, "qsgd-5");
        let r9 = compression_ratio(n, 2048, 9, Packing::BaseS, "qsgd-9");
        let r2 = compression_ratio(n, 2048, 2, Packing::Fixed, "bingrad-b");
        assert!((18.0..21.0).contains(&r3), "r3={r3}"); // paper ×20.2
        assert!((12.5..14.5).contains(&r5), "r5={r5}"); // paper ×13.8
        assert!((9.0..10.5).contains(&r9), "r9={r9}"); // paper ×10.1
        assert!((28.0..32.5).contains(&r2), "r2={r2}"); // paper ×32
        // d=512 (paper's ImageNet bucket) pays more level-table overhead:
        let r3_512 = compression_ratio(n, 512, 3, Packing::BaseS, "terngrad");
        assert!((16.5..20.2).contains(&r3_512), "r3@512={r3_512}");
    }

    #[test]
    fn larger_bucket_lower_overhead() {
        let n = 1_000_000;
        let small = wire_size(n, 128, 9, Packing::BaseS, "orq-9");
        let large = wire_size(n, 8192, 9, Packing::BaseS, "orq-9");
        assert!(large < small, "level-table overhead shrinks with bucket size");
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let g = sample_grad(600, 10);
        let q = from_name("terngrad").unwrap();
        let qg = BucketQuantizer::new(200).quantize(&g, q.as_ref(), &mut Rng::seed_from(11));
        let mut buf = vec![0xFFu8; 3]; // stale contents must be cleared
        encode_into(&qg, "terngrad", Packing::BaseS, &mut buf);
        assert_eq!(buf, encode(&qg, "terngrad", Packing::BaseS));
        encode_fp_into(&g, &mut buf);
        assert_eq!(buf, encode_fp(&g));
    }

    /// A deterministic variable-width quantized gradient: bucket `bi`
    /// gets `widths[bi]` levels with synthetic level values and cycling
    /// indices — enough structure for byte-exact roundtrip checks.
    fn widths_grad(total: usize, d: usize, widths: &[u8]) -> QuantizedGrad {
        assert_eq!(widths.len(), total.div_ceil(d));
        let buckets = widths
            .iter()
            .enumerate()
            .map(|(bi, &w)| {
                let len = if bi + 1 == widths.len() { tail_len(total, d) } else { d };
                let levels: Vec<f32> =
                    (0..w).map(|l| (bi + 1) as f32 * 0.25 + l as f32).collect();
                let indices: Vec<u8> = (0..len).map(|j| (j % w as usize) as u8).collect();
                QuantizedBucket { levels, indices }
            })
            .collect();
        QuantizedGrad { bucket_size: d, total_len: total, buckets }
    }

    #[test]
    fn widths_roundtrip_both_packings() {
        // ragged tail (1000 % 128 = 104), widths spanning 2..=7
        let qg = widths_grad(1000, 128, &[3, 2, 7, 4, 2, 5, 6, 2]);
        let want = qg.dequantize();
        let mut scratch = DecodeScratch::default();
        for packing in [Packing::Fixed, Packing::BaseS] {
            let mut bytes = Vec::new();
            encode_widths_into(&qg, "orq-7", packing, &mut bytes);
            assert_eq!(
                bytes.len(),
                wire_size_widths(1000, 128, &[3, 2, 7, 4, 2, 5, 6, 2], packing, "orq-7"),
                "{packing:?} closed-form size"
            );
            assert_eq!(
                message_widths(&bytes).unwrap(),
                Some(&[3u8, 2, 7, 4, 2, 5, 6, 2][..]),
                "{packing:?} table readback"
            );
            // materializing and flat decode agree with the source grad
            match decode(&bytes).unwrap() {
                Decoded::Quantized { grad, scheme } => {
                    assert_eq!(scheme, "orq-7");
                    assert_eq!(grad.dequantize(), want, "{packing:?}");
                }
                _ => panic!("expected quantized"),
            }
            let mut flat = Vec::new();
            decode_flat_into(&bytes, &mut flat, &mut scratch).unwrap();
            assert_eq!(flat, want, "{packing:?} flat");
            // header s must be the table maximum
            assert_eq!(bytes[6], 7, "{packing:?} header s");
        }
    }

    /// Mirror of `flat_decode_rejects_what_decode_rejects` for width
    /// messages: every truncation point must fail, as must corrupt table
    /// entries (out of range, max disagreeing with header s), a widths
    /// flag on FP or empty messages, and slicing stays grid-aligned.
    #[test]
    fn widths_fuzz_every_truncation_and_corruption() {
        let qg = widths_grad(600, 128, &[2, 5, 3, 4, 2]);
        let mut scratch = DecodeScratch::default();
        let mut flat = Vec::new();
        for packing in [Packing::Fixed, Packing::BaseS] {
            let mut bytes = Vec::new();
            encode_widths_into(&qg, "qsgd-5", packing, &mut bytes);
            for n in 0..bytes.len() {
                assert!(
                    decode_flat_into(&bytes[..n], &mut flat, &mut scratch).is_err(),
                    "{packing:?} prefix {n} must not decode"
                );
                assert!(decode(&bytes[..n]).is_err(), "{packing:?} prefix {n}");
            }
            assert!(decode_flat_into(&bytes, &mut flat, &mut scratch).is_ok());
            let table_at = header_bytes("qsgd-5");
            // entry below 2
            let mut bad = bytes.clone();
            bad[table_at] = 1;
            assert!(decode(&bad).is_err(), "{packing:?} width 1 rejected");
            // entry above header s (payload length also disagrees)
            let mut bad = bytes.clone();
            bad[table_at + 2] = 6;
            assert!(decode(&bad).is_err(), "{packing:?} width > s rejected");
            // max(table) < header s
            let mut bad = bytes.clone();
            bad[table_at + 1] = 4; // drop the only 5 → max 4 ≠ s 5
            assert!(decode(&bad).is_err(), "{packing:?} max ≠ s rejected");
        }
        // widths flag on an FP message
        let mut fp = encode_fp(&sample_grad(8, 20));
        fp[5] |= FLAG_WIDTHS;
        assert!(decode(&fp).is_err(), "FP + widths rejected");
        // widths flag on an empty quantized message
        let mut empty = Vec::new();
        write_header(&mut empty, FLAG_WIDTHS, 2, "terngrad", 0, 128);
        assert!(decode(&empty).is_err(), "empty + widths rejected");
    }

    /// Slicing a width message keeps the sub-table (header s = sub-max),
    /// empty slices drop the flag, and concat inverts the slicing — the
    /// identity the overlap/streaming paths rely on under a budget.
    #[test]
    fn widths_slice_and_concat_invert() {
        let table = [3u8, 2, 7, 4, 2, 5, 6, 2];
        let qg = widths_grad(1000, 128, &table);
        let full = qg.dequantize();
        for packing in [Packing::Fixed, Packing::BaseS] {
            let mut bytes = Vec::new();
            encode_widths_into(&qg, "orq-7", packing, &mut bytes);
            let mut out = Vec::new();
            for (e0, e1) in [(0usize, 256usize), (256, 1000), (1000, 1000), (0, 1000)] {
                slice_elements_into(&bytes, e0, e1, &mut out).unwrap();
                let dec = decode(&out).unwrap();
                assert_eq!(dec.to_flat(), &full[e0..e1], "{packing:?} {e0}..{e1}");
                if e0 < e1 {
                    let sub = &table[e0 / 128..e1.div_ceil(128)];
                    assert_eq!(
                        message_widths(&out).unwrap(),
                        Some(sub),
                        "{packing:?} {e0}..{e1} sub-table"
                    );
                    assert_eq!(out[6], *sub.iter().max().unwrap(), "{packing:?} slice s");
                } else {
                    assert_eq!(message_widths(&out).unwrap(), None, "empty drops flag");
                }
            }
            assert!(slice_elements_into(&bytes, 64, 256, &mut out).is_err());
            // slice into pieces (+ an empty piece) and concat back
            let cuts = [0usize, 256, 512, 1000];
            let mut parts = Vec::new();
            for w in cuts.windows(2) {
                let mut p = Vec::new();
                slice_elements_into(&bytes, w[0], w[1], &mut p).unwrap();
                parts.push(p);
            }
            let mut empty = Vec::new();
            slice_elements_into(&bytes, 512, 512, &mut empty).unwrap();
            let views =
                [parts[0].as_slice(), parts[1].as_slice(), empty.as_slice(), parts[2].as_slice()];
            let mut back = Vec::new();
            concat_messages_into(&views, &mut back).unwrap();
            assert_eq!(back, bytes, "{packing:?} concat ∘ slice = id with widths");
            // a widths part cannot concat with a non-widths part
            let plain = {
                let g = sample_grad(128, 21);
                let q = from_name("orq-7").unwrap();
                let pg =
                    BucketQuantizer::new(128).quantize(&g, q.as_ref(), &mut Rng::seed_from(22));
                encode(&pg, "orq-7", packing)
            };
            let views = [parts[0].as_slice(), plain.as_slice()];
            assert!(concat_messages_into(&views, &mut back).is_err(), "{packing:?} mixed");
        }
    }

    /// `capture_widths` copies the table through a scratch buffer (and
    /// clears stale contents when there is none).
    #[test]
    fn capture_widths_scratch() {
        let qg = widths_grad(256, 128, &[2, 4]);
        let mut bytes = Vec::new();
        encode_widths_into(&qg, "orq-4", Packing::BaseS, &mut bytes);
        let mut scratch = vec![9u8; 3];
        assert!(capture_widths(&bytes, &mut scratch).unwrap());
        assert_eq!(scratch, vec![2, 4]);
        let fp = encode_fp(&[1.0, 2.0]);
        assert!(!capture_widths(&fp, &mut scratch).unwrap());
        assert!(scratch.is_empty());
        assert!(capture_widths(&bytes[..10], &mut scratch).is_err());
    }
}
