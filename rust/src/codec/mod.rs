//! Wire codec: `QuantizedGrad` ⇄ bytes, with exact byte accounting.
//!
//! Message layout (little-endian):
//! ```text
//! magic      u32   0x3151_524F ("ORQ1")
//! version    u8    1
//! flags      u8    bit0 = raw FP32 payload, bit1 = base-s packing
//! s          u8    number of levels (0 for FP)
//! name_len   u8    scheme name length
//! bucket     u32   bucket size d
//! total      u64   total element count
//! name       [u8]  scheme name (ASCII)
//! payload:
//!   FP   : total × f32
//!   else : per bucket — s × f32 level table, then packed indices
//! ```
//! The per-bucket f32 level table is exactly the "sending floating-point
//! to represent quantization levels" overhead the paper discusses for
//! bucket-size selection (Table 3).

pub mod bitpack;

use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::QuantizedBucket;

const MAGIC: u32 = 0x3151_524F;
const VERSION: u8 = 1;
const FLAG_FP: u8 = 1;
const FLAG_BASE_S: u8 = 2;

/// Index packing strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packing {
    /// `ceil(log2 s)` bits/element.
    Fixed,
    /// Radix packing at ~`log2 s` bits/element (paper's ratios).
    BaseS,
}

/// Encode a full-precision gradient (the ×1 baseline wire format).
pub fn encode_fp(g: &[f32]) -> Vec<u8> {
    let mut out = header(FLAG_FP, 0, "fp", g.len() as u64, g.len().max(1) as u32);
    out.reserve(g.len() * 4);
    for v in g {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Encode a quantized gradient.
pub fn encode(qg: &QuantizedGrad, scheme: &str, packing: Packing) -> Vec<u8> {
    let s = qg.buckets.first().map(|b| b.levels.len()).unwrap_or(0);
    let flags = if packing == Packing::BaseS { FLAG_BASE_S } else { 0 };
    let mut out = header(flags, s as u8, scheme, qg.total_len as u64, qg.bucket_size as u32);
    for b in &qg.buckets {
        debug_assert_eq!(b.levels.len(), s, "all buckets must share s");
        for lv in &b.levels {
            out.extend_from_slice(&lv.to_le_bytes());
        }
        let packed = match packing {
            Packing::Fixed => bitpack::pack_fixed(&b.indices, bits_for(s)),
            Packing::BaseS => bitpack::pack_base_s(&b.indices, s),
        };
        out.extend_from_slice(&packed);
    }
    out
}

/// Decoded message.
#[derive(Debug)]
pub enum Decoded {
    Fp(Vec<f32>),
    Quantized { grad: QuantizedGrad, scheme: String },
}

impl Decoded {
    /// Dequantize either variant to a flat vector.
    pub fn to_flat(&self) -> Vec<f32> {
        match self {
            Decoded::Fp(v) => v.clone(),
            Decoded::Quantized { grad, .. } => grad.dequantize(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Decoded::Fp(v) => v.len(),
            Decoded::Quantized { grad, .. } => grad.total_len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Decode a wire message.
pub fn decode(bytes: &[u8]) -> Result<Decoded> {
    let mut r = Reader { bytes, pos: 0 };
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(Error::Codec(format!("bad magic {magic:#x}")));
    }
    let version = r.u8()?;
    if version != VERSION {
        return Err(Error::Codec(format!("unsupported version {version}")));
    }
    let flags = r.u8()?;
    let s = r.u8()? as usize;
    let name_len = r.u8()? as usize;
    let bucket = r.u32()? as usize;
    let total = r.u64()? as usize;
    let name_bytes = r.take(name_len)?;
    let scheme = String::from_utf8(name_bytes.to_vec())
        .map_err(|_| Error::Codec("non-utf8 scheme name".into()))?;

    // Guard against length lies in corrupted headers: the exact payload
    // size is computable up front — reject before any allocation sized by
    // attacker-controlled fields (found by the byte-corruption fuzz test).
    let remaining = bytes.len() - r.pos;
    if flags & FLAG_FP != 0 {
        let need = total
            .checked_mul(4)
            .ok_or_else(|| Error::Codec("total overflows".into()))?;
        if need != remaining {
            return Err(Error::Codec(format!(
                "fp payload is {remaining} bytes, header claims {need}"
            )));
        }
        let mut out = Vec::with_capacity(total);
        for _ in 0..total {
            out.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        return Ok(Decoded::Fp(out));
    }
    if s < 2 {
        return Err(Error::Codec(format!("quantized message with s={s}")));
    }
    if bucket == 0 {
        return Err(Error::Codec("bucket size 0".into()));
    }
    let base_s = flags & FLAG_BASE_S != 0;
    let packing = if base_s { Packing::BaseS } else { Packing::Fixed };
    // Coarse bound first: ≥1 bit per element, so total can never exceed
    // 8× the payload bytes — rejects absurd headers before the exact
    // (multiplication-bearing) computation below can overflow.
    if total > remaining.saturating_mul(8).saturating_add(bucket) {
        return Err(Error::Codec(format!(
            "header claims {total} elements for a {remaining}-byte payload"
        )));
    }
    let expected = wire_size(total, bucket, s, packing, &scheme)
        .checked_sub(r.pos)
        .ok_or_else(|| Error::Codec("header size underflow".into()))?;
    if expected != remaining {
        return Err(Error::Codec(format!(
            "payload is {remaining} bytes, header claims {expected}"
        )));
    }
    let n_buckets = total.div_ceil(bucket);
    let mut buckets = Vec::with_capacity(n_buckets);
    for bi in 0..n_buckets {
        let len = if bi + 1 == n_buckets && total % bucket != 0 { total % bucket } else { bucket };
        let mut levels = Vec::with_capacity(s);
        for _ in 0..s {
            levels.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
        }
        let payload_len = if base_s {
            len.div_ceil(bitpack::digits_per_word(s)) * 8
        } else {
            (len * bits_for(s) as usize).div_ceil(8)
        };
        let payload = r.take(payload_len)?;
        let indices = if base_s {
            bitpack::unpack_base_s(payload, len, s)
        } else {
            bitpack::unpack_fixed(payload, len, bits_for(s))
        };
        if indices.iter().any(|&i| (i as usize) >= s) {
            return Err(Error::Codec("index out of level range".into()));
        }
        buckets.push(QuantizedBucket { levels, indices });
    }
    Ok(Decoded::Quantized {
        grad: QuantizedGrad { bucket_size: bucket, total_len: total, buckets },
        scheme,
    })
}

/// Exact wire size in bytes without materializing the message (closed
/// form — O(1), also used as the decoder's pre-allocation validator).
pub fn wire_size(total: usize, bucket: usize, s: usize, packing: Packing, scheme: &str) -> usize {
    let hdr = 4 + 1 + 1 + 1 + 1 + 4 + 8 + scheme.len();
    if s == 0 {
        return hdr + total * 4;
    }
    let per_bucket = |len: usize| -> usize {
        s * 4
            + match packing {
                Packing::Fixed => (len * bits_for(s) as usize).div_ceil(8),
                Packing::BaseS => len.div_ceil(bitpack::digits_per_word(s)) * 8,
            }
    };
    let n_buckets = total.div_ceil(bucket);
    if n_buckets == 0 {
        return hdr;
    }
    let tail_len = if total % bucket == 0 { bucket } else { total % bucket };
    hdr + (n_buckets - 1) * per_bucket(bucket) + per_bucket(tail_len)
}

/// Compression ratio vs 32-bit FP for a gradient of `total` elements.
pub fn compression_ratio(
    total: usize,
    bucket: usize,
    s: usize,
    packing: Packing,
    scheme: &str,
) -> f64 {
    let fp = wire_size(total, bucket.max(1), 0, packing, "fp");
    let q = wire_size(total, bucket, s, packing, scheme);
    fp as f64 / q as f64
}

fn bits_for(s: usize) -> u32 {
    (usize::BITS - (s - 1).leading_zeros()).max(1)
}

fn header(flags: u8, s: u8, name: &str, total: u64, bucket: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(20 + name.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(flags);
    out.push(s);
    out.push(name.len() as u8);
    out.extend_from_slice(&bucket.to_le_bytes());
    out.extend_from_slice(&total.to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            return Err(Error::Codec(format!(
                "truncated message: need {n} bytes at offset {}",
                self.pos
            )));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bucket::BucketQuantizer;
    use crate::quant::from_name;
    use crate::tensor::rng::Rng;

    fn sample_grad(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    #[test]
    fn fp_roundtrip() {
        let g = sample_grad(1000, 1);
        let bytes = encode_fp(&g);
        match decode(&bytes).unwrap() {
            Decoded::Fp(v) => assert_eq!(v, g),
            _ => panic!("expected FP"),
        }
        assert_eq!(bytes.len(), wire_size(1000, 1000, 0, Packing::Fixed, "fp"));
    }

    #[test]
    fn quantized_roundtrip_all_schemes() {
        let g = sample_grad(1500, 2);
        for scheme in crate::quant::paper_methods() {
            if scheme == "fp" {
                continue;
            }
            let q = from_name(scheme).unwrap();
            let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut Rng::seed_from(3));
            for packing in [Packing::Fixed, Packing::BaseS] {
                let bytes = encode(&qg, scheme, packing);
                assert_eq!(
                    bytes.len(),
                    wire_size(1500, 512, q.num_levels().max(2), packing, scheme),
                    "{scheme} {packing:?} size"
                );
                match decode(&bytes).unwrap() {
                    Decoded::Quantized { grad, scheme: name } => {
                        assert_eq!(name, scheme);
                        assert_eq!(grad.dequantize(), qg.dequantize(), "{scheme} {packing:?}");
                    }
                    _ => panic!("expected quantized"),
                }
            }
        }
    }

    #[test]
    fn ragged_tail_roundtrip() {
        let g = sample_grad(1001, 4);
        let q = from_name("orq-9").unwrap();
        let qg = BucketQuantizer::new(512).quantize(&g, q.as_ref(), &mut Rng::seed_from(5));
        let bytes = encode(&qg, "orq-9", Packing::BaseS);
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.to_flat().len(), 1001);
    }

    #[test]
    fn rejects_corrupt() {
        let g = sample_grad(256, 6);
        let q = from_name("terngrad").unwrap();
        let qg = BucketQuantizer::new(256).quantize(&g, q.as_ref(), &mut Rng::seed_from(7));
        let mut bytes = encode(&qg, "terngrad", Packing::Fixed);
        // corrupt magic
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).is_err());
        bytes[0] ^= 0xFF;
        // truncate
        let n = bytes.len();
        assert!(decode(&bytes[..n - 3]).is_err());
        // bad version
        bytes[4] = 99;
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn empty_message() {
        let bytes = encode_fp(&[]);
        assert!(decode(&bytes).unwrap().is_empty());
    }

    #[test]
    fn compression_ratios_match_paper_bands() {
        // 25.6M-element gradient (ResNet-50), d=2048 (paper's CIFAR bucket),
        // base-s packing. Paper ratios ignore header/level-table overhead;
        // with d=2048 our *exact* wire accounting lands within ~6%.
        let n = 25_600_000;
        let r3 = compression_ratio(n, 2048, 3, Packing::BaseS, "terngrad");
        let r5 = compression_ratio(n, 2048, 5, Packing::BaseS, "qsgd-5");
        let r9 = compression_ratio(n, 2048, 9, Packing::BaseS, "qsgd-9");
        let r2 = compression_ratio(n, 2048, 2, Packing::Fixed, "bingrad-b");
        assert!((18.0..21.0).contains(&r3), "r3={r3}"); // paper ×20.2
        assert!((12.5..14.5).contains(&r5), "r5={r5}"); // paper ×13.8
        assert!((9.0..10.5).contains(&r9), "r9={r9}"); // paper ×10.1
        assert!((28.0..32.5).contains(&r2), "r2={r2}"); // paper ×32
        // d=512 (paper's ImageNet bucket) pays more level-table overhead:
        let r3_512 = compression_ratio(n, 512, 3, Packing::BaseS, "terngrad");
        assert!((16.5..20.2).contains(&r3_512), "r3@512={r3_512}");
    }

    #[test]
    fn larger_bucket_lower_overhead() {
        let n = 1_000_000;
        let small = wire_size(n, 128, 9, Packing::BaseS, "orq-9");
        let large = wire_size(n, 8192, 9, Packing::BaseS, "orq-9");
        assert!(large < small, "level-table overhead shrinks with bucket size");
    }
}
