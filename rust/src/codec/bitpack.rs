//! Index packing: fixed-width bit packing and base-s ("entropy-ideal")
//! packing.
//!
//! Fixed-width spends `ceil(log2 s)` bits/element (2 bits for s=3). The
//! paper's reported compression ratios (×20.2 for 3 levels, ×13.8 for 5,
//! ×10.1 for 9) correspond to the *ideal* `log2(s)` bits/element; base-s
//! packing reaches that asymptotically by radix-encoding groups of digits
//! into u64 words (40 trits / 27 pentits / 20 nonits per word).
//!
//! Each packer has an `_into` form that appends to (or refills) a caller
//! buffer — the exchange hot path uses those so per-bucket work never
//! allocates.

/// Append `indices` (< 2^bits each) at `bits` per element to `out`.
pub fn pack_fixed_into(indices: &[u8], bits: u32, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    let start = out.len();
    let total_bits = indices.len() * bits as usize;
    out.resize(start + total_bits.div_ceil(8), 0);
    let buf = &mut out[start..];
    let mut bitpos = 0usize;
    for &idx in indices {
        debug_assert!((idx as u32) < (1 << bits));
        let byte = bitpos / 8;
        let off = (bitpos % 8) as u32;
        buf[byte] |= idx << off;
        if off + bits > 8 {
            buf[byte + 1] |= idx >> (8 - off);
        }
        bitpos += bits as usize;
    }
}

/// Pack `indices` (< 2^bits each) at `bits` per element.
pub fn pack_fixed(indices: &[u8], bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    pack_fixed_into(indices, bits, &mut out);
    out
}

/// Unpack `n` elements at `bits` per element into a reused buffer
/// (cleared first).
pub fn unpack_fixed_into(bytes: &[u8], n: usize, bits: u32, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    let mask = ((1u16 << bits) - 1) as u8;
    out.clear();
    out.reserve(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = (bitpos % 8) as u32;
        let mut v = bytes[byte] >> off;
        if off + bits > 8 {
            v |= bytes[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
}

/// Unpack `n` elements at `bits` per element.
pub fn unpack_fixed(bytes: &[u8], n: usize, bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    unpack_fixed_into(bytes, n, bits, &mut out);
    out
}

/// Max digits of radix `s` that fit a u64: largest g with s^g ≤ 2^64.
pub fn digits_per_word(s: usize) -> usize {
    debug_assert!(s >= 2);
    let mut g = 0usize;
    let mut acc: u128 = 1;
    loop {
        acc *= s as u128;
        if acc > u128::from(u64::MAX) + 1 {
            return g;
        }
        g += 1;
    }
}

/// Append radix-s-encoded indices (< s each) as u64 words, little-endian
/// digits, to `out`.
pub fn pack_base_s_into(indices: &[u8], s: usize, out: &mut Vec<u8>) {
    let g = digits_per_word(s);
    out.reserve(indices.len().div_ceil(g) * 8);
    for chunk in indices.chunks(g) {
        let mut word: u64 = 0;
        for &d in chunk.iter().rev() {
            debug_assert!((d as usize) < s);
            word = word * s as u64 + d as u64;
        }
        out.extend_from_slice(&word.to_le_bytes());
    }
}

/// Radix-encode indices (< s each) into u64 words, little-endian digits.
pub fn pack_base_s(indices: &[u8], s: usize) -> Vec<u8> {
    let mut out = Vec::new();
    pack_base_s_into(indices, s, &mut out);
    out
}

/// Decode `n` radix-s digits from packed u64 words into a reused buffer
/// (cleared first).
pub fn unpack_base_s_into(bytes: &[u8], n: usize, s: usize, out: &mut Vec<u8>) {
    let g = digits_per_word(s);
    out.clear();
    out.reserve(n);
    for chunk in bytes.chunks(8) {
        let mut word = u64::from_le_bytes(chunk.try_into().expect("word-aligned payload"));
        for _ in 0..g {
            if out.len() == n {
                break;
            }
            out.push((word % s as u64) as u8);
            word /= s as u64;
        }
        if out.len() == n {
            break;
        }
    }
    assert_eq!(out.len(), n, "payload too short");
}

/// Decode `n` radix-s digits from packed u64 words.
pub fn unpack_base_s(bytes: &[u8], n: usize, s: usize) -> Vec<u8> {
    let mut out = Vec::new();
    unpack_base_s_into(bytes, n, s, &mut out);
    out
}

/// Effective bits/element of base-s packing (asymptotic, exact per word).
pub fn base_s_bits_per_element(s: usize) -> f64 {
    64.0 / digits_per_word(s) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn rand_indices(n: usize, s: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.below(s as u64) as u8).collect()
    }

    #[test]
    fn fixed_roundtrip_all_widths() {
        for bits in 1..=8u32 {
            let s = 1usize << bits;
            for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
                let idx = rand_indices(n, s, bits as u64 * 100 + n as u64);
                let packed = pack_fixed(&idx, bits);
                assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
                assert_eq!(unpack_fixed(&packed, n, bits), idx, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn digits_per_word_known_values() {
        assert_eq!(digits_per_word(2), 64);
        assert_eq!(digits_per_word(3), 40); // 3^40 < 2^64 < 3^41
        assert_eq!(digits_per_word(5), 27);
        assert_eq!(digits_per_word(9), 20);
        assert_eq!(digits_per_word(16), 16);
        assert_eq!(digits_per_word(256), 8);
    }

    #[test]
    fn base_s_roundtrip() {
        for s in [2usize, 3, 5, 9, 17] {
            for n in [0usize, 1, 19, 20, 21, 40, 1000] {
                let idx = rand_indices(n, s, s as u64 * 1000 + n as u64);
                let packed = pack_base_s(&idx, s);
                assert_eq!(unpack_base_s(&packed, n, s), idx, "s={s} n={n}");
            }
        }
    }

    #[test]
    fn into_variants_append_and_reuse() {
        let idx = rand_indices(100, 5, 1);
        // append semantics for packers
        let mut out = vec![0xAAu8; 3];
        pack_base_s_into(&idx, 5, &mut out);
        assert_eq!(&out[..3], &[0xAA; 3]);
        assert_eq!(&out[3..], pack_base_s(&idx, 5).as_slice());
        let mut out2 = vec![0x55u8; 2];
        pack_fixed_into(&idx, 3, &mut out2);
        assert_eq!(&out2[..2], &[0x55; 2]);
        assert_eq!(&out2[2..], pack_fixed(&idx, 3).as_slice());
        // clear semantics for unpackers
        let packed = pack_base_s(&idx, 5);
        let mut scratch = vec![9u8; 7];
        unpack_base_s_into(&packed, idx.len(), 5, &mut scratch);
        assert_eq!(scratch, idx);
        let packed_f = pack_fixed(&idx, 3);
        unpack_fixed_into(&packed_f, idx.len(), 3, &mut scratch);
        assert_eq!(scratch, idx);
    }

    #[test]
    fn base_s_beats_fixed_for_non_powers() {
        // 3 levels: fixed = 2 bits, base-3 = 1.6 bits.
        assert!(base_s_bits_per_element(3) < 2.0);
        assert!((base_s_bits_per_element(3) - 1.6).abs() < 1e-9);
        // 9 levels: fixed = 4, base-9 = 3.2
        assert!((base_s_bits_per_element(9) - 3.2).abs() < 1e-9);
        // powers of two identical
        assert_eq!(base_s_bits_per_element(2), 1.0);
    }

    #[test]
    fn paper_compression_ratios() {
        // Paper Table 2: ×20.2 (3 lvls), ×13.8 (5 lvls), ×10.1 (9 lvls).
        // 32 / bits-per-element with base-s packing should land close.
        let r3 = 32.0 / base_s_bits_per_element(3);
        let r5 = 32.0 / base_s_bits_per_element(5);
        let r9 = 32.0 / base_s_bits_per_element(9);
        assert!((r3 - 20.0).abs() < 0.5, "r3={r3}");
        assert!((r5 - 13.5).abs() < 0.5, "r5={r5}");
        assert!((r9 - 10.0).abs() < 0.5, "r9={r9}");
    }
}
