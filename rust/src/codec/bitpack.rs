//! Index packing: fixed-width bit packing and base-s ("entropy-ideal")
//! packing.
//!
//! Fixed-width spends `ceil(log2 s)` bits/element (2 bits for s=3). The
//! paper's reported compression ratios (×20.2 for 3 levels, ×13.8 for 5,
//! ×10.1 for 9) correspond to the *ideal* `log2(s)` bits/element; base-s
//! packing reaches that asymptotically by radix-encoding groups of digits
//! into u64 words (40 trits / 27 pentits / 20 nonits per word).
//!
//! # Word-level layout
//!
//! The fixed-width wire format is a little-endian bit stream: element k
//! occupies stream bits `[k·bits, (k+1)·bits)`, and byte b of the payload
//! holds stream bits `[8b, 8b+8)` with bit j of the byte at stream
//! position `8b + j`. A u64 in little-endian byte order has exactly the
//! same bit numbering as 8 consecutive stream bytes, so the packers work
//! a word at a time instead of an element at a time: 8 elements always
//! fill exactly `bits` whole bytes (`8·bits` stream bits), and for the
//! power-of-two widths 1/2/4/8 a full u64 holds `64/bits` elements. The
//! word kernels ([`pack_fixed_into`]/[`unpack_fixed_into`]) are branchless
//! per group — build `Σ idx_k << (k·bits)`, store/load the low bytes —
//! with monomorphic specializations for bits ∈ {1, 2, 4} (bits = 8 is a
//! byte copy). They are bit-identical to the retained scalar reference
//! kernels ([`pack_fixed_scalar_into`]/[`unpack_fixed_scalar_into`]),
//! which the differential suite (`rust/tests/codec_differential.rs`) and
//! the `perfbench` baseline keep honest.
//!
//! # Reciprocal-multiplication radix decode
//!
//! Base-s decode extracts one digit per `%`/`/` pair. A hardware 64-bit
//! division costs 20–40 cycles; [`Radix`] replaces it with
//! multiply-by-precomputed-reciprocal: for a non-power-of-two radix s
//! with ℓ = ⌊log₂ s⌋ ≥ 1, precompute `m = ⌊2^(64+ℓ)/s⌋ < 2^64`. Then for
//! any n < 2^64, `q̂ = ⌊n·m / 2^(64+ℓ)⌋` under-estimates `⌊n/s⌋` by at
//! most 1 (writing `2^(64+ℓ) = m·s + e` with `0 ≤ e < s`, the error term
//! `n·e/(s·2^(64+ℓ)) < 2^-ℓ ≤ ½ < 1`), so a single branchless
//! compare-and-fix of the remainder recovers the exact quotient. Powers
//! of two use shift/mask. `digits_per_word(s)` and the reciprocal are
//! computed once per [`Radix`] and hoisted out of every pack/unpack loop
//! (and, via the codec, out of the per-bucket decode loop).
//!
//! Each packer has an `_into` form that appends to (or refills) a caller
//! buffer — the exchange hot path uses those so per-bucket work never
//! allocates. Unpackers are fallible: truncated or non-word-aligned
//! payloads return `Err` instead of panicking, so malformed wire bytes
//! can never take down a worker.

use crate::error::{Error, Result};

// --------------------------------------------------------------------
// Fixed-width packing
// --------------------------------------------------------------------

/// Append `indices` (< 2^bits each) at `bits` per element to `out`.
/// Word-at-a-time kernel; bit-identical to [`pack_fixed_scalar_into`].
pub fn pack_fixed_into(indices: &[u8], bits: u32, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    let start = out.len();
    let total_bits = indices.len() * bits as usize;
    out.resize(start + total_bits.div_ceil(8), 0);
    let buf = &mut out[start..];
    match bits {
        1 => pack_words::<1>(indices, buf),
        2 => pack_words::<2>(indices, buf),
        4 => pack_words::<4>(indices, buf),
        8 => buf.copy_from_slice(indices),
        _ => pack_groups(indices, bits, buf),
    }
}

/// Monomorphic kernel for the power-of-two widths 1/2/4: `64/B` elements
/// per output u64 word, whole words stored with `to_le_bytes`.
fn pack_words<const B: u32>(indices: &[u8], buf: &mut [u8]) {
    let per = (64 / B) as usize;
    let nf = indices.len() / per;
    let (full, tail) = buf.split_at_mut(nf * 8);
    for (chunk, dst) in indices.chunks_exact(per).zip(full.chunks_exact_mut(8)) {
        let mut word = 0u64;
        for (k, &idx) in chunk.iter().enumerate() {
            debug_assert!((idx as u32) < (1 << B));
            word |= (idx as u64) << (k as u32 * B);
        }
        dst.copy_from_slice(&word.to_le_bytes());
    }
    let rem = &indices[nf * per..];
    if !rem.is_empty() {
        let mut word = 0u64;
        for (k, &idx) in rem.iter().enumerate() {
            debug_assert!((idx as u32) < (1 << B));
            word |= (idx as u64) << (k as u32 * B);
        }
        tail.copy_from_slice(&word.to_le_bytes()[..tail.len()]);
    }
}

/// Generic word kernel for bits ∈ {3, 5, 6, 7}: 8 elements fill exactly
/// `bits` whole bytes, so groups never straddle a byte boundary.
fn pack_groups(indices: &[u8], bits: u32, buf: &mut [u8]) {
    let b = bits as usize;
    let nf = indices.len() / 8;
    let (full, tail) = buf.split_at_mut(nf * b);
    for (chunk, dst) in indices.chunks_exact(8).zip(full.chunks_exact_mut(b)) {
        let mut word = 0u64;
        for (k, &idx) in chunk.iter().enumerate() {
            debug_assert!((idx as u32) < (1 << bits));
            word |= (idx as u64) << (k as u32 * bits);
        }
        dst.copy_from_slice(&word.to_le_bytes()[..b]);
    }
    let rem = &indices[nf * 8..];
    if !rem.is_empty() {
        let mut word = 0u64;
        for (k, &idx) in rem.iter().enumerate() {
            debug_assert!((idx as u32) < (1 << bits));
            word |= (idx as u64) << (k as u32 * bits);
        }
        tail.copy_from_slice(&word.to_le_bytes()[..tail.len()]);
    }
}

/// Pack `indices` (< 2^bits each) at `bits` per element.
pub fn pack_fixed(indices: &[u8], bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    pack_fixed_into(indices, bits, &mut out);
    out
}

/// Retained scalar reference packer (per-element shift loop). The word
/// kernels are asserted byte-identical to this; `perfbench` measures
/// both in the same run.
pub fn pack_fixed_scalar_into(indices: &[u8], bits: u32, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&bits));
    let start = out.len();
    let total_bits = indices.len() * bits as usize;
    out.resize(start + total_bits.div_ceil(8), 0);
    let buf = &mut out[start..];
    let mut bitpos = 0usize;
    for &idx in indices {
        debug_assert!((idx as u32) < (1 << bits));
        let byte = bitpos / 8;
        let off = (bitpos % 8) as u32;
        buf[byte] |= idx << off;
        if off + bits > 8 {
            buf[byte + 1] |= idx >> (8 - off);
        }
        bitpos += bits as usize;
    }
}

/// Unpack `n` elements at `bits` per element into a reused buffer
/// (cleared first). Errors on a payload shorter than `n` elements need.
pub fn unpack_fixed_into(bytes: &[u8], n: usize, bits: u32, out: &mut Vec<u8>) -> Result<()> {
    assert!((1..=8).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    if bytes.len() < need {
        return Err(Error::Codec(format!(
            "fixed-width payload too short: {} bytes for {n} elements at {bits} bits",
            bytes.len()
        )));
    }
    out.clear();
    out.reserve(n);
    let bytes = &bytes[..need];
    match bits {
        1 => unpack_words::<1>(bytes, n, out),
        2 => unpack_words::<2>(bytes, n, out),
        4 => unpack_words::<4>(bytes, n, out),
        8 => out.extend_from_slice(&bytes[..n]),
        _ => unpack_groups(bytes, n, bits, out),
    }
    Ok(())
}

/// Monomorphic unpack for the power-of-two widths 1/2/4. `bytes` is the
/// exact payload (`ceil(n·B/8)` bytes, checked by the caller).
fn unpack_words<const B: u32>(bytes: &[u8], n: usize, out: &mut Vec<u8>) {
    let per = (64 / B) as usize;
    let mask = (1u64 << B) - 1;
    let nf = n / per;
    for chunk in bytes.chunks_exact(8).take(nf) {
        let word = u64::from_le_bytes(chunk.try_into().unwrap());
        for k in 0..per {
            out.push(((word >> (k as u32 * B)) & mask) as u8);
        }
    }
    let r = n - nf * per;
    if r > 0 {
        let tail = &bytes[nf * 8..];
        let mut wb = [0u8; 8];
        wb[..tail.len()].copy_from_slice(tail);
        let word = u64::from_le_bytes(wb);
        for k in 0..r {
            out.push(((word >> (k as u32 * B)) & mask) as u8);
        }
    }
}

/// Generic word unpack for bits ∈ {3, 5, 6, 7}: one `bits`-byte group of
/// 8 elements per iteration.
fn unpack_groups(bytes: &[u8], n: usize, bits: u32, out: &mut Vec<u8>) {
    let b = bits as usize;
    let mask = (1u64 << bits) - 1;
    let nf = n / 8;
    for chunk in bytes.chunks_exact(b).take(nf) {
        let mut wb = [0u8; 8];
        wb[..b].copy_from_slice(chunk);
        let word = u64::from_le_bytes(wb);
        for k in 0..8u32 {
            out.push(((word >> (k * bits)) & mask) as u8);
        }
    }
    let r = n - nf * 8;
    if r > 0 {
        let tail = &bytes[nf * b..];
        let mut wb = [0u8; 8];
        wb[..tail.len()].copy_from_slice(tail);
        let word = u64::from_le_bytes(wb);
        for k in 0..r as u32 {
            out.push(((word >> (k * bits)) & mask) as u8);
        }
    }
}

/// Unpack `n` elements at `bits` per element.
pub fn unpack_fixed(bytes: &[u8], n: usize, bits: u32) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    unpack_fixed_into(bytes, n, bits, &mut out)?;
    Ok(out)
}

/// Retained scalar reference unpacker (per-element shift/branch loop).
pub fn unpack_fixed_scalar_into(
    bytes: &[u8],
    n: usize,
    bits: u32,
    out: &mut Vec<u8>,
) -> Result<()> {
    assert!((1..=8).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    if bytes.len() < need {
        return Err(Error::Codec(format!(
            "fixed-width payload too short: {} bytes for {n} elements at {bits} bits",
            bytes.len()
        )));
    }
    let mask = ((1u16 << bits) - 1) as u8;
    out.clear();
    out.reserve(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = (bitpos % 8) as u32;
        let mut v = bytes[byte] >> off;
        if off + bits > 8 {
            v |= bytes[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += bits as usize;
    }
    Ok(())
}

// --------------------------------------------------------------------
// Base-s (radix) packing
// --------------------------------------------------------------------

/// Max digits of radix `s` that fit a u64: largest g with s^g ≤ 2^64.
pub fn digits_per_word(s: usize) -> usize {
    debug_assert!(s >= 2);
    let mut g = 0usize;
    let mut acc: u128 = 1;
    loop {
        acc *= s as u128;
        if acc > u128::from(u64::MAX) + 1 {
            return g;
        }
        g += 1;
    }
}

/// Precomputed radix-s codec state: digits-per-word and the
/// divide-by-reciprocal constants, hoisted out of the pack/unpack loops.
/// Construct once per message; see the module docs for the exactness
/// argument of the reciprocal trick.
#[derive(Debug, Clone, Copy)]
pub struct Radix {
    s: u64,
    g: usize,
    kind: RadixKind,
}

#[derive(Debug, Clone, Copy)]
enum RadixKind {
    /// Power-of-two radix: shift/mask.
    Pow2 { shift: u32 },
    /// `q̂ = (n·m) >> p` under-estimates `n/s` by at most 1 (see module
    /// docs); one branchless remainder fixup makes it exact.
    Mul { m: u64, p: u32 },
}

impl Radix {
    /// `s` must be in [2, 256].
    pub fn new(s: usize) -> Radix {
        assert!((2..=256).contains(&s), "radix must be in [2, 256], got {s}");
        let su = s as u64;
        let g = digits_per_word(s);
        let kind = if su.is_power_of_two() {
            RadixKind::Pow2 { shift: su.trailing_zeros() }
        } else {
            let l = 63 - su.leading_zeros(); // ⌊log₂ s⌋ ≥ 1 for s ≥ 3
            let p = 64 + l;
            let m = ((1u128 << p) / su as u128) as u64;
            RadixKind::Mul { m, p }
        };
        Radix { s: su, g, kind }
    }

    /// Digits of this radix per u64 word.
    pub fn digits_per_word(&self) -> usize {
        self.g
    }

    /// Exact `(n / s, n % s)` without a hardware division.
    #[inline]
    fn divmod(&self, n: u64) -> (u64, u64) {
        match self.kind {
            RadixKind::Pow2 { shift } => (n >> shift, n & (self.s - 1)),
            RadixKind::Mul { m, p } => {
                let q = ((n as u128 * m as u128) >> p) as u64;
                let r = n - q * self.s;
                let fix = (r >= self.s) as u64;
                (q + fix, r - fix * self.s)
            }
        }
    }

    /// Append radix-encoded `indices` (< s each) as u64 words,
    /// little-endian digits, to `out`. Sizes the output exactly,
    /// accounting for any non-empty prefix already in `out`.
    pub fn pack_into(&self, indices: &[u8], out: &mut Vec<u8>) {
        let start = out.len();
        let words = indices.len().div_ceil(self.g);
        out.resize(start + words * 8, 0);
        for (chunk, dst) in indices.chunks(self.g).zip(out[start..].chunks_exact_mut(8)) {
            let mut word: u64 = 0;
            for &d in chunk.iter().rev() {
                debug_assert!((d as u64) < self.s);
                word = word * self.s + d as u64;
            }
            dst.copy_from_slice(&word.to_le_bytes());
        }
    }

    /// Decode `n` digits from packed u64 words into a reused buffer
    /// (cleared first). Errors on a short or non-word-aligned payload.
    pub fn unpack_into(&self, bytes: &[u8], n: usize, out: &mut Vec<u8>) -> Result<()> {
        let g = self.g;
        let need = n
            .div_ceil(g)
            .checked_mul(8)
            .ok_or_else(|| Error::Codec("digit count overflows".into()))?;
        if bytes.len() < need {
            return Err(Error::Codec(format!(
                "base-{} payload too short: {} bytes for {n} digits (need {need})",
                self.s,
                bytes.len()
            )));
        }
        out.clear();
        out.reserve(n);
        let nf = n / g; // words drained completely
        let mut chunks = bytes.chunks_exact(8);
        for chunk in chunks.by_ref().take(nf) {
            let mut word = u64::from_le_bytes(chunk.try_into().unwrap());
            for _ in 0..g {
                let (q, r) = self.divmod(word);
                out.push(r as u8);
                word = q;
            }
        }
        let rem = n - nf * g;
        if rem > 0 {
            let chunk = chunks.next().expect("length checked above");
            let mut word = u64::from_le_bytes(chunk.try_into().unwrap());
            for _ in 0..rem {
                let (q, r) = self.divmod(word);
                out.push(r as u8);
                word = q;
            }
        }
        Ok(())
    }
}

/// Append radix-s-encoded indices (< s each) as u64 words, little-endian
/// digits, to `out`.
pub fn pack_base_s_into(indices: &[u8], s: usize, out: &mut Vec<u8>) {
    Radix::new(s).pack_into(indices, out);
}

/// Radix-encode indices (< s each) into u64 words, little-endian digits.
pub fn pack_base_s(indices: &[u8], s: usize) -> Vec<u8> {
    let mut out = Vec::new();
    pack_base_s_into(indices, s, &mut out);
    out
}

/// Decode `n` radix-s digits from packed u64 words into a reused buffer
/// (cleared first). Errors on truncated/non-word-aligned payloads.
pub fn unpack_base_s_into(bytes: &[u8], n: usize, s: usize, out: &mut Vec<u8>) -> Result<()> {
    Radix::new(s).unpack_into(bytes, n, out)
}

/// Decode `n` radix-s digits from packed u64 words.
pub fn unpack_base_s(bytes: &[u8], n: usize, s: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    unpack_base_s_into(bytes, n, s, &mut out)?;
    Ok(out)
}

/// Retained scalar reference decoder (`%`/`/` per digit); the reciprocal
/// path is asserted identical to this, and `perfbench` measures both.
pub fn unpack_base_s_scalar_into(
    bytes: &[u8],
    n: usize,
    s: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    let g = digits_per_word(s);
    if bytes.len() < n.div_ceil(g) * 8 {
        return Err(Error::Codec(format!(
            "base-{s} payload too short: {} bytes for {n} digits",
            bytes.len()
        )));
    }
    out.clear();
    out.reserve(n);
    for chunk in bytes.chunks_exact(8) {
        let mut word = u64::from_le_bytes(chunk.try_into().unwrap());
        for _ in 0..g {
            if out.len() == n {
                return Ok(());
            }
            out.push((word % s as u64) as u8);
            word /= s as u64;
        }
        if out.len() == n {
            return Ok(());
        }
    }
    if out.len() != n {
        return Err(Error::Codec("payload too short".into()));
    }
    Ok(())
}

/// Effective bits/element of base-s packing (asymptotic, exact per word).
pub fn base_s_bits_per_element(s: usize) -> f64 {
    64.0 / digits_per_word(s) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn rand_indices(n: usize, s: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.below(s as u64) as u8).collect()
    }

    #[test]
    fn fixed_roundtrip_all_widths() {
        for bits in 1..=8u32 {
            let s = 1usize << bits;
            for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
                let idx = rand_indices(n, s, bits as u64 * 100 + n as u64);
                let packed = pack_fixed(&idx, bits);
                assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
                assert_eq!(unpack_fixed(&packed, n, bits).unwrap(), idx, "bits={bits} n={n}");
            }
        }
    }

    /// Word kernels vs the retained scalar reference: byte-for-byte, for
    /// every width, across group-boundary lengths (the big sweep lives in
    /// `rust/tests/codec_differential.rs`).
    #[test]
    fn word_kernels_match_scalar_reference() {
        for bits in 1..=8u32 {
            let s = 1usize << bits;
            for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 127, 128, 129, 500] {
                let idx = rand_indices(n, s, bits as u64 * 999 + n as u64);
                let mut word = vec![0xA5u8; 3];
                let mut scalar = vec![0xA5u8; 3];
                pack_fixed_into(&idx, bits, &mut word);
                pack_fixed_scalar_into(&idx, bits, &mut scalar);
                assert_eq!(word, scalar, "pack bits={bits} n={n}");
                let mut a = Vec::new();
                let mut b = Vec::new();
                unpack_fixed_into(&word[3..], n, bits, &mut a).unwrap();
                unpack_fixed_scalar_into(&word[3..], n, bits, &mut b).unwrap();
                assert_eq!(a, b, "unpack bits={bits} n={n}");
                assert_eq!(a, idx, "roundtrip bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fixed_unpack_rejects_short_payload() {
        let idx = rand_indices(100, 8, 3);
        let packed = pack_fixed(&idx, 3);
        let mut out = Vec::new();
        assert!(unpack_fixed_into(&packed[..packed.len() - 1], 100, 3, &mut out).is_err());
        assert!(unpack_fixed_scalar_into(&packed[..5], 100, 3, &mut out).is_err());
        assert!(unpack_fixed(&[], 1, 1).is_err());
        // exact payload still decodes
        assert_eq!(unpack_fixed(&packed, 100, 3).unwrap(), idx);
    }

    #[test]
    fn digits_per_word_known_values() {
        assert_eq!(digits_per_word(2), 64);
        assert_eq!(digits_per_word(3), 40); // 3^40 < 2^64 < 3^41
        assert_eq!(digits_per_word(5), 27);
        assert_eq!(digits_per_word(9), 20);
        assert_eq!(digits_per_word(16), 16);
        assert_eq!(digits_per_word(256), 8);
    }

    /// The reciprocal divmod must agree with hardware `/`/`%` for every
    /// radix and adversarial dividends (word-boundary values, near
    /// multiples, random u64s).
    #[test]
    fn reciprocal_divmod_exact() {
        for s in 2..=256usize {
            let r = Radix::new(s);
            let su = s as u64;
            let mut cases = vec![
                0u64,
                1,
                su - 1,
                su,
                su + 1,
                su * su,
                u64::MAX,
                u64::MAX - 1,
                u64::MAX / su,
                (u64::MAX / su) * su,
                (u64::MAX / su) * su - 1,
            ];
            for k in [1u32, 7, 31, 32, 33, 62, 63] {
                let p = 1u64 << k;
                cases.extend([p - 1, p, p + 1]);
            }
            let mut rng = Rng::seed_from(s as u64);
            cases.extend((0..64).map(|_| rng.next_u64()));
            for n in cases {
                assert_eq!(r.divmod(n), (n / su, n % su), "s={s} n={n}");
            }
        }
    }

    #[test]
    fn base_s_roundtrip() {
        for s in [2usize, 3, 5, 9, 17] {
            for n in [0usize, 1, 19, 20, 21, 40, 1000] {
                let idx = rand_indices(n, s, s as u64 * 1000 + n as u64);
                let packed = pack_base_s(&idx, s);
                assert_eq!(unpack_base_s(&packed, n, s).unwrap(), idx, "s={s} n={n}");
            }
        }
    }

    #[test]
    fn base_s_unpack_rejects_short_or_misaligned() {
        let idx = rand_indices(100, 5, 7);
        let packed = pack_base_s(&idx, 5);
        let mut out = Vec::new();
        // truncated to a non-word boundary
        assert!(unpack_base_s_into(&packed[..packed.len() - 3], 100, 5, &mut out).is_err());
        // truncated to a word boundary but still short
        assert!(unpack_base_s_into(&packed[..packed.len() - 8], 100, 5, &mut out).is_err());
        assert!(unpack_base_s_into(&[], 1, 5, &mut out).is_err());
        assert!(unpack_base_s_scalar_into(&packed[..8], 100, 5, &mut out).is_err());
        // exact payload still decodes, and extra trailing bytes are ignored
        assert!(unpack_base_s_into(&packed, 100, 5, &mut out).is_ok());
        assert_eq!(out, idx);
    }

    #[test]
    fn into_variants_append_and_reuse() {
        let idx = rand_indices(100, 5, 1);
        // append semantics for packers
        let mut out = vec![0xAAu8; 3];
        pack_base_s_into(&idx, 5, &mut out);
        assert_eq!(&out[..3], &[0xAA; 3]);
        assert_eq!(&out[3..], pack_base_s(&idx, 5).as_slice());
        let mut out2 = vec![0x55u8; 2];
        pack_fixed_into(&idx, 3, &mut out2);
        assert_eq!(&out2[..2], &[0x55; 2]);
        assert_eq!(&out2[2..], pack_fixed(&idx, 3).as_slice());
        // clear semantics for unpackers
        let packed = pack_base_s(&idx, 5);
        let mut scratch = vec![9u8; 7];
        unpack_base_s_into(&packed, idx.len(), 5, &mut scratch).unwrap();
        assert_eq!(scratch, idx);
        let packed_f = pack_fixed(&idx, 3);
        unpack_fixed_into(&packed_f, idx.len(), 3, &mut scratch).unwrap();
        assert_eq!(scratch, idx);
    }

    #[test]
    fn base_s_beats_fixed_for_non_powers() {
        // 3 levels: fixed = 2 bits, base-3 = 1.6 bits.
        assert!(base_s_bits_per_element(3) < 2.0);
        assert!((base_s_bits_per_element(3) - 1.6).abs() < 1e-9);
        // 9 levels: fixed = 4, base-9 = 3.2
        assert!((base_s_bits_per_element(9) - 3.2).abs() < 1e-9);
        // powers of two identical
        assert_eq!(base_s_bits_per_element(2), 1.0);
    }

    #[test]
    fn paper_compression_ratios() {
        // Paper Table 2: ×20.2 (3 lvls), ×13.8 (5 lvls), ×10.1 (9 lvls).
        // 32 / bits-per-element with base-s packing should land close.
        let r3 = 32.0 / base_s_bits_per_element(3);
        let r5 = 32.0 / base_s_bits_per_element(5);
        let r9 = 32.0 / base_s_bits_per_element(9);
        assert!((r3 - 20.0).abs() < 0.5, "r3={r3}");
        assert!((r5 - 13.5).abs() < 0.5, "r5={r5}");
        assert!((r9 - 10.0).abs() < 0.5, "r9={r9}");
    }
}
