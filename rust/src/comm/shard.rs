//! Sharded parameter-server substrate: the bucket-aligned shard
//! partition, the versioned wire frame that carries per-shard chunks, the
//! bounded-staleness accounting ([`StalenessStats`]), and the closed-form
//! critical-path models ([`sharded_time`], [`async_time`]) that mirror
//! [`super::ring::allreduce_time`] / [`super::hier::hier_time`].
//!
//! **Partition.** The flat gradient is cut into `S` contiguous,
//! bucket-aligned element ranges ([`shard_range`], the ring's
//! [`chunk_range`](super::ring::chunk_range) grid with `parts = S`), so a
//! worker's per-shard upload is a pure byte slice of its one encoded
//! gradient ([`crate::codec::slice_elements_into`]) — no per-shard
//! requantization, and shard `s` of every worker covers the identical
//! element range. Ranges are contiguous and increasing, so shard chunks
//! reassemble by concatenation in shard order.
//!
//! **Versioned frames.** Every framed message wraps its codec payload in
//! a fixed [`FRAME_HEADER_BYTES`]-byte frame carrying the round number, a
//! kind-dependent **slot** and the sender id. The frame is
//! topology-agnostic: for sharded-ps uploads/means the slot is the shard
//! id; for the streaming exchange ([`super::overlap`]) the slot is the
//! *section* index of a [`FrameKind::Section`] frame, whose payload is an
//! 8-byte little-endian `f64` readiness stamp followed by one standalone
//! codec message holding that section's elements. The round field is what
//! makes bounded staleness *checkable*: a worker at round `r` with window
//! `K` refuses any mean frame older than `r − K` (and, in the
//! deterministic schedule, any frame that is not exactly `r − K`).
//! Parsing is fully validated — truncated headers, bad
//! magic/version/kind bytes and payload-length lies all return `Err`,
//! never panic (same contract as [`crate::codec`]).
//!
//! **Staleness accounting.** [`StalenessStats`] is the per-round
//! applied-version age histogram kept by the coordinator inside
//! [`CommStats`](super::CommStats): warm rounds record `age = round −
//! applied_version` (exactly `K` under the deterministic schedule —
//! the structure also admits adaptive pulls), cold rounds (the first `K`
//! rounds, before any version is inside the window) are counted
//! separately, and `max_age` is the bound the staleness property test
//! asserts (`max_age ≤ K`).
//!
//! **Time models.** One synchronous sharded round costs the slowest
//! shard's star: `max_s [max_l uplink(chunk_s) + broadcast(chunk_s)]`
//! ([`sharded_time`]; with `S = 1` this is exactly the flat PS round).
//! With a staleness window `K`, up to `K + 1` rounds are in flight, so
//! per-round latency amortizes across the window while bandwidth does
//! not ([`async_time`]); `async_time(.., rounds, 0, ..)` reduces exactly
//! to `rounds · sharded_time(..)`. The executable collective
//! ([`super::async_ps`]) measures the same quantities with exact
//! per-frame byte accounting.

use std::ops::Range;

use super::link::Link;
use crate::error::{Error, Result};

// --------------------------------------------------------------------
// Shard partition
// --------------------------------------------------------------------

/// Element range owned by server shard `i` of `shards`, for a gradient of
/// `total` elements on the `bucket`-sized quantization grid. Delegates to
/// the ring's chunk grid: contiguous, increasing, bucket-aligned ranges
/// that cover `[0, total)` exactly.
pub fn shard_range(total: usize, bucket: usize, shards: usize, i: usize) -> Range<usize> {
    super::ring::chunk_range(total, bucket, shards, i)
}

// --------------------------------------------------------------------
// Versioned frames
// --------------------------------------------------------------------

/// Frame magic `"ORQF"` (little-endian).
pub const FRAME_MAGIC: u32 = 0x4651_524F;
/// Versioned-frame wire version.
pub const FRAME_VERSION: u8 = 1;
/// Fixed frame header size: magic u32, version u8, kind u8, slot u16,
/// sender u16, round u64, payload_len u32.
pub const FRAME_HEADER_BYTES: usize = 4 + 1 + 1 + 2 + 2 + 8 + 4;

/// What a versioned frame carries. The u16 slot field is kind-dependent:
/// a shard id for [`FrameKind::Upload`]/[`FrameKind::Mean`], a section
/// index for [`FrameKind::Section`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → shard: one encoded gradient chunk.
    Upload,
    /// Shard → worker: the mean of the shard's chunk — FP-encoded by
    /// default, or requantized once by the shard under
    /// `quantize_downlink` (the frame is kind-agnostic about the inner
    /// codec payload).
    Mean,
    /// Streaming exchange: one gradient *section*, pushed onto the wire
    /// the moment backward finishes it. The payload is an 8-byte LE
    /// `f64` readiness stamp (sim seconds since the round's backward
    /// started) followed by one standalone codec message — or a
    /// bucket-aligned slice of one, when the receiver partitions the
    /// section further (shard/chunk intersections).
    Section,
}

impl FrameKind {
    fn byte(self) -> u8 {
        match self {
            FrameKind::Upload => 0,
            FrameKind::Mean => 1,
            FrameKind::Section => 2,
        }
    }

    fn from_byte(b: u8) -> Result<FrameKind> {
        match b {
            0 => Ok(FrameKind::Upload),
            1 => Ok(FrameKind::Mean),
            2 => Ok(FrameKind::Section),
            other => Err(Error::Codec(format!("unknown frame kind {other}"))),
        }
    }
}

/// Parsed view of a versioned frame: header fields + payload slice (the
/// inner [`crate::codec`] message bytes).
#[derive(Debug)]
pub struct Frame<'a> {
    pub kind: FrameKind,
    pub slot: u16,
    pub sender: u16,
    pub round: u64,
    pub payload: &'a [u8],
}

/// Serialize a versioned frame into a reused buffer (cleared first).
pub fn encode_frame_into(
    kind: FrameKind,
    round: u64,
    slot: u16,
    sender: u16,
    payload: &[u8],
    out: &mut Vec<u8>,
) {
    out.clear();
    out.reserve(FRAME_HEADER_BYTES + payload.len());
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.push(FRAME_VERSION);
    out.push(kind.byte());
    out.extend_from_slice(&slot.to_le_bytes());
    out.extend_from_slice(&sender.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Start a frame in `out` (cleared): the header with a zero payload
/// length. Append the payload bytes directly behind it (e.g.
/// [`crate::codec::slice_elements_append`] — one copy, no intermediate
/// buffer), then call [`finish_frame`] to patch the length in.
pub fn begin_frame_into(kind: FrameKind, round: u64, slot: u16, sender: u16, out: &mut Vec<u8>) {
    encode_frame_into(kind, round, slot, sender, &[], out);
}

/// Patch the payload length of a frame started with [`begin_frame_into`]
/// after its payload has been appended.
pub fn finish_frame(out: &mut Vec<u8>) {
    debug_assert!(out.len() >= FRAME_HEADER_BYTES, "finish_frame needs a begun frame");
    let len = (out.len() - FRAME_HEADER_BYTES) as u32;
    out[18..22].copy_from_slice(&len.to_le_bytes());
}

/// Parse and fully validate a versioned frame. Truncated headers, wrong
/// magic/version, unknown kinds and payload-length lies are all `Err`.
pub fn parse_frame(bytes: &[u8]) -> Result<Frame<'_>> {
    if bytes.len() < FRAME_HEADER_BYTES {
        return Err(Error::Codec(format!(
            "truncated frame: {} bytes, header needs {FRAME_HEADER_BYTES}",
            bytes.len()
        )));
    }
    // The length check above guarantees every fixed-width slice below,
    // so these conversions are infallible.
    let magic = u32::from_le_bytes(bytes[0..4].try_into().expect("4-byte slice"));
    if magic != FRAME_MAGIC {
        return Err(Error::Codec(format!("bad frame magic {magic:#x}")));
    }
    let version = bytes[4];
    if version != FRAME_VERSION {
        return Err(Error::Codec(format!("unsupported frame version {version}")));
    }
    let kind = FrameKind::from_byte(bytes[5])?;
    let slot = u16::from_le_bytes(bytes[6..8].try_into().expect("2-byte slice"));
    let sender = u16::from_le_bytes(bytes[8..10].try_into().expect("2-byte slice"));
    let round = u64::from_le_bytes(bytes[10..18].try_into().expect("8-byte slice"));
    let payload_len = u32::from_le_bytes(bytes[18..22].try_into().expect("4-byte slice")) as usize;
    let payload = &bytes[FRAME_HEADER_BYTES..];
    if payload.len() != payload_len {
        return Err(Error::Codec(format!(
            "frame payload is {} bytes, header claims {payload_len}",
            payload.len()
        )));
    }
    Ok(Frame { kind, slot, sender, round, payload })
}

/// Prefix bytes of a [`FrameKind::Section`] payload: the `f64` readiness
/// stamp that rides in front of the section's codec message.
pub const SECTION_STAMP_BYTES: usize = 8;

/// Split a parsed [`FrameKind::Section`] payload into its readiness
/// stamp and the inner codec message bytes. The stamp must be finite and
/// non-negative (sim seconds since the round's backward started).
pub fn split_section_payload(payload: &[u8]) -> Result<(f64, &[u8])> {
    if payload.len() < SECTION_STAMP_BYTES {
        return Err(Error::Codec(format!(
            "section payload is {} bytes, stamp needs {SECTION_STAMP_BYTES}",
            payload.len()
        )));
    }
    let stamp = f64::from_le_bytes(payload[..SECTION_STAMP_BYTES].try_into().expect("8-byte slice"));
    if !stamp.is_finite() || stamp < 0.0 {
        return Err(Error::Codec(format!("bad section readiness stamp {stamp}")));
    }
    Ok((stamp, &payload[SECTION_STAMP_BYTES..]))
}

// --------------------------------------------------------------------
// Byte-budget framing overhead
// --------------------------------------------------------------------

/// Upper bound on the *framing* bytes a single full-gradient uplink
/// stream pays beyond one flat codec message, for the given topology and
/// streaming mode — the amount the trainer subtracts from `byte_budget`
/// before handing the remainder to the width allocator
/// ([`crate::quant::budget::allocate_widths`]), so the wire spend
/// *including every header* stays ≤ the configured budget.
///
/// A width-table message that is cut into `k` bucket-aligned pieces
/// (shard slices, ring chunks, streamed section frames) repeats the
/// codec header `k − 1` extra times; the per-bucket width sub-tables
/// concatenate to exactly the flat table, so they cost nothing extra.
/// Framed pieces additionally pay the versioned frame header and — for
/// [`FrameKind::Section`] — the readiness stamp. Pieces per stream:
///
/// * `ps` — flat: 1; streamed: one section frame per section;
/// * `sharded-ps` — one slice per shard, ×sections when streamed;
/// * `ring` — one requantized chunk per reduce-scatter hop
///   (`workers − 1`), ×sections when streamed;
/// * `hier` — intra-ring hops (`m − 1` for group size `m = workers /
///   groups`) plus the member→leader gather and the leader's star
///   uplink, ×sections for the hop-0 frames when streamed.
///
/// The bound is conservative (some hops ship fewer bytes than the full
/// stream share); budgeted runs may therefore undershoot, never
/// overshoot.
pub fn budget_frame_overhead(
    topology: super::Topology,
    workers: usize,
    groups: usize,
    shards: usize,
    sections: Option<usize>,
    scheme: &str,
) -> usize {
    use super::Topology;
    let hdr = crate::codec::header_bytes(scheme);
    let streamed = sections.is_some();
    let nsec = sections.unwrap_or(1).max(1);
    // Charge every counted frame the stamped size even where the stamp
    // is absent (sharded Upload frames) — conservative by design.
    let frame = FRAME_HEADER_BYTES + SECTION_STAMP_BYTES;
    let (pieces, frames) = match topology {
        Topology::Ps => (nsec, if streamed { nsec } else { 0 }),
        Topology::ShardedPs => {
            let k = nsec * shards.max(1);
            (k, k)
        }
        Topology::Ring => {
            let hops = workers.saturating_sub(1).max(1);
            (nsec * hops, if streamed { nsec } else { 0 })
        }
        Topology::Hier => {
            let m = (workers / groups.max(1)).max(1);
            // hop-0 pieces (sections when streamed) + remaining intra
            // hops + member→leader gather + leader star uplink
            let hop0 = if streamed { nsec } else { 1 };
            (hop0 + m.saturating_sub(2) + 2, if streamed { nsec } else { 0 })
        }
    };
    pieces.saturating_sub(1) * hdr + frames * frame
}

// --------------------------------------------------------------------
// Staleness accounting
// --------------------------------------------------------------------

/// Histogram buckets of [`StalenessStats::hist`]: ages `0..=7`, with the
/// last bucket absorbing everything older.
pub const STALENESS_HIST_BUCKETS: usize = 9;

/// Per-round applied-version age accounting for the sharded/async
/// parameter server (zero everywhere for the synchronous topologies).
///
/// `Copy` by design (a fixed-width inline histogram) so it rides inside
/// [`CommStats`](super::CommStats) without changing that struct's
/// by-value ergonomics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StalenessStats {
    /// Rounds served in total (warm + cold).
    pub rounds: u64,
    /// Rounds applied before any model version was inside the staleness
    /// window (the first `K` rounds of an async run).
    pub cold_rounds: u64,
    /// Largest observed `round − applied_version` age. The staleness
    /// bound property is `max_age ≤ K`.
    pub max_age: u64,
    /// Counts by age: `hist[a]` rounds applied a version `a` rounds old;
    /// the final bucket absorbs ages `≥ STALENESS_HIST_BUCKETS − 1`.
    pub hist: [u64; STALENESS_HIST_BUCKETS],
}

impl StalenessStats {
    /// Record one warm round that applied a version `age` rounds old.
    pub fn record(&mut self, age: u64) {
        self.rounds += 1;
        self.max_age = self.max_age.max(age);
        self.hist[(age as usize).min(STALENESS_HIST_BUCKETS - 1)] += 1;
    }

    /// Record one cold round (no version inside the window yet).
    pub fn record_cold(&mut self) {
        self.rounds += 1;
        self.cold_rounds += 1;
    }

    /// Warm rounds recorded in the age histogram.
    pub fn observed(&self) -> u64 {
        self.hist.iter().sum()
    }
}

// --------------------------------------------------------------------
// Closed-form cost models
// --------------------------------------------------------------------

/// Critical-path time of one *synchronous* sharded-ps round: `l` workers
/// upload one `up_bytes / shards` chunk to each of `shards` servers
/// concurrently, each shard broadcasts a `down_bytes / shards` mean
/// chunk; the round waits for the slowest shard. With equal chunks over a
/// homogeneous link this is `2·latency + (up + down)/S · 8/bw` — at
/// `shards == 1` exactly the flat parameter-server round
/// ([`super::ring::ps_time`]), and `S×` less bandwidth per endpoint
/// otherwise (the whole point of sharding the server). `down_bytes` is
/// whatever the downlink actually carries: the FP wire size by default,
/// or the quantized wire size under `quantize_downlink`.
pub fn sharded_time(
    link: &Link,
    _workers: usize,
    shards: usize,
    up_bytes: usize,
    down_bytes: usize,
) -> f64 {
    assert!(shards > 0);
    let up = up_bytes as f64 / shards as f64;
    let down = down_bytes as f64 / shards as f64;
    2.0 * link.latency_s + (up + down) * 8.0 / link.bandwidth_bps
}

/// Critical-path time of `rounds` sharded-ps rounds under a bounded
/// staleness window of `staleness` rounds: up to `staleness + 1` rounds
/// are in flight, so the per-round latency is paid once per window
/// (`ceil(rounds / (K+1))` barriers) while the bandwidth term — the
/// shards' serial service time — is paid in full. `staleness == 0`
/// reduces exactly to `rounds · sharded_time(..)`.
pub fn async_time(
    link: &Link,
    workers: usize,
    shards: usize,
    rounds: usize,
    staleness: usize,
    up_bytes: usize,
    down_bytes: usize,
) -> f64 {
    if rounds == 0 {
        return 0.0;
    }
    let per_round_bw =
        sharded_time(link, workers, shards, up_bytes, down_bytes) - 2.0 * link.latency_s;
    let barriers = rounds.div_ceil(staleness + 1);
    rounds as f64 * per_round_bw + barriers as f64 * 2.0 * link.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The budget overhead bound: exact hand-computed values for the flat
    /// cases and the streamed ≥ flat / more-pieces-costs-more shape.
    #[test]
    fn budget_overhead_bound_shapes() {
        use super::super::Topology;
        let hdr = crate::codec::header_bytes("orq-8"); // 20 + 5
        let frame = FRAME_HEADER_BYTES + SECTION_STAMP_BYTES;
        // flat PS: one message, no extra framing at all
        assert_eq!(budget_frame_overhead(Topology::Ps, 8, 1, 1, None, "orq-8"), 0);
        // flat ring with L workers: L − 1 pieces
        assert_eq!(
            budget_frame_overhead(Topology::Ring, 4, 1, 1, None, "orq-8"),
            2 * hdr
        );
        // flat sharded-ps: S framed slices
        assert_eq!(
            budget_frame_overhead(Topology::ShardedPs, 8, 1, 3, None, "orq-8"),
            2 * hdr + 3 * frame
        );
        // flat hier, 8 workers in 2 groups (m = 4): 1 + 2 + 2 pieces
        assert_eq!(
            budget_frame_overhead(Topology::Hier, 8, 2, 1, None, "orq-8"),
            4 * hdr
        );
        for topo in [Topology::Ps, Topology::Ring, Topology::Hier, Topology::ShardedPs] {
            let flat = budget_frame_overhead(topo, 8, 2, 2, None, "orq-8");
            let streamed = budget_frame_overhead(topo, 8, 2, 2, Some(4), "orq-8");
            assert!(streamed >= flat, "{topo}: streaming adds framing, never removes it");
            let more = budget_frame_overhead(topo, 8, 2, 2, Some(8), "orq-8");
            assert!(more >= streamed, "{topo}: more sections, more framing");
        }
    }

    #[test]
    fn shard_ranges_cover_and_align() {
        for (total, bucket, shards) in
            [(1000usize, 128usize, 4usize), (2048, 256, 7), (5, 2, 2), (4096, 512, 1)]
        {
            let mut covered = 0usize;
            for i in 0..shards {
                let r = shard_range(total, bucket, shards, i);
                assert_eq!(r.start, covered, "contiguous at {total}/{bucket}/{shards}");
                assert!(r.start % bucket == 0 || r.start == total, "aligned start");
                assert!(r.end % bucket == 0 || r.end == total, "aligned end");
                covered = r.end;
            }
            assert_eq!(covered, total, "full cover at {total}/{bucket}/{shards}");
        }
    }

    #[test]
    fn frame_roundtrip() {
        let payload = [7u8, 8, 9, 10, 11];
        let mut bytes = Vec::new();
        encode_frame_into(FrameKind::Upload, 42, 3, 17, &payload, &mut bytes);
        assert_eq!(bytes.len(), FRAME_HEADER_BYTES + payload.len());
        let f = parse_frame(&bytes).unwrap();
        assert_eq!(f.kind, FrameKind::Upload);
        assert_eq!(f.slot, 3);
        assert_eq!(f.sender, 17);
        assert_eq!(f.round, 42);
        assert_eq!(f.payload, &payload);
        // the mean kind and an empty payload round-trip too
        encode_frame_into(FrameKind::Mean, u64::MAX, 0, 0, &[], &mut bytes);
        let f = parse_frame(&bytes).unwrap();
        assert_eq!(f.kind, FrameKind::Mean);
        assert_eq!(f.round, u64::MAX);
        assert!(f.payload.is_empty());
    }

    /// Section frames (slot = section index, payload = stamp + inner
    /// message) round-trip, and the stamp splitter validates its prefix.
    #[test]
    fn section_frame_roundtrip_and_stamp_split() {
        let inner = [0xA0u8, 0xA1, 0xA2];
        let mut payload = 0.125f64.to_le_bytes().to_vec();
        payload.extend_from_slice(&inner);
        let mut bytes = Vec::new();
        encode_frame_into(FrameKind::Section, 7, 5, 2, &payload, &mut bytes);
        let f = parse_frame(&bytes).unwrap();
        assert_eq!(f.kind, FrameKind::Section);
        assert_eq!(f.slot, 5, "slot carries the section index");
        assert_eq!(f.sender, 2);
        let (stamp, msg) = split_section_payload(f.payload).unwrap();
        assert_eq!(stamp, 0.125);
        assert_eq!(msg, &inner);
        // a stamp-only payload splits to an empty message
        let (stamp, msg) = split_section_payload(&0.0f64.to_le_bytes()).unwrap();
        assert_eq!(stamp, 0.0);
        assert!(msg.is_empty());
    }

    /// Malformed section payloads are `Err`, never panic: every stamp
    /// truncation point and non-physical stamp values.
    #[test]
    fn malformed_section_payloads_rejected() {
        for n in 0..SECTION_STAMP_BYTES {
            let short = vec![0u8; n];
            assert!(split_section_payload(&short).is_err(), "stamp prefix {n} must not split");
        }
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            assert!(split_section_payload(&bad.to_le_bytes()).is_err(), "stamp {bad} rejected");
        }
    }

    /// Malformed versioned frames are rejected with `Err`, never panic:
    /// every truncation point, corrupted magic/version/kind bytes, and
    /// payload-length lies in both directions — exercised for both a
    /// mean frame and a section frame.
    #[test]
    fn malformed_frames_rejected() {
        let mut section = Vec::new();
        {
            let mut payload = 0.5f64.to_le_bytes().to_vec();
            payload.extend_from_slice(&[9, 9]);
            encode_frame_into(FrameKind::Section, 3, 0, 1, &payload, &mut section);
        }
        let mut bytes = Vec::new();
        encode_frame_into(FrameKind::Mean, 9, 1, 2, &[1, 2, 3, 4], &mut bytes);
        for frame in [&bytes, &section] {
            for n in 0..frame.len() {
                assert!(parse_frame(&frame[..n]).is_err(), "prefix {n} must not parse");
            }
        }
        // bad magic
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        assert!(parse_frame(&b).is_err());
        // bad version
        let mut b = bytes.clone();
        b[4] = 99;
        assert!(parse_frame(&b).is_err());
        // unknown kind (2 became Section; 3 is the first free byte)
        let mut b = bytes.clone();
        b[5] = 3;
        assert!(parse_frame(&b).is_err());
        // payload-length lies: claims more and less than present
        let mut b = bytes.clone();
        b[18] = 200;
        assert!(parse_frame(&b).is_err());
        let mut b = bytes.clone();
        b[18] = 1;
        assert!(parse_frame(&b).is_err());
        // trailing garbage breaks the exact-length contract
        let mut b = bytes.clone();
        b.push(0);
        assert!(parse_frame(&b).is_err());
        // the pristine frame still parses
        assert!(parse_frame(&bytes).is_ok());
    }

    /// A frame built incrementally (header first, payload appended, length
    /// patched) must be byte-identical to the one-shot encoder.
    #[test]
    fn begin_finish_frame_matches_one_shot() {
        let payload = [9u8, 8, 7, 6, 5, 4];
        let mut oneshot = Vec::new();
        encode_frame_into(FrameKind::Upload, 31, 4, 9, &payload, &mut oneshot);
        let mut staged = Vec::new();
        begin_frame_into(FrameKind::Upload, 31, 4, 9, &mut staged);
        staged.extend_from_slice(&payload);
        finish_frame(&mut staged);
        assert_eq!(staged, oneshot);
        let f = parse_frame(&staged).unwrap();
        assert_eq!(f.payload, &payload);
        // empty payload stays valid
        let mut empty = Vec::new();
        begin_frame_into(FrameKind::Mean, 0, 0, 0, &mut empty);
        finish_frame(&mut empty);
        assert!(parse_frame(&empty).is_ok());
    }

    #[test]
    fn staleness_stats_record_and_saturate() {
        let mut st = StalenessStats::default();
        st.record_cold();
        st.record(0);
        st.record(2);
        st.record(2);
        st.record(100); // saturates into the last bucket
        assert_eq!(st.rounds, 5);
        assert_eq!(st.cold_rounds, 1);
        assert_eq!(st.max_age, 100);
        assert_eq!(st.observed(), 4);
        assert_eq!(st.hist[0], 1);
        assert_eq!(st.hist[2], 2);
        assert_eq!(st.hist[STALENESS_HIST_BUCKETS - 1], 1);
    }

    #[test]
    fn sharded_time_degenerates_to_flat_ps_at_one_shard() {
        let link = Link::new(1e9, 0.002);
        let up = 1_000_000usize;
        let down = 4_000_000usize;
        let flat = super::super::ring::ps_time(&link, 4, up, down);
        assert!((sharded_time(&link, 4, 1, up, down) - flat).abs() < 1e-12);
        // S shards cut the bandwidth term by S while latency stays
        let t4 = sharded_time(&link, 4, 4, up, down);
        let bw = (up + down) as f64 * 8.0 / 1e9;
        assert!((t4 - (2.0 * 0.002 + bw / 4.0)).abs() < 1e-12);
    }

    #[test]
    fn async_time_amortizes_latency_only() {
        let link = Link::new(1e9, 0.010);
        let (l, s, up, down) = (4usize, 2usize, 1 << 20, 1 << 20);
        let rounds = 12;
        // K = 0 is exactly rounds × the synchronous round
        let sync = async_time(&link, l, s, rounds, 0, up, down);
        assert!((sync - rounds as f64 * sharded_time(&link, l, s, up, down)).abs() < 1e-12);
        // a window of K hides all but every (K+1)-th latency barrier,
        // leaving the bandwidth term untouched
        let k3 = async_time(&link, l, s, rounds, 3, up, down);
        let bw_term = sync - rounds as f64 * 2.0 * link.latency_s;
        assert!((k3 - (bw_term + 3.0 * 2.0 * link.latency_s)).abs() < 1e-12);
        assert!(k3 < sync);
        // zero rounds cost nothing
        assert_eq!(async_time(&link, l, s, 0, 3, up, down), 0.0);
    }
}
