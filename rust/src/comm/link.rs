//! Link model: `time = latency + bytes / bandwidth` with exact byte
//! accounting — the substrate behind Table 1's "Comm Time" column.

/// A simulated network link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Bandwidth in bits per second (paper: 10 Gbps).
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

impl Link {
    /// The paper's Table 1 testbed: 10 Gbps, zero modeled latency.
    pub fn ten_gbps() -> Self {
        Link { bandwidth_bps: 10e9, latency_s: 0.0 }
    }

    /// A federated-edge-like uplink (25 Mbps, 20 ms) for the motivation
    /// scenarios in §1.
    pub fn edge_uplink() -> Self {
        Link { bandwidth_bps: 25e6, latency_s: 0.020 }
    }

    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        Link { bandwidth_bps, latency_s }
    }

    /// Time to push `bytes` through this link, seconds.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Running account of simulated traffic over one link.
#[derive(Debug, Clone, Default)]
pub struct TrafficMeter {
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub time_s: f64,
    pub messages: u64,
}

impl TrafficMeter {
    pub fn record_up(&mut self, link: &Link, bytes: usize) {
        self.bytes_up += bytes as u64;
        self.time_s += link.transfer_time(bytes);
        self.messages += 1;
    }

    pub fn record_down(&mut self, link: &Link, bytes: usize) {
        self.bytes_down += bytes as u64;
        self.time_s += link.transfer_time(bytes);
        self.messages += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table1_comm_times() {
        // Table 1: time to transmit one FP32 gradient at 10 Gbps.
        // AlexNet 61.1M -> 195 ms; ResNet-50 25.6M -> 82 ms, etc.
        let link = Link::ten_gbps();
        let cases: [(f64, f64); 5] = [
            (61.1e6, 0.195),  // AlexNet
            (143.7e6, 0.460), // VGG-19
            (28.7e6, 0.092),  // DenseNet-161
            (13.0e6, 0.044),  // GoogLeNet
            (25.6e6, 0.082),  // ResNet-50
        ];
        for (params, expect_s) in cases {
            let t = link.transfer_time((params * 4.0) as usize);
            assert!(
                (t - expect_s).abs() / expect_s < 0.07,
                "params={params}: {t}s vs paper {expect_s}s"
            );
        }
    }

    #[test]
    fn latency_additive() {
        let link = Link::new(1e9, 0.010);
        assert!((link.transfer_time(0) - 0.010).abs() < 1e-12);
        let t = link.transfer_time(1_000_000); // 8 Mbit / 1 Gbps = 8 ms
        assert!((t - 0.018).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn meter_accumulates() {
        let link = Link::ten_gbps();
        let mut m = TrafficMeter::default();
        m.record_up(&link, 1000);
        m.record_down(&link, 500);
        assert_eq!(m.total_bytes(), 1500);
        assert_eq!(m.messages, 2);
        assert!(m.time_s > 0.0);
    }
}
