//! Link model: `time = latency + bytes / bandwidth` with exact byte
//! accounting — the substrate behind Table 1's "Comm Time" column.
//!
//! Two layers:
//! * [`Link`] — one point-to-point link (bandwidth + one-way latency);
//! * [`LinkMap`] — the per-edge-class generalization: every edge of a
//!   topology is either *intra-group* (fast, rack-local) or *inter-group*
//!   (slow, cross-rack). Flat topologies (PS star, ring) treat every
//!   worker as its own group, so all of their edges are inter-class; the
//!   hierarchical collective localizes most traffic onto intra edges,
//!   which is exactly the TernGrad/§1 motivation for compressing harder
//!   on slow inter-node links.

/// A simulated network link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Bandwidth in bits per second (paper: 10 Gbps).
    pub bandwidth_bps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

impl Link {
    /// The paper's Table 1 testbed: 10 Gbps, zero modeled latency.
    pub fn ten_gbps() -> Self {
        Link { bandwidth_bps: 10e9, latency_s: 0.0 }
    }

    /// A federated-edge-like uplink (25 Mbps, 20 ms) for the motivation
    /// scenarios in §1.
    pub fn edge_uplink() -> Self {
        Link { bandwidth_bps: 25e6, latency_s: 0.020 }
    }

    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        Link { bandwidth_bps, latency_s }
    }

    /// Time to push `bytes` through this link, seconds.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Which class of edge a transfer crosses in the (possibly hierarchical)
/// cluster graph. Flat topologies have only [`EdgeClass::Inter`] edges
/// (every worker is its own group); the hierarchical collective uses
/// [`EdgeClass::Intra`] for in-group hops and [`EdgeClass::Inter`] for the
/// leader star.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeClass {
    /// Within an aggregation group (fast, e.g. NVLink/rack-local).
    Intra,
    /// Between groups / across the central aggregation boundary (slow).
    Inter,
}

/// Per-edge-class link model: one [`Link`] per [`EdgeClass`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkMap {
    pub intra: Link,
    pub inter: Link,
}

impl LinkMap {
    /// Homogeneous cluster: the same link everywhere (the paper's Table 1
    /// testbed when built from [`Link::ten_gbps`]).
    pub fn uniform(link: Link) -> Self {
        LinkMap { intra: link, inter: link }
    }

    pub fn new(intra: Link, inter: Link) -> Self {
        LinkMap { intra, inter }
    }

    pub fn link(&self, class: EdgeClass) -> &Link {
        match class {
            EdgeClass::Intra => &self.intra,
            EdgeClass::Inter => &self.inter,
        }
    }

    /// Time to push `bytes` over one edge of the given class, seconds.
    pub fn transfer_time(&self, class: EdgeClass, bytes: usize) -> f64 {
        self.link(class).transfer_time(bytes)
    }
}

/// Running account of simulated traffic over one link.
#[derive(Debug, Clone, Default)]
pub struct TrafficMeter {
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub time_s: f64,
    pub messages: u64,
}

impl TrafficMeter {
    pub fn record_up(&mut self, link: &Link, bytes: usize) {
        self.bytes_up += bytes as u64;
        self.time_s += link.transfer_time(bytes);
        self.messages += 1;
    }

    pub fn record_down(&mut self, link: &Link, bytes: usize) {
        self.bytes_down += bytes as u64;
        self.time_s += link.transfer_time(bytes);
        self.messages += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table1_comm_times() {
        // Table 1: time to transmit one FP32 gradient at 10 Gbps.
        // AlexNet 61.1M -> 195 ms; ResNet-50 25.6M -> 82 ms, etc.
        let link = Link::ten_gbps();
        let cases: [(f64, f64); 5] = [
            (61.1e6, 0.195),  // AlexNet
            (143.7e6, 0.460), // VGG-19
            (28.7e6, 0.092),  // DenseNet-161
            (13.0e6, 0.044),  // GoogLeNet
            (25.6e6, 0.082),  // ResNet-50
        ];
        for (params, expect_s) in cases {
            let t = link.transfer_time((params * 4.0) as usize);
            assert!(
                (t - expect_s).abs() / expect_s < 0.07,
                "params={params}: {t}s vs paper {expect_s}s"
            );
        }
    }

    #[test]
    fn latency_additive() {
        let link = Link::new(1e9, 0.010);
        assert!((link.transfer_time(0) - 0.010).abs() < 1e-12);
        let t = link.transfer_time(1_000_000); // 8 Mbit / 1 Gbps = 8 ms
        assert!((t - 0.018).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn link_map_routes_by_class() {
        let fast = Link::new(100e9, 0.0);
        let slow = Link::new(1e9, 0.010);
        let m = LinkMap::new(fast, slow);
        assert_eq!(*m.link(EdgeClass::Intra), fast);
        assert_eq!(*m.link(EdgeClass::Inter), slow);
        let b = 1_000_000usize; // 8 Mbit
        assert!((m.transfer_time(EdgeClass::Intra, b) - 8e-5).abs() < 1e-12);
        assert!((m.transfer_time(EdgeClass::Inter, b) - 0.018).abs() < 1e-9);
        let u = LinkMap::uniform(fast);
        assert_eq!(u.intra, u.inter);
    }

    #[test]
    fn meter_accumulates() {
        let link = Link::ten_gbps();
        let mut m = TrafficMeter::default();
        m.record_up(&link, 1000);
        m.record_down(&link, 500);
        assert_eq!(m.total_bytes(), 1500);
        assert_eq!(m.messages, 2);
        assert!(m.time_s > 0.0);
    }
}
