//! Synchronous parameter-server exchange (paper Algorithm 2) over real
//! `std::sync::mpsc` channels, with simulated-time accounting.
//!
//! Topology: L workers ⇄ 1 server. Each round every worker uploads its
//! encoded gradient; the server aggregates and broadcasts one message to
//! every worker. Wall-clock never sleeps — the round's *simulated* time is
//! `max_l(uplink_l) + broadcast` (synchronous SGD critical path).
//!
//! [`ParameterServer`]/[`WorkerHandle`] are the raw channel star;
//! [`PsCollective`]/[`PsWorker`] wrap them into the topology-agnostic
//! [`Collective`]/[`WorkerExchange`] interface the trainer runs on.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::collective::{Collective, CommStats, GradCodec, WireSpec, WorkerExchange};
use super::link::{Link, LinkMap, TrafficMeter};
use crate::codec::{self, DecodeScratch};
use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::error_feedback::ErrorFeedback;
use crate::quant::parallel::BucketPipeline;
use crate::tensor::rng::Rng;

/// Message from a worker: (worker id, encoded gradient bytes).
type Upload = (usize, Vec<u8>);

/// The server's end of the topology.
pub struct ParameterServer {
    link: Link,
    uplink_rx: Receiver<Upload>,
    downlinks: Vec<Sender<Vec<u8>>>,
    pub meter: TrafficMeter,
    /// Simulated seconds spent in communication so far.
    pub sim_time_s: f64,
}

/// A worker's end of the topology.
pub struct WorkerHandle {
    pub id: usize,
    uplink_tx: Sender<Upload>,
    downlink_rx: Receiver<Vec<u8>>,
}

impl ParameterServer {
    /// Build the star topology; returns the server and the L worker handles.
    pub fn new(num_workers: usize, link: Link) -> (ParameterServer, Vec<WorkerHandle>) {
        assert!(num_workers > 0);
        let (uplink_tx, uplink_rx) = channel::<Upload>();
        let mut downlinks = Vec::with_capacity(num_workers);
        let mut handles = Vec::with_capacity(num_workers);
        for id in 0..num_workers {
            let (dtx, drx) = channel::<Vec<u8>>();
            downlinks.push(dtx);
            handles.push(WorkerHandle { id, uplink_tx: uplink_tx.clone(), downlink_rx: drx });
        }
        (
            ParameterServer {
                link,
                uplink_rx,
                downlinks,
                meter: TrafficMeter::default(),
                sim_time_s: 0.0,
            },
            handles,
        )
    }

    pub fn num_workers(&self) -> usize {
        self.downlinks.len()
    }

    /// Collect exactly one upload from every worker (any arrival order).
    /// Advances simulated time by the slowest uplink (synchronous barrier).
    pub fn gather(&mut self) -> Result<Vec<Vec<u8>>> {
        let n = self.num_workers();
        let mut slots: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
        let mut max_uplink = 0.0f64;
        for _ in 0..n {
            let (id, bytes) = self
                .uplink_rx
                .recv()
                .map_err(|_| Error::Comm("worker channel closed mid-round".into()))?;
            if id >= n {
                return Err(Error::Comm(format!("unknown worker id {id}")));
            }
            if slots[id].is_some() {
                return Err(Error::Comm(format!("duplicate upload from worker {id}")));
            }
            max_uplink = max_uplink.max(self.link.transfer_time(bytes.len()));
            self.meter.record_up(&self.link, bytes.len());
            slots[id] = Some(bytes);
        }
        self.sim_time_s += max_uplink;
        // Infallible: the loop above filled all n slots (duplicates and
        // unknown ids were rejected), so every slot is Some.
        Ok(slots.into_iter().map(|s| s.expect("one upload per worker")).collect())
    }

    /// Broadcast one message to every worker. Advances simulated time by a
    /// single transfer (tree/multicast assumption, same as the paper's
    /// "broadcast" step).
    pub fn broadcast(&mut self, bytes: &[u8]) -> Result<()> {
        for tx in &self.downlinks {
            tx.send(bytes.to_vec())
                .map_err(|_| Error::Comm("worker hung up before broadcast".into()))?;
        }
        self.meter.record_down(&self.link, bytes.len());
        self.sim_time_s += self.link.transfer_time(bytes.len());
        Ok(())
    }
}

impl WorkerHandle {
    /// Upload this round's encoded gradient.
    pub fn send_grad(&self, bytes: Vec<u8>) -> Result<()> {
        self.uplink_tx
            .send((self.id, bytes))
            .map_err(|_| Error::Comm("server hung up".into()))
    }

    /// Block for the server's broadcast.
    pub fn recv_broadcast(&self) -> Result<Vec<u8>> {
        self.downlink_rx
            .recv()
            .map_err(|_| Error::Comm("server hung up before broadcast".into()))
    }
}

/// [`Collective`] over the parameter-server star: gather L encoded
/// uploads, decode + average in f64, optionally requantize the downlink
/// (paper §4 option b), broadcast. All decode/aggregate scratch is reused
/// across rounds — the aggregation loop performs no per-bucket
/// allocation. With `WireSpec::threads != 1` the decode+reduce runs
/// through the parallel [`BucketPipeline`] (bit-identical sums, see
/// `quant::parallel`); `threads == 1` keeps the serial loop as the
/// retained baseline `perfbench` measures against.
pub struct PsCollective {
    server: ParameterServer,
    codec: GradCodec,
    quantize_downlink: bool,
    /// Server-side downlink residual (TernGrad-style bidirectional
    /// compression): with `error_feedback` and a lossy downlink, the mean
    /// is compensated by what previous broadcasts failed to carry.
    down_ef: Option<ErrorFeedback>,
    rng_down: Rng,
    acc: Vec<f64>,
    flat: Vec<f32>,
    msg: Vec<u8>,
    qg: QuantizedGrad,
    dscratch: DecodeScratch,
    pipeline: Option<BucketPipeline>,
}

impl PsCollective {
    /// Build over a per-edge-class link map. Every star edge crosses the
    /// central aggregation boundary, so the PS uses the *inter* link
    /// (flat topologies treat each worker as its own group).
    pub fn new(
        workers: usize,
        links: LinkMap,
        spec: &WireSpec,
        quantize_downlink: bool,
        error_feedback: bool,
    ) -> Result<(PsCollective, Vec<PsWorker>)> {
        if workers == 0 {
            // Same contract as RingAllReduce::new — Err, not the raw
            // ParameterServer::new assert.
            return Err(Error::InvalidArg("parameter server needs at least 1 worker".into()));
        }
        let codec = GradCodec::new(spec)?;
        let down_ef = (error_feedback && quantize_downlink && !codec.is_fp())
            .then(|| codec.error_feedback());
        let (server, handles) = ParameterServer::new(workers, links.inter);
        let ends = handles
            .into_iter()
            .map(|handle| PsWorker { handle, scratch: DecodeScratch::default() })
            .collect();
        Ok((
            PsCollective {
                server,
                codec,
                quantize_downlink,
                down_ef,
                rng_down: Rng::stream(spec.seed, 3_000),
                acc: Vec::new(),
                flat: Vec::new(),
                msg: Vec::new(),
                qg: QuantizedGrad::default(),
                dscratch: DecodeScratch::default(),
                // Same construction rule as the worker codecs: pooled by
                // default (spec.pool), scoped as the retained baseline.
                pipeline: spec.build_pipeline(),
            },
            ends,
        ))
    }
}

impl Collective for PsCollective {
    fn num_workers(&self) -> usize {
        self.server.num_workers()
    }

    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let uploads = self.server.gather()?;
        match &mut self.pipeline {
            Some(pipe) => pipe.decode_reduce_into(&uploads, &mut self.acc)?,
            None => {
                // Serial baseline: decode each upload, add element-wise.
                self.acc.clear();
                let mut expect: Option<usize> = None;
                for u in &uploads {
                    codec::decode_flat_into(u, &mut self.flat, &mut self.dscratch)?;
                    match expect {
                        None => {
                            expect = Some(self.flat.len());
                            self.acc.resize(self.flat.len(), 0.0);
                        }
                        Some(n) if n != self.flat.len() => {
                            return Err(Error::Shape(format!(
                                "worker gradient has {} elements, expected {n}",
                                self.flat.len()
                            )))
                        }
                        Some(_) => {}
                    }
                    for (a, v) in self.acc.iter_mut().zip(&self.flat) {
                        *a += *v as f64;
                    }
                }
            }
        }
        let inv = 1.0 / uploads.len() as f64;
        mean_out.clear();
        mean_out.extend(self.acc.iter().map(|a| (*a * inv) as f32));
        if self.quantize_downlink && !self.codec.is_fp() && !mean_out.is_empty() {
            // Lossy downlink: every node (this coordinator included) must
            // apply the *decoded broadcast*, not the exact mean, to stay
            // bit-identical with the workers. With EF on, the server
            // compensates the mean with its own downlink residual first.
            match &mut self.down_ef {
                Some(ef) => self.codec.encode_ef_into(
                    ef,
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
                None => self.codec.encode_into(
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
            }
            self.server.broadcast(&self.msg)?;
            codec::decode_flat_into(&self.msg, mean_out, &mut self.dscratch)?;
        } else {
            codec::encode_fp_into(mean_out, &mut self.msg);
            self.server.broadcast(&self.msg)?;
        }
        Ok(())
    }

    fn stats(&self) -> CommStats {
        CommStats {
            wire_bytes: self.server.meter.total_bytes(),
            wire_bytes_intra: 0,
            wire_bytes_inter: self.server.meter.total_bytes(),
            wire_bytes_up: self.server.meter.bytes_up,
            wire_bytes_down: self.server.meter.bytes_down,
            sim_time_s: self.server.sim_time_s,
            messages: self.server.meter.messages,
            staleness: Default::default(),
        }
    }
}

/// Worker end of [`PsCollective`]: upload, block for the broadcast,
/// decode it through a reused scratch.
pub struct PsWorker {
    handle: WorkerHandle,
    scratch: DecodeScratch,
}

impl WorkerExchange for PsWorker {
    fn id(&self) -> usize {
        self.handle.id
    }

    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()> {
        self.handle.send_grad(std::mem::take(encoded))?;
        let bcast = self.handle.recv_broadcast()?;
        codec::decode_flat_into(&bcast, mean_out, &mut self.scratch)?;
        // Recycle the broadcast allocation as the caller's next encode
        // buffer (the upload Vec was handed to the channel above) — keeps
        // the PS round free of full-gradient reallocations, like the ring.
        *encoded = bcast;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_in_process() {
        let (mut srv, workers) = ParameterServer::new(3, Link::ten_gbps());
        for w in &workers {
            w.send_grad(vec![w.id as u8; 100]).unwrap();
        }
        let uploads = srv.gather().unwrap();
        assert_eq!(uploads.len(), 3);
        for (i, u) in uploads.iter().enumerate() {
            assert_eq!(u[0] as usize, i, "uploads ordered by worker id");
        }
        srv.broadcast(&[9, 9]).unwrap();
        for w in &workers {
            assert_eq!(w.recv_broadcast().unwrap(), vec![9, 9]);
        }
        assert_eq!(srv.meter.messages, 4);
        assert_eq!(srv.meter.bytes_up, 300);
        assert_eq!(srv.meter.bytes_down, 2);
    }

    #[test]
    fn multi_threaded_round() {
        let (mut srv, workers) = ParameterServer::new(4, Link::ten_gbps());
        let threads: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    w.send_grad(vec![w.id as u8; 10 * (w.id + 1)]).unwrap();
                    w.recv_broadcast().unwrap()
                })
            })
            .collect();
        let uploads = srv.gather().unwrap();
        assert_eq!(uploads[3].len(), 40);
        srv.broadcast(&[7]).unwrap();
        for t in threads {
            assert_eq!(t.join().unwrap(), vec![7]);
        }
    }

    #[test]
    fn sim_time_is_critical_path() {
        let link = Link::new(8e6, 0.0); // 1 MB/s
        let (mut srv, workers) = ParameterServer::new(2, link);
        workers[0].send_grad(vec![0; 1_000_000]).unwrap(); // 1 s
        workers[1].send_grad(vec![0; 500_000]).unwrap(); // 0.5 s
        srv.gather().unwrap();
        assert!((srv.sim_time_s - 1.0).abs() < 1e-9, "slowest uplink wins");
        srv.broadcast(&vec![0; 2_000_000]).unwrap(); // +2 s
        assert!((srv.sim_time_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_upload_rejected() {
        let (mut srv, workers) = ParameterServer::new(2, Link::ten_gbps());
        workers[0].send_grad(vec![1]).unwrap();
        workers[0].send_grad(vec![2]).unwrap();
        assert!(srv.gather().is_err());
    }

    #[test]
    fn closed_channel_errors() {
        let (mut srv, workers) = ParameterServer::new(1, Link::ten_gbps());
        drop(workers);
        assert!(srv.gather().is_err());
    }
}
