//! Synchronous parameter-server exchange (paper Algorithm 2) over real
//! `std::sync::mpsc` channels, with simulated-time accounting.
//!
//! Topology: L workers ⇄ 1 server. Each round every worker uploads its
//! encoded gradient; the server aggregates and broadcasts one message to
//! every worker. Wall-clock never sleeps — the round's *simulated* time is
//! `max_l(uplink_l) + broadcast` (synchronous SGD critical path).
//!
//! In streaming mode ([`ExchangeConfig::with_streaming`]
//! [`super::collective::ExchangeConfig::with_streaming`]) workers push
//! one [`FrameKind::Section`] frame per overlap section as backward
//! stages it; the server reduces the frames incrementally — per section,
//! in worker order, in f64 — so the mean stays bit-identical to the flat
//! path, while the simulated uplink runs the pipeline recurrence
//! `end = max(end, ready) + transfer(frame)` from the frames' in-band
//! readiness stamps.
//!
//! [`ParameterServer`]/[`WorkerHandle`] are the raw channel star;
//! [`PsCollective`]/[`PsWorker`] wrap them into the topology-agnostic
//! [`Collective`]/[`WorkerExchange`] interface the trainer runs on.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::collective::{Collective, CommStats, GradCodec, WireSpec, WorkerExchange};
use super::link::{Link, LinkMap, TrafficMeter};
use super::shard::{
    begin_frame_into, finish_frame, parse_frame, split_section_payload, FrameKind,
    FRAME_HEADER_BYTES, SECTION_STAMP_BYTES,
};
use crate::codec::{self, DecodeScratch};
use crate::error::{Error, Result};
use crate::obs::{TraceRecorder as Recorder, Track};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::error_feedback::ErrorFeedback;
use crate::quant::parallel::BucketPipeline;
use crate::tensor::rng::Rng;

/// Byte offset of a section frame's inner codec message: frame header,
/// then the f64 readiness stamp, then the standalone message.
pub(crate) const SECTION_MSG_OFFSET: usize = FRAME_HEADER_BYTES + SECTION_STAMP_BYTES;

/// Message from a worker: (worker id, encoded gradient bytes).
type Upload = (usize, Vec<u8>);

/// The server's end of the topology.
pub struct ParameterServer {
    link: Link,
    uplink_rx: Receiver<Upload>,
    downlinks: Vec<Sender<Vec<u8>>>,
    pub meter: TrafficMeter,
    /// Simulated seconds spent in communication so far.
    pub sim_time_s: f64,
    /// The gather leg of the most recent round (slowest uplink on flat
    /// rounds, slowest worker's pipeline recurrence on streamed ones) —
    /// what the drift accounting compares against the closed-form model.
    pub(crate) last_gather_s: f64,
    /// Span recorder ([`crate::obs`]); disabled by default.
    pub(crate) recorder: Recorder,
}

/// A worker's end of the topology.
pub struct WorkerHandle {
    pub id: usize,
    uplink_tx: Sender<Upload>,
    downlink_rx: Receiver<Vec<u8>>,
}

impl ParameterServer {
    /// Build the star topology; returns the server and the L worker handles.
    pub fn new(num_workers: usize, link: Link) -> (ParameterServer, Vec<WorkerHandle>) {
        assert!(num_workers > 0);
        let (uplink_tx, uplink_rx) = channel::<Upload>();
        let mut downlinks = Vec::with_capacity(num_workers);
        let mut handles = Vec::with_capacity(num_workers);
        for id in 0..num_workers {
            let (dtx, drx) = channel::<Vec<u8>>();
            downlinks.push(dtx);
            handles.push(WorkerHandle { id, uplink_tx: uplink_tx.clone(), downlink_rx: drx });
        }
        (
            ParameterServer {
                link,
                uplink_rx,
                downlinks,
                meter: TrafficMeter::default(),
                sim_time_s: 0.0,
                last_gather_s: 0.0,
                recorder: Recorder::off(),
            },
            handles,
        )
    }

    pub fn num_workers(&self) -> usize {
        self.downlinks.len()
    }

    /// Collect exactly one upload from every worker (any arrival order).
    /// Advances simulated time by the slowest uplink (synchronous barrier).
    pub fn gather(&mut self) -> Result<Vec<Vec<u8>>> {
        let n = self.num_workers();
        let base = self.sim_time_s;
        let mut slots: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
        let mut max_uplink = 0.0f64;
        for _ in 0..n {
            let (id, bytes) = self
                .uplink_rx
                .recv()
                .map_err(|_| Error::Comm("worker channel closed mid-round".into()))?;
            if id >= n {
                return Err(Error::Comm(format!("unknown worker id {id}")));
            }
            if slots[id].is_some() {
                return Err(Error::Comm(format!("duplicate upload from worker {id}")));
            }
            let t = self.link.transfer_time(bytes.len());
            max_uplink = max_uplink.max(t);
            if self.recorder.is_fine() {
                let w = Track::Worker(id as u16);
                self.recorder.begin_sim(w, "uplink", base);
                self.recorder.end_sim(w, "uplink", base + t);
            }
            self.meter.record_up(&self.link, bytes.len());
            slots[id] = Some(bytes);
        }
        self.last_gather_s = max_uplink;
        self.sim_time_s += max_uplink;
        // Infallible: the loop above filled all n slots (duplicates and
        // unknown ids were rejected), so every slot is Some.
        Ok(slots.into_iter().map(|s| s.expect("one upload per worker")).collect())
    }

    /// The streamed twin of [`Self::gather`]: collect exactly `nsec`
    /// section frames from every worker (any cross-worker interleaving;
    /// each worker's own frames arrive in its send order, which mpsc
    /// preserves), validating frame kind, round, sender, section bounds,
    /// stamps and duplicates. Advances simulated time by the slowest
    /// worker's pipeline recurrence `end = max(end, ready) +
    /// transfer(frame)` over that worker's frames in arrival order —
    /// measured from the round's backward start, which is what lets a
    /// streamed round beat "backward end + flat exchange". Returns the
    /// raw frames indexed `worker * nsec + section`; the inner codec
    /// message of each starts at [`SECTION_MSG_OFFSET`].
    pub(crate) fn gather_sections(&mut self, nsec: usize, round: u64) -> Result<Vec<Vec<u8>>> {
        let l = self.num_workers();
        let base = self.sim_time_s;
        let mut slots: Vec<Option<Vec<u8>>> = (0..l * nsec).map(|_| None).collect();
        let mut ends = vec![0.0f64; l];
        for _ in 0..l * nsec {
            let (id, bytes) = self
                .uplink_rx
                .recv()
                .map_err(|_| Error::Comm("worker channel closed mid-round".into()))?;
            if id >= l {
                return Err(Error::Comm(format!("unknown worker id {id}")));
            }
            let (sec, ready) = {
                let f = parse_frame(&bytes)?;
                if f.kind != FrameKind::Section {
                    return Err(Error::Comm(format!(
                        "expected a section frame from worker {id}, got {:?}",
                        f.kind
                    )));
                }
                if f.round != round {
                    return Err(Error::Comm(format!(
                        "section frame for round {} from worker {id}, expected round {round}",
                        f.round
                    )));
                }
                if f.sender as usize != id {
                    return Err(Error::Comm(format!(
                        "frame sender {} does not match channel id {id}",
                        f.sender
                    )));
                }
                let sec = f.slot as usize;
                if sec >= nsec {
                    return Err(Error::Comm(format!(
                        "section {sec} out of range ({nsec} sections)"
                    )));
                }
                let (ready, _msg) = split_section_payload(f.payload)?;
                (sec, ready)
            };
            if slots[id * nsec + sec].is_some() {
                return Err(Error::Comm(format!(
                    "duplicate section {sec} from worker {id}"
                )));
            }
            let start = ends[id].max(ready);
            ends[id] = start + self.link.transfer_time(bytes.len());
            if self.recorder.is_fine() {
                // Instants, not spans: the sending worker thread may be
                // recording on its own track concurrently.
                let w = Track::Worker(id as u16);
                self.recorder.instant_sim(w, "section_ready", base + ready);
                self.recorder.instant_sim(w, "section_link_start", base + start);
                self.recorder.instant_sim(w, "section_link_done", base + ends[id]);
            }
            self.meter.record_up(&self.link, bytes.len());
            slots[id * nsec + sec] = Some(bytes);
        }
        self.last_gather_s = ends.iter().copied().fold(0.0, f64::max);
        self.sim_time_s += self.last_gather_s;
        Ok(slots
            .into_iter()
            .map(|s| s.expect("one frame per (worker, section)"))
            .collect())
    }

    /// Broadcast one message to every worker. Advances simulated time by a
    /// single transfer (tree/multicast assumption, same as the paper's
    /// "broadcast" step).
    pub fn broadcast(&mut self, bytes: &[u8]) -> Result<()> {
        for tx in &self.downlinks {
            tx.send(bytes.to_vec())
                .map_err(|_| Error::Comm("worker hung up before broadcast".into()))?;
        }
        self.meter.record_down(&self.link, bytes.len());
        let t = self.link.transfer_time(bytes.len());
        if self.recorder.is_fine() {
            self.recorder.begin_sim(Track::Coordinator, "broadcast", self.sim_time_s);
            self.recorder.end_sim(Track::Coordinator, "broadcast", self.sim_time_s + t);
        }
        self.sim_time_s += t;
        Ok(())
    }
}

impl WorkerHandle {
    /// Upload this round's encoded gradient.
    pub fn send_grad(&self, bytes: Vec<u8>) -> Result<()> {
        self.uplink_tx
            .send((self.id, bytes))
            .map_err(|_| Error::Comm("server hung up".into()))
    }

    /// Block for the server's broadcast.
    pub fn recv_broadcast(&self) -> Result<Vec<u8>> {
        self.downlink_rx
            .recv()
            .map_err(|_| Error::Comm("server hung up before broadcast".into()))
    }
}

/// [`Collective`] over the parameter-server star: gather L encoded
/// uploads, decode + average in f64, optionally requantize the downlink
/// (paper §4 option b), broadcast. All decode/aggregate scratch is reused
/// across rounds — the aggregation loop performs no per-bucket
/// allocation. With `WireSpec::threads != 1` the decode+reduce runs
/// through the parallel [`BucketPipeline`] (bit-identical sums, see
/// `quant::parallel`); `threads == 1` keeps the serial loop as the
/// retained baseline `perfbench` measures against.
pub struct PsCollective {
    server: ParameterServer,
    codec: GradCodec,
    quantize_downlink: bool,
    /// Server-side downlink residual (TernGrad-style bidirectional
    /// compression): with `error_feedback` and a lossy downlink, the mean
    /// is compensated by what previous broadcasts failed to carry.
    down_ef: Option<ErrorFeedback>,
    rng_down: Rng,
    acc: Vec<f64>,
    flat: Vec<f32>,
    msg: Vec<u8>,
    qg: QuantizedGrad,
    dscratch: DecodeScratch,
    pipeline: Option<BucketPipeline>,
    /// `Some(nsec)` = streamed rounds: expect `nsec` section frames per
    /// worker instead of one flat upload.
    streaming: Option<usize>,
    /// Round counter, validated against every section frame's round field.
    round: u64,
    recorder: Recorder,
    /// Closed-form model prediction accumulated alongside the simulated
    /// time (see [`CommStats::model_time_s`]).
    model_time_s: f64,
}

impl PsCollective {
    /// Build over a per-edge-class link map. Every star edge crosses the
    /// central aggregation boundary, so the PS uses the *inter* link
    /// (flat topologies treat each worker as its own group).
    pub fn new(
        workers: usize,
        links: LinkMap,
        spec: &WireSpec,
        quantize_downlink: bool,
        error_feedback: bool,
        streaming: Option<usize>,
    ) -> Result<(PsCollective, Vec<PsWorker>)> {
        if workers == 0 {
            // Same contract as RingAllReduce::new — Err, not the raw
            // ParameterServer::new assert.
            return Err(Error::InvalidArg("parameter server needs at least 1 worker".into()));
        }
        let codec = GradCodec::new(spec)?;
        let down_ef = (error_feedback && quantize_downlink && !codec.is_fp())
            .then(|| codec.error_feedback());
        let (mut server, handles) = ParameterServer::new(workers, links.inter);
        server.recorder = spec.recorder.clone();
        let ends = handles
            .into_iter()
            .map(|handle| PsWorker {
                handle,
                scratch: DecodeScratch::default(),
                streaming,
                round: 0,
            })
            .collect();
        Ok((
            PsCollective {
                server,
                codec,
                quantize_downlink,
                down_ef,
                rng_down: Rng::stream(spec.seed, 3_000),
                acc: Vec::new(),
                flat: Vec::new(),
                msg: Vec::new(),
                qg: QuantizedGrad::default(),
                dscratch: DecodeScratch::default(),
                // Same construction rule as the worker codecs: pooled by
                // default (spec.pool), scoped as the retained baseline.
                pipeline: spec.build_pipeline(),
                streaming,
                round: 0,
                recorder: spec.recorder.clone(),
                model_time_s: 0.0,
            },
            ends,
        ))
    }

    /// Reduce one streamed round's section frames: sections ascending,
    /// workers in id order within each section, summed in f64 — the same
    /// per-element accumulation order as the flat path, so the mean is
    /// bit-identical to it. Section lengths come from the frames' own
    /// codec headers and must agree across workers.
    fn reduce_sections(&mut self, frames: &[Vec<u8>], l: usize, nsec: usize) -> Result<()> {
        self.acc.clear();
        let mut offset = 0usize;
        for sec in 0..nsec {
            let mut sec_len: Option<usize> = None;
            for w in 0..l {
                let msg = &frames[w * nsec + sec][SECTION_MSG_OFFSET..];
                codec::decode_flat_into(msg, &mut self.flat, &mut self.dscratch)?;
                match sec_len {
                    None => {
                        sec_len = Some(self.flat.len());
                        self.acc.resize(offset + self.flat.len(), 0.0);
                    }
                    Some(n) if n != self.flat.len() => {
                        return Err(Error::Shape(format!(
                            "worker {w} sent {} elements for section {sec}, expected {n}",
                            self.flat.len()
                        )))
                    }
                    Some(_) => {}
                }
                for (a, v) in self.acc[offset..].iter_mut().zip(&self.flat) {
                    *a += *v as f64;
                }
            }
            offset += sec_len.unwrap_or(0);
        }
        Ok(())
    }
}

impl Collective for PsCollective {
    fn num_workers(&self) -> usize {
        self.server.num_workers()
    }

    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let l = self.server.num_workers();
        let rec = self.recorder.clone();
        let fine = rec.is_fine();
        // Flat rounds feed the closed-form star model the slowest upload;
        // streamed rounds replay the pipeline recurrence, which *is* the
        // streamed model, so the gather leg transfers over verbatim.
        let mut model_up = 0.0f64;
        match self.streaming {
            Some(nsec) => {
                if fine {
                    rec.begin(Track::Coordinator, "ps_gather");
                }
                let frames = self.server.gather_sections(nsec, self.round);
                if fine {
                    rec.end(Track::Coordinator, "ps_gather");
                }
                let frames = frames?;
                model_up = self.server.last_gather_s;
                if fine {
                    rec.begin(Track::Coordinator, "ps_reduce");
                }
                let red = self.reduce_sections(&frames, l, nsec);
                if fine {
                    rec.end(Track::Coordinator, "ps_reduce");
                }
                red?;
                self.round += 1;
            }
            None => {
                if fine {
                    rec.begin(Track::Coordinator, "ps_gather");
                }
                let uploads = self.server.gather();
                if fine {
                    rec.end(Track::Coordinator, "ps_gather");
                }
                let uploads = uploads?;
                let max_up = uploads.iter().map(Vec::len).max().unwrap_or(0);
                model_up = super::ring::ps_time(&self.server.link, l, max_up, 0);
                if fine {
                    rec.begin(Track::Coordinator, "ps_reduce");
                }
                match &mut self.pipeline {
                    Some(pipe) => pipe.decode_reduce_into(&uploads, &mut self.acc)?,
                    None => {
                        // Serial baseline: decode each upload, add element-wise.
                        self.acc.clear();
                        let mut expect: Option<usize> = None;
                        for u in &uploads {
                            codec::decode_flat_into(u, &mut self.flat, &mut self.dscratch)?;
                            match expect {
                                None => {
                                    expect = Some(self.flat.len());
                                    self.acc.resize(self.flat.len(), 0.0);
                                }
                                Some(n) if n != self.flat.len() => {
                                    return Err(Error::Shape(format!(
                                        "worker gradient has {} elements, expected {n}",
                                        self.flat.len()
                                    )))
                                }
                                Some(_) => {}
                            }
                            for (a, v) in self.acc.iter_mut().zip(&self.flat) {
                                *a += *v as f64;
                            }
                        }
                    }
                }
                if fine {
                    rec.end(Track::Coordinator, "ps_reduce");
                }
            }
        }
        let inv = 1.0 / l as f64;
        mean_out.clear();
        mean_out.extend(self.acc.iter().map(|a| (*a * inv) as f32));
        if self.quantize_downlink && !self.codec.is_fp() && !mean_out.is_empty() {
            // Lossy downlink: every node (this coordinator included) must
            // apply the *decoded broadcast*, not the exact mean, to stay
            // bit-identical with the workers. With EF on, the server
            // compensates the mean with its own downlink residual first.
            match &mut self.down_ef {
                Some(ef) => self.codec.encode_ef_into(
                    ef,
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
                None => self.codec.encode_into(
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
            }
            self.server.broadcast(&self.msg)?;
            codec::decode_flat_into(&self.msg, mean_out, &mut self.dscratch)?;
        } else {
            codec::encode_fp_into(mean_out, &mut self.msg);
            self.server.broadcast(&self.msg)?;
        }
        self.model_time_s += model_up + self.server.link.transfer_time(self.msg.len());
        Ok(())
    }

    fn stats(&self) -> CommStats {
        CommStats {
            wire_bytes: self.server.meter.total_bytes(),
            wire_bytes_intra: 0,
            wire_bytes_inter: self.server.meter.total_bytes(),
            wire_bytes_up: self.server.meter.bytes_up,
            wire_bytes_down: self.server.meter.bytes_down,
            sim_time_s: self.server.sim_time_s,
            model_time_s: self.model_time_s,
            messages: self.server.meter.messages,
            staleness: Default::default(),
        }
    }
}

/// Worker end of [`PsCollective`]: upload, block for the broadcast,
/// decode it through a reused scratch. In streaming mode the flat
/// [`WorkerExchange::exchange`] is refused and uploads go through
/// [`WorkerExchange::push_section`] as [`FrameKind::Section`] frames.
pub struct PsWorker {
    handle: WorkerHandle,
    scratch: DecodeScratch,
    streaming: Option<usize>,
    round: u64,
}

impl WorkerExchange for PsWorker {
    fn id(&self) -> usize {
        self.handle.id
    }

    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()> {
        if self.streaming.is_some() {
            return Err(Error::InvalidArg(
                "this PS exchange streams sections; use push_section/finish_streamed".into(),
            ));
        }
        self.handle.send_grad(std::mem::take(encoded))?;
        let bcast = self.handle.recv_broadcast()?;
        codec::decode_flat_into(&bcast, mean_out, &mut self.scratch)?;
        // Recycle the broadcast allocation as the caller's next encode
        // buffer (the upload Vec was handed to the channel above) — keeps
        // the PS round free of full-gradient reallocations, like the ring.
        *encoded = bcast;
        Ok(())
    }

    fn push_section(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this PS exchange was not built for streaming".into(),
            ));
        };
        if section >= nsec {
            return Err(Error::InvalidArg(format!(
                "section {section} out of range ({nsec} sections)"
            )));
        }
        if !ready_s.is_finite() || ready_s < 0.0 {
            return Err(Error::InvalidArg(format!(
                "readiness stamp must be finite and non-negative, got {ready_s}"
            )));
        }
        let mut buf =
            Vec::with_capacity(FRAME_HEADER_BYTES + SECTION_STAMP_BYTES + payload.len());
        begin_frame_into(
            FrameKind::Section,
            self.round,
            section as u16,
            self.handle.id as u16,
            &mut buf,
        );
        buf.extend_from_slice(&ready_s.to_le_bytes());
        buf.extend_from_slice(payload);
        finish_frame(&mut buf);
        self.handle.send_grad(buf)
    }

    fn finish_streamed(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        if self.streaming.is_none() {
            return Err(Error::InvalidArg(
                "this PS exchange was not built for streaming".into(),
            ));
        }
        let bcast = self.handle.recv_broadcast()?;
        codec::decode_flat_into(&bcast, mean_out, &mut self.scratch)?;
        self.round += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_round_in_process() {
        let (mut srv, workers) = ParameterServer::new(3, Link::ten_gbps());
        for w in &workers {
            w.send_grad(vec![w.id as u8; 100]).unwrap();
        }
        let uploads = srv.gather().unwrap();
        assert_eq!(uploads.len(), 3);
        for (i, u) in uploads.iter().enumerate() {
            assert_eq!(u[0] as usize, i, "uploads ordered by worker id");
        }
        srv.broadcast(&[9, 9]).unwrap();
        for w in &workers {
            assert_eq!(w.recv_broadcast().unwrap(), vec![9, 9]);
        }
        assert_eq!(srv.meter.messages, 4);
        assert_eq!(srv.meter.bytes_up, 300);
        assert_eq!(srv.meter.bytes_down, 2);
    }

    #[test]
    fn multi_threaded_round() {
        let (mut srv, workers) = ParameterServer::new(4, Link::ten_gbps());
        let threads: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    w.send_grad(vec![w.id as u8; 10 * (w.id + 1)]).unwrap();
                    w.recv_broadcast().unwrap()
                })
            })
            .collect();
        let uploads = srv.gather().unwrap();
        assert_eq!(uploads[3].len(), 40);
        srv.broadcast(&[7]).unwrap();
        for t in threads {
            assert_eq!(t.join().unwrap(), vec![7]);
        }
    }

    #[test]
    fn sim_time_is_critical_path() {
        let link = Link::new(8e6, 0.0); // 1 MB/s
        let (mut srv, workers) = ParameterServer::new(2, link);
        workers[0].send_grad(vec![0; 1_000_000]).unwrap(); // 1 s
        workers[1].send_grad(vec![0; 500_000]).unwrap(); // 0.5 s
        srv.gather().unwrap();
        assert!((srv.sim_time_s - 1.0).abs() < 1e-9, "slowest uplink wins");
        srv.broadcast(&vec![0; 2_000_000]).unwrap(); // +2 s
        assert!((srv.sim_time_s - 3.0).abs() < 1e-9);
    }

    #[test]
    fn duplicate_upload_rejected() {
        let (mut srv, workers) = ParameterServer::new(2, Link::ten_gbps());
        workers[0].send_grad(vec![1]).unwrap();
        workers[0].send_grad(vec![2]).unwrap();
        assert!(srv.gather().is_err());
    }

    #[test]
    fn closed_channel_errors() {
        let (mut srv, workers) = ParameterServer::new(1, Link::ten_gbps());
        drop(workers);
        assert!(srv.gather().is_err());
    }

    /// Build a raw section frame: header, f64 readiness stamp, message.
    fn section_frame(kind: FrameKind, round: u64, sec: u16, sender: u16, ready: f64, msg_len: usize) -> Vec<u8> {
        let mut payload = ready.to_le_bytes().to_vec();
        payload.extend(std::iter::repeat(0xA5u8).take(msg_len));
        let mut out = Vec::new();
        super::super::shard::encode_frame_into(kind, round, sec, sender, &payload, &mut out);
        out
    }

    #[test]
    fn gather_sections_validates_frames() {
        // Malformed frames: each case needs a fresh star since the gather
        // consumes the channel.
        let bad = [
            // Wrong kind.
            section_frame(FrameKind::Upload, 0, 0, 0, 0.0, 4),
            // Wrong round.
            section_frame(FrameKind::Section, 7, 0, 0, 0.0, 4),
            // Sender does not match channel id.
            section_frame(FrameKind::Section, 0, 0, 1, 0.0, 4),
            // Section out of range (1 section expected).
            section_frame(FrameKind::Section, 0, 1, 0, 0.0, 4),
            // Non-finite readiness stamp.
            section_frame(FrameKind::Section, 0, 0, 0, f64::NAN, 4),
        ];
        for frame in bad {
            let (mut srv, workers) = ParameterServer::new(1, Link::ten_gbps());
            workers[0].send_grad(frame).unwrap();
            assert!(srv.gather_sections(1, 0).is_err());
        }

        // Duplicate section.
        let (mut srv, workers) = ParameterServer::new(1, Link::ten_gbps());
        workers[0].send_grad(section_frame(FrameKind::Section, 0, 0, 0, 0.0, 4)).unwrap();
        workers[0].send_grad(section_frame(FrameKind::Section, 0, 0, 0, 0.0, 4)).unwrap();
        assert!(srv.gather_sections(2, 0).is_err());
    }

    #[test]
    fn gather_sections_sim_time_is_pipeline_recurrence() {
        let link = Link::new(8e6, 0.0); // 1 MB/s
        let (mut srv, workers) = ParameterServer::new(2, link);
        // Worker 0 streams two small frames gated on readiness: the second
        // frame's stamp dominates. Frame bytes = 30 + msg, so msg_len 970
        // makes each transfer exactly 1 ms.
        workers[0].send_grad(section_frame(FrameKind::Section, 0, 1, 0, 0.5, 970)).unwrap();
        workers[0].send_grad(section_frame(FrameKind::Section, 0, 0, 0, 1.0, 970)).unwrap();
        // Worker 1 is ready immediately but transfer-bound: 0.5 s per frame.
        workers[1].send_grad(section_frame(FrameKind::Section, 0, 1, 1, 0.0, 499_970)).unwrap();
        workers[1].send_grad(section_frame(FrameKind::Section, 0, 0, 1, 0.0, 499_970)).unwrap();
        let frames = srv.gather_sections(2, 0).unwrap();
        assert_eq!(frames.len(), 4);
        // Frames come back indexed worker*nsec+section regardless of send
        // order; the inner message starts at SECTION_MSG_OFFSET.
        assert_eq!(frames[0].len(), SECTION_MSG_OFFSET + 970);
        assert_eq!(frames[3].len(), SECTION_MSG_OFFSET + 499_970);
        // Worker 0: max(0+0, 0.5)+0.001 = 0.501; max(0.501, 1.0)+0.001 = 1.001.
        // Worker 1: 0.5 + 0.5 = 1.0. Round = slowest worker = 1.001 s.
        assert!((srv.sim_time_s - 1.001).abs() < 1e-9, "got {}", srv.sim_time_s);
    }
}
