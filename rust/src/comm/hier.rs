//! Hierarchical two-level collective: intra-group ring reduction over
//! fast links, a leader star over slow links, results broadcast back down.
//!
//! The ROADMAP's hierarchical/tree follow-up to PR 1, motivated by the
//! heterogeneous clusters of §1: TernGrad-style compression pays off
//! precisely on slow inter-node links, so the topology should localize as
//! much traffic as possible onto the fast intra-group edges. Workers are
//! partitioned into `groups` equal groups (`--groups N`, config
//! `groups = N`); a round runs four phases:
//!
//! 1. **Intra reduce-scatter** (fast [`EdgeClass::Intra`] edges): each
//!    group of m members runs the PR 1 ring reduce-scatter — `m−1` hops
//!    of decode → partial-reduce → requantize on the bucket-aligned chunk
//!    grid ([`super::ring::chunk_range`] with `parts = m`), first hop a
//!    byte slice of the original encoded gradient.
//! 2. **Gather** (intra): every member ships its completed group-sum
//!    chunk to the group leader (requantized, exactly like the ring's
//!    first all-gather hop); the leader assembles the decoded group sum.
//! 3. **Leader star** (slow [`EdgeClass::Inter`] edges): non-root leaders
//!    requantize their group sum and upload it to the root (worker 0);
//!    the root decodes, reduces every group sum in group order (f64),
//!    and multicasts the encoded global mean back to the leaders — FP by
//!    default, or requantized once at the root with `quantize_downlink`
//!    (paper §4 option b on the slow inter links).
//!    Single-member groups skip phases 1–2 and forward their *original*
//!    encoded gradient unchanged — with `groups == workers` the star
//!    degenerates to the parameter server with no extra quantization.
//! 4. **Intra broadcast** (intra): each leader re-multicasts the root's
//!    exact bytes to its members. Every node (the root included, which
//!    decodes its own message) decodes the same bytes, so the mean is
//!    bit-identical cluster-wide — the invariant that keeps parameter
//!    replicas in sync (same as PS and ring), lossless or not.
//!
//! **Per-hop error feedback.** With `error_feedback` on, every lossy
//! requantization site keeps its own [`ErrorFeedback`] residual — one per
//! intra reduce-scatter hop position, one for the member gather encode,
//! one for the leader uplink (tree-edge-local residuals: each site
//! compensates a different partial sum), and, combined with
//! `quantize_downlink`, one at the root for the mean downlink
//! (TernGrad-style bidirectional compression). Single-member-group
//! forwarding stays verbatim (nothing is requantized, so there is
//! nothing to compensate).
//!
//! **Codec threads.** Like the ring, every node's [`GradCodec`] honors
//! `WireSpec::threads` for its quantize/requantize work (parallel
//! per-bucket pipeline, deterministic and thread-count invariant).
//!
//! **Accounting.** Wire bytes are exact encoded sizes, kept per edge
//! class ([`crate::comm::CommStats::wire_bytes_intra`] /
//! [`wire_bytes_inter`](crate::comm::CommStats::wire_bytes_inter)).
//! Simulated time is the synchronous-step critical path over a fixed
//! global step grid of `m + 3` steps — `m−1` reduce-scatter steps, one
//! gather step, one inter uplink step, one inter multicast, one intra
//! multicast — where each step costs the max transfer over all nodes
//! transmitting in it (multicasts count once, the PS broadcast
//! convention). [`hier_time`] is the closed-form model the Table 1 bench
//! prints next to the measured rounds.
//!
//! **Streaming.** With `ExchangeConfig::with_streaming` the round's
//! first wire leg goes on the wire while backward still runs. For
//! `m > 1` each worker's hop-0 chunk slice is cut per overlap section
//! and shipped as [`FrameKind::Section`] frames the moment the section
//! is staged; the ring successor reassembles the flat chunk message
//! with [`codec::concat_messages_into`] (byte-identical to the flat
//! hop-0 slice), so hops 1…m−1, the gather, the star and the downlink
//! run the exact flat path — the cluster mean stays bit-identical to
//! the flat round. For `m == 1` the leaders stream whole-section frames
//! straight up the star and the root reassembles each group's original
//! message. The streamed leg's simulated cost replaces the flat step it
//! supersedes: the slowest worker's pipeline recurrence `end =
//! max(end, ready) + transfer(frame)` from the frames' in-band
//! readiness stamps (measured from the round's backward start).

use std::sync::mpsc::{channel, Receiver, Sender};

use super::collective::{
    collect_traces, Collective, CommStats, GradCodec, RoundTrace, WireSpec, WorkerExchange,
};
use super::link::{EdgeClass, LinkMap, TrafficMeter};
use super::ps::SECTION_MSG_OFFSET;
use super::ring::{chunk_range, ring_sub};
use super::shard::{begin_frame_into, finish_frame, parse_frame, split_section_payload, FrameKind};
use crate::codec;
use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::error_feedback::ErrorFeedback;
use crate::tensor::rng::Rng;

// --------------------------------------------------------------------
// Closed-form cost model (Table 1's modeled column)
// --------------------------------------------------------------------

/// Critical-path time of one hierarchical round: `l` workers in `groups`
/// groups, a quantized gradient of `quant_bytes` on the wire up, a mean
/// of `down_bytes` on the way down (the FP size by default, the
/// requantized size under `quantize_downlink`). Matches the executable
/// collective up to per-chunk header/level-table overhead (each hop
/// message is an independently headered chunk).
pub fn hier_time(
    links: &LinkMap,
    l: usize,
    groups: usize,
    quant_bytes: usize,
    down_bytes: usize,
) -> f64 {
    assert!(l > 0 && groups > 0 && l % groups == 0);
    let m = l / groups;
    if l == 1 {
        return 0.0;
    }
    let mut t = 0.0;
    if m > 1 {
        // m−1 reduce-scatter steps + 1 gather step, each one chunk of
        // quant_bytes / m on the fast links.
        let chunk = quant_bytes as f64 / m as f64;
        t += m as f64 * (links.intra.latency_s + chunk * 8.0 / links.intra.bandwidth_bps);
        // leader multicast of the mean into the group
        t += links.intra.transfer_time(down_bytes);
    }
    if groups > 1 {
        // slowest-of-(G−1) leader uplinks (all equal) + root multicast
        t += links.inter.transfer_time(quant_bytes);
        t += links.inter.transfer_time(down_bytes);
    }
    t
}

// --------------------------------------------------------------------
// Executable topology
// --------------------------------------------------------------------

/// Coordinator end: pure bookkeeping (per-edge-class bytes, critical-path
/// time) plus relaying the root's decoded mean. No gradient bytes flow
/// through it.
pub struct HierarchicalCollective {
    workers: usize,
    group_size: usize,
    links: LinkMap,
    /// `Some(nsec)` = streamed rounds: the first wire leg arrives as
    /// `nsec` per-worker section frames, accounted by recurrence.
    streaming: Option<usize>,
    trace_rx: Receiver<RoundTrace>,
    mean_rx: Receiver<Vec<f32>>,
    meter_intra: TrafficMeter,
    meter_inter: TrafficMeter,
    sim_time_s: f64,
    /// Closed-form [`hier_time`] accumulated per flat round for the obs
    /// drift section (the model prices the intra phase at `quant/m`
    /// chunks without per-chunk headers, so a small genuine error is
    /// expected). Streamed rounds mirror the executable recurrence —
    /// the streamed model *is* that recurrence (`hier_streamed_time`),
    /// so their drift measures accounting consistency.
    model_time_s: f64,
    recorder: crate::obs::TraceRecorder,
}

impl HierarchicalCollective {
    /// Build the two-level topology: `workers` must be a positive
    /// multiple of `groups`; group g is workers `[g·m, (g+1)·m)`, its
    /// leader the first of them, the global root worker 0.
    pub fn new(
        workers: usize,
        groups: usize,
        links: LinkMap,
        spec: &WireSpec,
        quantize_downlink: bool,
        error_feedback: bool,
        streaming: Option<usize>,
    ) -> Result<(HierarchicalCollective, Vec<HierWorker>)> {
        if workers == 0 {
            return Err(Error::InvalidArg("hier needs at least 1 worker".into()));
        }
        if groups == 0 || workers % groups != 0 {
            return Err(Error::InvalidArg(format!(
                "groups ({groups}) must be a positive divisor of the worker count ({workers})"
            )));
        }
        let probe = GradCodec::new(spec)?; // validate the quantizer name up front
        let lossy_ef = error_feedback && !probe.is_fp();
        let m = workers / groups;

        let (trace_tx, trace_rx) = channel::<RoundTrace>();
        let (mean_tx, mean_rx) = channel::<Vec<f32>>();

        // Intra ring edges: worker w → next member of its group.
        let mut ring_txs: Vec<Option<Sender<Vec<u8>>>> = Vec::with_capacity(workers);
        let mut ring_rxs: Vec<Option<Receiver<Vec<u8>>>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Vec<u8>>();
            ring_txs.push(Some(tx));
            ring_rxs.push(Some(rx));
        }
        // Gather channels: one per group, rx at the leader.
        let mut gather = Vec::with_capacity(groups);
        for _ in 0..groups {
            let (tx, rx) = channel::<(usize, Vec<u8>)>();
            gather.push((tx, Some(rx)));
        }
        // Leader star: uplink to the root + per-leader downlinks.
        let (up_tx, up_rx) = channel::<(usize, Vec<u8>)>();
        let mut up_rx = Some(up_rx);
        let mut down_txs: Vec<Sender<Vec<u8>>> = Vec::with_capacity(groups.saturating_sub(1));
        let mut down_rxs: Vec<Option<Receiver<Vec<u8>>>> =
            (0..workers).map(|_| None).collect();
        for g in 1..groups {
            let (tx, rx) = channel::<Vec<u8>>();
            down_txs.push(tx);
            down_rxs[g * m] = Some(rx);
        }
        // Intra broadcast: per-member channels held by the group leader.
        let mut bcast_txs: Vec<Vec<Sender<Vec<u8>>>> = (0..groups).map(|_| Vec::new()).collect();
        let mut bcast_rxs: Vec<Option<Receiver<Vec<u8>>>> =
            (0..workers).map(|_| None).collect();
        for g in 0..groups {
            for j in 1..m {
                let (tx, rx) = channel::<Vec<u8>>();
                bcast_txs[g].push(tx);
                bcast_rxs[g * m + j] = Some(rx);
            }
        }

        let mut ends = Vec::with_capacity(workers);
        for w in 0..workers {
            let g = w / m;
            let j = w % m;
            let codec = GradCodec::new(spec)?;
            // One residual per lossy requantization site this worker owns
            // (each site compensates a different signal): intra hop k,
            // the member gather encode, the leader uplink encode, and —
            // at the root, under quantize_downlink — the mean downlink.
            let hop_ef = if lossy_ef && m > 2 {
                (0..m - 2).map(|_| codec.error_feedback()).collect()
            } else {
                Vec::new()
            };
            let gather_ef = (lossy_ef && m > 1 && j != 0).then(|| codec.error_feedback());
            let up_ef = (lossy_ef && m > 1 && j == 0 && g != 0).then(|| codec.error_feedback());
            let down_ef =
                (lossy_ef && quantize_downlink && w == 0).then(|| codec.error_feedback());
            ends.push(HierWorker {
                id: w,
                workers,
                groups,
                group_size: m,
                group: g,
                member: j,
                ring_tx: ring_txs[g * m + (j + 1) % m].take().expect("edge assigned once"),
                ring_rx: ring_rxs[w].take().expect("inbox assigned once"),
                gather_tx: if j != 0 { Some(gather[g].0.clone()) } else { None },
                gather_rx: if j == 0 { gather[g].1.take() } else { None },
                up_tx: if j == 0 && g != 0 { Some(up_tx.clone()) } else { None },
                up_rx: if w == 0 { up_rx.take() } else { None },
                down_txs: if w == 0 { std::mem::take(&mut down_txs) } else { Vec::new() },
                down_rx: down_rxs[w].take(),
                bcast_txs: if j == 0 { std::mem::take(&mut bcast_txs[g]) } else { Vec::new() },
                bcast_rx: bcast_rxs[w].take(),
                trace_tx: trace_tx.clone(),
                mean_tx: if w == 0 { Some(mean_tx.clone()) } else { None },
                codec,
                hop_ef,
                gather_ef,
                up_ef,
                down_ef,
                quantize_downlink,
                rng: Rng::stream(spec.seed, 5_000 + w as u64),
                rng_down: Rng::stream(spec.seed, 6_000),
                own: Vec::new(),
                chunk: Vec::new(),
                group_sum: Vec::new(),
                chunk_filled: Vec::new(),
                acc: Vec::new(),
                slots: Vec::new(),
                slot_filled: Vec::new(),
                qg: QuantizedGrad::default(),
                msg: Vec::new(),
                step_bytes: Vec::new(),
                streaming,
                round: 0,
                sec_lens: Vec::new(),
                sec_bufs: Vec::new(),
                sec_ready: Vec::new(),
                sec_order: Vec::new(),
                stream_rows: Vec::new(),
                flat_msg: Vec::new(),
                last_msg_bytes: 0,
                wscratch: Vec::new(),
                wfull: Vec::new(),
                wfull_has: false,
            });
        }
        Ok((
            HierarchicalCollective {
                workers,
                group_size: m,
                links,
                streaming,
                trace_rx,
                mean_rx,
                meter_intra: TrafficMeter::default(),
                meter_inter: TrafficMeter::default(),
                sim_time_s: 0.0,
                model_time_s: 0.0,
                recorder: spec.recorder.clone(),
            },
            ends,
        ))
    }

    /// Edge class of global step `k` on the `m + 3` grid.
    fn step_class(&self, k: usize) -> EdgeClass {
        let m = self.group_size;
        if k < m {
            EdgeClass::Intra // reduce-scatter hops + gather
        } else if k < m + 2 {
            EdgeClass::Inter // leader uplink, root multicast
        } else {
            EdgeClass::Intra // leader multicast
        }
    }
}

impl Collective for HierarchicalCollective {
    fn num_workers(&self) -> usize {
        self.workers
    }

    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let l = self.workers;
        let steps = self.group_size + 3;
        let traces =
            collect_traces(&self.trace_rx, l, steps, self.streaming.unwrap_or(0), "hier")?;
        let fine = self.recorder.is_fine();
        let sim_before = self.sim_time_s;
        if self.streaming.is_some() {
            // Streamed leg: replaces the flat step it supersedes (hop 0
            // on the intra ring for m > 1, the leader uplink on the
            // inter star for m == 1 — the superseded step's trace entry
            // is zero). Cost = slowest worker's pipeline recurrence over
            // its section frames; zero-byte rows are non-senders (the
            // root, single-worker runs) gated only on readiness.
            let class =
                if self.group_size > 1 { EdgeClass::Intra } else { EdgeClass::Inter };
            let link = self.links.link(class);
            let mut leg = 0.0f64;
            for tr in &traces {
                let mut end = 0.0f64;
                for &(ready, bytes) in &tr.stream {
                    end = end.max(ready);
                    if bytes > 0 {
                        end += link.transfer_time(bytes);
                        match class {
                            EdgeClass::Intra => &mut self.meter_intra,
                            EdgeClass::Inter => &mut self.meter_inter,
                        }
                        .record_up(link, bytes);
                    }
                }
                leg = leg.max(end);
            }
            if fine && leg > 0.0 {
                let t = crate::obs::Track::Coordinator;
                self.recorder.begin_sim(t, "hier_stream_leg", self.sim_time_s);
                self.recorder.end_sim(t, "hier_stream_leg", self.sim_time_s + leg);
            }
            self.sim_time_s += leg;
        }
        // Synchronous-step critical path on the global grid: nodes
        // transmit concurrently within a step, steps serialize. A zero
        // entry means "silent this step" and contributes no latency.
        for k in 0..steps {
            let class = self.step_class(k);
            let mut step = 0.0f64;
            for tr in &traces {
                let bytes = tr.step_bytes[k];
                if bytes == 0 {
                    continue;
                }
                step = step.max(self.links.transfer_time(class, bytes));
                let meter = match class {
                    EdgeClass::Intra => &mut self.meter_intra,
                    EdgeClass::Inter => &mut self.meter_inter,
                };
                // Up through the gather/uplink steps, down for multicasts.
                if k < self.group_size + 1 {
                    meter.record_up(self.links.link(class), bytes);
                } else {
                    meter.record_down(self.links.link(class), bytes);
                }
            }
            if fine && step > 0.0 {
                let m = self.group_size;
                let name = if k + 1 < m {
                    "hier_rs_hop"
                } else if k + 1 == m {
                    "hier_gather"
                } else if k == m {
                    "hier_uplink"
                } else if k == m + 1 {
                    "hier_root_multicast"
                } else {
                    "hier_group_multicast"
                };
                let t = crate::obs::Track::Coordinator;
                self.recorder.begin_sim(t, name, self.sim_time_s);
                self.recorder.end_sim(t, name, self.sim_time_s + step);
            }
            self.sim_time_s += step;
        }
        if self.streaming.is_some() {
            // The streamed closed form *is* the executable recurrence
            // (`hier_streamed_time` mirrors this loop), so the model here
            // is the measured increment: the drift section then checks
            // accounting consistency rather than a re-derivation.
            self.model_time_s += self.sim_time_s - sim_before;
        } else {
            let m = self.group_size;
            let quant = traces.iter().map(|tr| tr.msg_bytes).max().unwrap_or(0);
            let down = traces
                .iter()
                .map(|tr| tr.step_bytes[m + 1].max(tr.step_bytes[m + 2]))
                .max()
                .unwrap_or(0);
            self.model_time_s += hier_time(&self.links, l, self.workers / m, quant, down);
        }
        let mean = self
            .mean_rx
            .recv()
            .map_err(|_| Error::Comm("hier root died before reporting the mean".into()))?;
        mean_out.clear();
        mean_out.extend_from_slice(&mean);
        Ok(())
    }

    fn stats(&self) -> CommStats {
        CommStats {
            wire_bytes: self.meter_intra.total_bytes() + self.meter_inter.total_bytes(),
            wire_bytes_intra: self.meter_intra.total_bytes(),
            wire_bytes_inter: self.meter_inter.total_bytes(),
            wire_bytes_up: self.meter_intra.bytes_up + self.meter_inter.bytes_up,
            wire_bytes_down: self.meter_intra.bytes_down + self.meter_inter.bytes_down,
            sim_time_s: self.sim_time_s,
            model_time_s: self.model_time_s,
            messages: self.meter_intra.messages + self.meter_inter.messages,
            staleness: Default::default(),
        }
    }
}

/// Worker end. All scratch (decoded gradient, chunk accumulator, group
/// sum, root reduction slots, requantization state, decode scratch) is
/// reused across rounds.
pub struct HierWorker {
    id: usize,
    workers: usize,
    groups: usize,
    group_size: usize,
    group: usize,
    member: usize,
    ring_tx: Sender<Vec<u8>>,
    ring_rx: Receiver<Vec<u8>>,
    gather_tx: Option<Sender<(usize, Vec<u8>)>>,
    gather_rx: Option<Receiver<(usize, Vec<u8>)>>,
    up_tx: Option<Sender<(usize, Vec<u8>)>>,
    up_rx: Option<Receiver<(usize, Vec<u8>)>>,
    down_txs: Vec<Sender<Vec<u8>>>,
    down_rx: Option<Receiver<Vec<u8>>>,
    bcast_txs: Vec<Sender<Vec<u8>>>,
    bcast_rx: Option<Receiver<Vec<u8>>>,
    trace_tx: Sender<RoundTrace>,
    mean_tx: Option<Sender<Vec<f32>>>,
    codec: GradCodec,
    /// Per-site error-feedback residuals (empty/`None` when EF is off,
    /// the codec is FP, or this worker doesn't own the site): intra
    /// reduce-scatter hop `k`, the member gather encode, the leader
    /// uplink encode, and the root's quantized mean downlink.
    hop_ef: Vec<ErrorFeedback>,
    gather_ef: Option<ErrorFeedback>,
    up_ef: Option<ErrorFeedback>,
    down_ef: Option<ErrorFeedback>,
    quantize_downlink: bool,
    rng: Rng,
    rng_down: Rng,
    own: Vec<f32>,
    chunk: Vec<f32>,
    group_sum: Vec<f32>,
    chunk_filled: Vec<bool>,
    acc: Vec<f64>,
    slots: Vec<Vec<f32>>,
    slot_filled: Vec<bool>,
    qg: QuantizedGrad,
    msg: Vec<u8>,
    step_bytes: Vec<usize>,
    /// `Some(nsec)` = streamed rounds (see the module docs).
    streaming: Option<usize>,
    /// Round counter stamped into / validated against section frames.
    round: u64,
    /// Streamed layout learned in round 0: element count per section.
    sec_lens: Vec<usize>,
    /// This round's staged section messages, indexed by section.
    sec_bufs: Vec<Vec<u8>>,
    /// Readiness stamp of each staged section.
    sec_ready: Vec<f64>,
    /// Sections in push (send-schedule) order.
    sec_order: Vec<usize>,
    /// Per-frame (readiness, frame bytes) trace rows, in send order.
    stream_rows: Vec<(f64, usize)>,
    /// The round's reassembled flat message (concat of all sections).
    flat_msg: Vec<u8>,
    /// Encoded upload size of the current flat round (0 when streamed) —
    /// the `quant_bytes` input of the coordinator's [`hier_time`] model.
    last_msg_bytes: usize,
    /// Width table captured from the latest incoming intra-ring hop
    /// message (budgeted rounds): the widths its requantization — and,
    /// after the final hop, the member→leader gather encode — must
    /// reproduce. Read from the frame, never derived locally.
    wscratch: Vec<u8>,
    /// Full-gradient width table captured from this worker's own encoded
    /// upload (`wfull_has` = one was present): every worker carries the
    /// identical table on budgeted rounds, and the leader's star uplink
    /// re-encodes the whole group sum at exactly these widths.
    wfull: Vec<u8>,
    wfull_has: bool,
}

impl HierWorker {
    fn hung_up(what: &str) -> Error {
        Error::Comm(format!("hier {what} hung up"))
    }

    /// Decode `msg` into the chunk scratch and verify it matches chunk `c`
    /// of the group grid. Routed through [`GradCodec`] so a parallel
    /// `WireSpec` decodes hop chunks on the worker pool too.
    fn decode_chunk(&mut self, msg: &[u8], c: usize, total: usize) -> Result<()> {
        self.codec.decode_flat_into(msg, &mut self.chunk)?;
        let want = chunk_range(total, self.codec.bucket_size(), self.group_size, c).len();
        if self.chunk.len() != want {
            return Err(Error::Comm(format!(
                "hier chunk {c} decoded to {} elements, expected {want}",
                self.chunk.len()
            )));
        }
        Ok(())
    }

    /// Intra reduce-scatter + gather: leaves the decoded group sum in
    /// `self.group_sum` on leaders; members return after shipping their
    /// completed chunk. For single-member groups the group sum is the
    /// worker's own decoded gradient. In streamed rounds the hop-0 send
    /// already happened as section frames and `hop0` carries the
    /// reassembled predecessor chunk (byte-identical to the flat hop-0
    /// message, so everything from hop 1 on is the flat path).
    fn reduce_group(&mut self, encoded: &[u8], n: usize, hop0: Option<Vec<u8>>) -> Result<()> {
        let m = self.group_size;
        let j = self.member;
        let d = self.codec.bucket_size();
        if m == 1 {
            self.group_sum.clear();
            self.group_sum.extend_from_slice(&self.own);
            return Ok(());
        }

        // ---- reduce-scatter: m−1 hops of decode → add → requantize ----
        let streamed = hop0.is_some();
        let mut incoming = hop0;
        let mut cur = Vec::new();
        if !streamed {
            let r = chunk_range(n, d, m, j);
            codec::slice_elements_into(encoded, r.start, r.end, &mut cur)?;
        }
        let mut last_has_w = false;
        for k in 0..m - 1 {
            if k > 0 || !streamed {
                self.step_bytes[k] = cur.len();
                self.ring_tx.send(cur).map_err(|_| Self::hung_up("ring successor"))?;
                cur = Vec::new();
            }
            let mut msg = match incoming.take() {
                Some(b) => b,
                None => {
                    self.ring_rx.recv().map_err(|_| Self::hung_up("ring predecessor"))?
                }
            };
            let c = ring_sub(j, k + 1, m);
            self.decode_chunk(&msg, c, n)?;
            // Capture the incoming in-band width table (budgeted rounds):
            // this hop's requantization — and, after the final hop, the
            // gather encode of this same chunk — must reproduce it.
            last_has_w = codec::capture_widths(&msg, &mut self.wscratch)?;
            let r = chunk_range(n, d, m, c);
            for (a, v) in self.chunk.iter_mut().zip(&self.own[r]) {
                *a += *v;
            }
            if k + 1 < m - 1 {
                // Requantize the partial sum for the next hop, recycling
                // the received buffer (hop-k residual compensates what the
                // previous round's hop-k encode dropped). The final sum is
                // requantized below for the gather instead.
                let widths = last_has_w.then_some(&self.wscratch[..]);
                match self.hop_ef.get_mut(k) {
                    Some(ef) => self.codec.encode_matched_ef_into(
                        widths,
                        ef,
                        &self.chunk,
                        &mut self.rng,
                        &mut self.qg,
                        &mut msg,
                    )?,
                    None => self.codec.encode_matched_into(
                        widths,
                        &self.chunk,
                        &mut self.rng,
                        &mut self.qg,
                        &mut msg,
                    )?,
                }
                cur = msg;
            } else {
                cur = Vec::new();
            }
        }
        // `self.chunk` now holds the complete group sum of chunk (j+1)%m.
        let c_own = (j + 1) % m;
        if j != 0 {
            // ---- gather: ship the completed chunk to the leader, at the
            // widths of the final hop's incoming message (that message
            // covered exactly this chunk) ----
            let widths = last_has_w.then_some(&self.wscratch[..]);
            match &mut self.gather_ef {
                Some(ef) => self.codec.encode_matched_ef_into(
                    widths,
                    ef,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut self.msg,
                )?,
                None => self.codec.encode_matched_into(
                    widths,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut self.msg,
                )?,
            }
            self.step_bytes[m - 1] = self.msg.len();
            let bytes = std::mem::take(&mut self.msg);
            self.gather_tx
                .as_ref()
                .expect("members hold the gather sender")
                .send((c_own, bytes))
                .map_err(|_| Self::hung_up("group leader"))?;
            return Ok(());
        }
        // ---- leader: assemble the group sum ----
        self.group_sum.clear();
        self.group_sum.resize(n, 0.0);
        let r = chunk_range(n, d, m, c_own);
        self.group_sum[r].copy_from_slice(&self.chunk);
        self.chunk_filled.clear();
        self.chunk_filled.resize(m, false);
        self.chunk_filled[c_own] = true;
        let rx = self.gather_rx.take().expect("leaders hold the gather receiver");
        let res = (|| -> Result<()> {
            for _ in 0..m - 1 {
                let (c, bytes) = rx.recv().map_err(|_| Self::hung_up("group member"))?;
                if c >= m || self.chunk_filled[c] {
                    return Err(Error::Comm(format!("unexpected gather chunk {c}")));
                }
                self.chunk_filled[c] = true;
                self.decode_chunk(&bytes, c, n)?;
                let r = chunk_range(n, d, m, c);
                self.group_sum[r].copy_from_slice(&self.chunk);
            }
            Ok(())
        })();
        self.gather_rx = Some(rx);
        res
    }

    /// Root: seed slot 0 with the own group sum and reset the fill map.
    fn root_init_slots(&mut self) {
        let g_count = self.groups;
        self.slots.resize_with(g_count, Vec::new);
        self.slot_filled.clear();
        self.slot_filled.resize(g_count, false);
        self.slots[0].clear();
        self.slots[0].extend_from_slice(&self.group_sum);
        self.slot_filled[0] = true;
    }

    /// Root: reduce all group sums in group order (f64), write the global
    /// mean, multicast it FP-encoded down the star.
    fn root_reduce_and_broadcast(&mut self, n: usize, mean_out: &mut Vec<f32>) -> Result<()> {
        let g_count = self.groups;
        self.root_init_slots();
        if g_count > 1 {
            let rx = self.up_rx.take().expect("root holds the uplink receiver");
            let res = (|| -> Result<()> {
                for _ in 0..g_count - 1 {
                    let (g, bytes) = rx.recv().map_err(|_| Self::hung_up("group leader"))?;
                    if g >= g_count || self.slot_filled[g] {
                        return Err(Error::Comm(format!("unexpected leader upload from group {g}")));
                    }
                    self.slot_filled[g] = true;
                    self.codec.decode_flat_into(&bytes, &mut self.slots[g])?;
                    if self.slots[g].len() != n {
                        return Err(Error::Shape(format!(
                            "group {g} sum has {} elements, expected {n}",
                            self.slots[g].len()
                        )));
                    }
                }
                Ok(())
            })();
            self.up_rx = Some(rx);
            res?;
        }
        self.root_finish(n, mean_out)
    }

    /// Root tail shared by the flat and streamed paths: f64-reduce the
    /// filled slots in group order, encode the mean once, multicast.
    fn root_finish(&mut self, n: usize, mean_out: &mut Vec<f32>) -> Result<()> {
        self.acc.clear();
        self.acc.resize(n, 0.0);
        for slot in &self.slots {
            for (a, v) in self.acc.iter_mut().zip(slot) {
                *a += *v as f64;
            }
        }
        let inv = 1.0 / self.workers as f64;
        mean_out.clear();
        mean_out.extend(self.acc.iter().map(|a| (*a * inv) as f32));
        // Encode the mean ONCE; every node (this root included) decodes
        // the exact same bytes, so the applied mean is bit-identical
        // cluster-wide whether the downlink is lossless FP or requantized
        // (`quantize_downlink`, optionally EF-compensated at the root).
        let lossy_down = self.quantize_downlink && !self.codec.is_fp() && !mean_out.is_empty();
        if lossy_down {
            match &mut self.down_ef {
                Some(ef) => self.codec.encode_ef_into(
                    ef,
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
                None => self.codec.encode_into(
                    mean_out,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.msg,
                ),
            }
        } else {
            codec::encode_fp_into(mean_out, &mut self.msg);
        }
        let m = self.group_size;
        if !self.down_txs.is_empty() {
            self.step_bytes[m + 1] = self.msg.len();
            for tx in &self.down_txs {
                tx.send(self.msg.clone()).map_err(|_| Self::hung_up("group leader"))?;
            }
        }
        if !self.bcast_txs.is_empty() {
            self.step_bytes[m + 2] = self.msg.len();
            for tx in &self.bcast_txs {
                tx.send(self.msg.clone()).map_err(|_| Self::hung_up("group member"))?;
            }
        }
        if lossy_down {
            // Lossy downlink: the root must apply its own decoded bytes,
            // not the exact mean, to stay bit-identical with the leaves.
            let HierWorker { codec, msg, .. } = self;
            codec.decode_flat_into(msg, mean_out)?;
        }
        Ok(())
    }

    fn finish_round(&mut self, mean: &[f32]) -> Result<()> {
        let trace = RoundTrace {
            worker: self.id,
            step_bytes: std::mem::take(&mut self.step_bytes),
            stream: std::mem::take(&mut self.stream_rows),
            msg_bytes: std::mem::take(&mut self.last_msg_bytes),
        };
        self.trace_tx.send(trace).map_err(|_| Self::hung_up("coordinator"))?;
        if let Some(tx) = &self.mean_tx {
            tx.send(mean.to_vec()).map_err(|_| Self::hung_up("coordinator"))?;
        }
        Ok(())
    }

    // ----------------------------------------------------------------
    // Streaming
    // ----------------------------------------------------------------

    /// Ship one staged section onto this worker's streamed leg and record
    /// its trace row. `m > 1`: the (section ∩ own hop-0 chunk) slice —
    /// possibly empty, empties keep the frame count in lockstep and
    /// concat back to nothing — on the intra ring. `m == 1`, non-root
    /// leader: the whole section up the star. Root / single worker: no
    /// wire leg, a zero-byte row gated only on readiness.
    fn send_streamed_frames(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let m = self.group_size;
        if m == 1 && (self.group == 0 || self.workers == 1) {
            self.stream_rows.push((ready_s, 0));
            return Ok(());
        }
        let mut frame = Vec::new();
        begin_frame_into(
            FrameKind::Section,
            self.round,
            section as u16,
            self.id as u16,
            &mut frame,
        );
        frame.extend_from_slice(&ready_s.to_le_bytes());
        if m > 1 {
            let n: usize = self.sec_lens.iter().sum();
            let sec_start: usize = self.sec_lens[..section].iter().sum();
            let sec_end = sec_start + self.sec_lens[section];
            let r = chunk_range(n, self.codec.bucket_size(), m, self.member);
            let lo = r.start.max(sec_start).min(sec_end);
            let hi = r.end.min(sec_end).max(sec_start);
            codec::slice_elements_append(payload, lo - sec_start, hi - sec_start, &mut frame)?;
            finish_frame(&mut frame);
            self.stream_rows.push((ready_s, frame.len()));
            self.ring_tx.send(frame).map_err(|_| Self::hung_up("ring successor"))?;
        } else {
            frame.extend_from_slice(payload);
            finish_frame(&mut frame);
            self.stream_rows.push((ready_s, frame.len()));
            self.up_tx
                .as_ref()
                .expect("non-root leaders hold the uplink sender")
                .send((self.group, frame))
                .map_err(|_| Self::hung_up("root"))?;
        }
        Ok(())
    }

    /// Validate an incoming section frame against this round and return
    /// its section index (stamp checked, then discarded — timing is the
    /// coordinator's job).
    fn check_section_frame(&self, bytes: &[u8], nsec: usize, sender: usize) -> Result<usize> {
        let f = parse_frame(bytes)?;
        if f.kind != FrameKind::Section {
            return Err(Error::Comm(format!(
                "hier expected a section frame, got {:?}",
                f.kind
            )));
        }
        if f.round != self.round {
            return Err(Error::Comm(format!(
                "hier section frame for round {}, expected round {}",
                f.round, self.round
            )));
        }
        if f.sender as usize != sender {
            return Err(Error::Comm(format!(
                "hier section frame from worker {}, expected worker {sender}",
                f.sender
            )));
        }
        let sec = f.slot as usize;
        if sec >= nsec {
            return Err(Error::Comm(format!(
                "hier section {sec} out of range ({nsec} sections)"
            )));
        }
        split_section_payload(f.payload)?;
        Ok(sec)
    }

    /// Concatenate the inner messages of per-section frames (ascending
    /// sections, empties dropped) into one flat message — byte-identical
    /// to slicing the sender's flat encode over the union range.
    fn concat_section_frames(frames: &[Vec<u8>], out: &mut Vec<u8>) -> Result<()> {
        let mut parts: Vec<&[u8]> = Vec::with_capacity(frames.len());
        for b in frames {
            let msg = &b[SECTION_MSG_OFFSET..];
            let (total, _) = codec::peek_shape(msg)?;
            if total > 0 {
                parts.push(msg);
            }
        }
        match parts.is_empty() {
            // All-empty (a chunk grid finer than the gradient): an empty
            // slice of any part keeps the scheme/bucket framing.
            true => codec::slice_elements_into(
                &frames[0][SECTION_MSG_OFFSET..],
                0,
                0,
                out,
            ),
            false => codec::concat_messages_into(&parts, out),
        }
    }

    /// `m > 1`: receive the predecessor's `nsec` hop-0 section frames and
    /// reassemble the flat chunk message the flat round would have sent.
    fn recv_hop0_sections(&mut self, nsec: usize) -> Result<Vec<u8>> {
        let m = self.group_size;
        let pred = self.group * m + (self.member + m - 1) % m;
        let mut bufs: Vec<Option<Vec<u8>>> = (0..nsec).map(|_| None).collect();
        for _ in 0..nsec {
            let bytes = self.ring_rx.recv().map_err(|_| Self::hung_up("ring predecessor"))?;
            let sec = self.check_section_frame(&bytes, nsec, pred)?;
            if bufs[sec].is_some() {
                return Err(Error::Comm(format!(
                    "duplicate hop-0 section {sec} from worker {pred}"
                )));
            }
            bufs[sec] = Some(bytes);
        }
        let frames: Vec<Vec<u8>> =
            bufs.into_iter().map(|b| b.expect("one frame per section")).collect();
        let mut out = Vec::new();
        Self::concat_section_frames(&frames, &mut out)?;
        Ok(out)
    }

    /// Root, `m == 1`: collect `nsec` section frames from every non-root
    /// leader, reassemble each group's original flat message, decode into
    /// the reduction slots (identical bytes to the flat star's verbatim
    /// forwards, so the reduction is bit-identical).
    fn root_collect_sections(&mut self, nsec: usize, n: usize) -> Result<()> {
        let g_count = self.groups;
        self.root_init_slots();
        if g_count == 1 {
            return Ok(());
        }
        let rx = self.up_rx.take().expect("root holds the uplink receiver");
        let res = (|| -> Result<()> {
            let mut bufs: Vec<Option<Vec<u8>>> = (0..g_count * nsec).map(|_| None).collect();
            for _ in 0..(g_count - 1) * nsec {
                let (g, bytes) = rx.recv().map_err(|_| Self::hung_up("group leader"))?;
                if g == 0 || g >= g_count {
                    return Err(Error::Comm(format!(
                        "unexpected leader upload from group {g}"
                    )));
                }
                // m == 1 ⇒ group g's leader is worker g · 1 = g.
                let sec = self.check_section_frame(&bytes, nsec, g * self.group_size)?;
                if bufs[g * nsec + sec].is_some() {
                    return Err(Error::Comm(format!(
                        "duplicate section {sec} from group {g}"
                    )));
                }
                bufs[g * nsec + sec] = Some(bytes);
            }
            let mut cat = Vec::new();
            for g in 1..g_count {
                let frames: Vec<Vec<u8>> = bufs[g * nsec..(g + 1) * nsec]
                    .iter_mut()
                    .map(|b| b.take().expect("one frame per (group, section)"))
                    .collect();
                Self::concat_section_frames(&frames, &mut cat)?;
                self.slot_filled[g] = true;
                self.codec.decode_flat_into(&cat, &mut self.slots[g])?;
                if self.slots[g].len() != n {
                    return Err(Error::Shape(format!(
                        "group {g} sum has {} elements, expected {n}",
                        self.slots[g].len()
                    )));
                }
            }
            Ok(())
        })();
        self.up_rx = Some(rx);
        res
    }

    /// The streamed round body: the flat [`Self::exchange`] with the
    /// first wire leg replaced by the section frames already in flight.
    fn run_streamed_round(&mut self, nsec: usize, mean_out: &mut Vec<f32>) -> Result<()> {
        let m = self.group_size;
        // Reassemble this worker's flat message from its staged sections
        // (byte-identical to the flat encode) and decode the gradient.
        {
            let parts: Vec<&[u8]> = self.sec_bufs.iter().map(|b| b.as_slice()).collect();
            codec::concat_messages_into(&parts, &mut self.flat_msg)?;
        }
        let HierWorker { codec, flat_msg, own, .. } = &mut *self;
        codec.decode_flat_into(flat_msg, own)?;
        self.wfull_has = codec::capture_widths(&self.flat_msg, &mut self.wfull)?;
        let n = self.own.len();
        mean_out.clear();
        self.step_bytes.clear();
        self.step_bytes.resize(m + 3, 0);

        if self.workers == 1 {
            mean_out.extend_from_slice(&self.own);
            return self.finish_round(mean_out);
        }

        let hop0 = (m > 1).then(|| self.recv_hop0_sections(nsec)).transpose()?;
        self.reduce_group(&[], n, hop0)?;

        if self.member == 0 && self.group != 0 && m > 1 {
            // ---- leader uplink over the slow star (flat-accounted; the
            // m == 1 uplink was already streamed section by section) ----
            let HierWorker { codec, up_ef, group_sum, rng, qg, msg, wfull, wfull_has, .. } = self;
            let widths = (*wfull_has).then_some(&wfull[..]);
            match up_ef {
                Some(ef) => codec.encode_matched_ef_into(widths, ef, group_sum, rng, qg, msg)?,
                None => codec.encode_matched_into(widths, group_sum, rng, qg, msg)?,
            }
            self.step_bytes[m] = self.msg.len();
            let bytes = std::mem::take(&mut self.msg);
            self.up_tx
                .as_ref()
                .expect("non-root leaders hold the uplink sender")
                .send((self.group, bytes))
                .map_err(|_| Self::hung_up("root"))?;
        }

        if self.id == 0 {
            if m == 1 {
                self.root_collect_sections(nsec, n)?;
                self.root_finish(n, mean_out)?;
            } else {
                self.root_reduce_and_broadcast(n, mean_out)?;
            }
        } else {
            let rx = if self.member == 0 {
                self.down_rx.take().expect("non-root leaders hold the star downlink")
            } else {
                self.bcast_rx.take().expect("members hold the group broadcast inbox")
            };
            let res = rx.recv().map_err(|_| {
                Self::hung_up(if self.member == 0 { "root" } else { "group leader" })
            });
            if self.member == 0 {
                self.down_rx = Some(rx);
            } else {
                self.bcast_rx = Some(rx);
            }
            let bytes = res?;
            if self.member == 0 && !self.bcast_txs.is_empty() {
                self.step_bytes[m + 2] = bytes.len();
                for tx in &self.bcast_txs {
                    tx.send(bytes.clone()).map_err(|_| Self::hung_up("group member"))?;
                }
            }
            self.codec.decode_flat_into(&bytes, mean_out)?;
        }
        if mean_out.len() != n {
            return Err(Error::Shape(format!(
                "hier mean has {} elements, worker {} contributed {n}",
                mean_out.len(),
                self.id
            )));
        }
        self.finish_round(mean_out)
    }
}

impl WorkerExchange for HierWorker {
    fn id(&self) -> usize {
        self.id
    }

    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()> {
        if self.streaming.is_some() {
            return Err(Error::InvalidArg(
                "this hier exchange streams sections; use push_section/finish_streamed".into(),
            ));
        }
        let m = self.group_size;
        self.codec.decode_flat_into(encoded, &mut self.own)?;
        // Budgeted rounds: remember the full-gradient width table for the
        // leader's star uplink re-encode (identical on every worker, and
        // still read from an encoded frame — this worker's own upload).
        self.wfull_has = codec::capture_widths(encoded, &mut self.wfull)?;
        let n = self.own.len();
        mean_out.clear();
        self.step_bytes.clear();
        self.step_bytes.resize(m + 3, 0);
        self.last_msg_bytes = encoded.len();

        if self.workers == 1 {
            // Nothing to exchange: the mean of one contribution is itself.
            mean_out.extend_from_slice(&self.own);
            return self.finish_round(mean_out);
        }

        self.reduce_group(encoded, n, None)?;

        if self.member == 0 && self.group != 0 {
            // ---- leader uplink over the slow star ----
            if m == 1 {
                // Single-member group: forward the original encoded bytes
                // verbatim — no spurious extra quantization (and nothing
                // to error-compensate).
                self.msg.clear();
                self.msg.append(encoded);
            } else {
                let HierWorker { codec, up_ef, group_sum, rng, qg, msg, wfull, wfull_has, .. } =
                    self;
                let widths = (*wfull_has).then_some(&wfull[..]);
                match up_ef {
                    Some(ef) => codec.encode_matched_ef_into(widths, ef, group_sum, rng, qg, msg)?,
                    None => codec.encode_matched_into(widths, group_sum, rng, qg, msg)?,
                }
            }
            self.step_bytes[m] = self.msg.len();
            let bytes = std::mem::take(&mut self.msg);
            self.up_tx
                .as_ref()
                .expect("non-root leaders hold the uplink sender")
                .send((self.group, bytes))
                .map_err(|_| Self::hung_up("root"))?;
        }

        if self.id == 0 {
            self.root_reduce_and_broadcast(n, mean_out)?;
        } else {
            // Leaders wait on the root's star downlink, members on their
            // leader's group broadcast.
            let rx = if self.member == 0 {
                self.down_rx.take().expect("non-root leaders hold the star downlink")
            } else {
                self.bcast_rx.take().expect("members hold the group broadcast inbox")
            };
            let res = rx.recv().map_err(|_| {
                Self::hung_up(if self.member == 0 { "root" } else { "group leader" })
            });
            if self.member == 0 {
                self.down_rx = Some(rx);
            } else {
                self.bcast_rx = Some(rx);
            }
            let bytes = res?;
            // Leaders re-multicast the identical bytes into their group.
            if self.member == 0 && !self.bcast_txs.is_empty() {
                self.step_bytes[m + 2] = bytes.len();
                for tx in &self.bcast_txs {
                    tx.send(bytes.clone()).map_err(|_| Self::hung_up("group member"))?;
                }
            }
            self.codec.decode_flat_into(&bytes, mean_out)?;
            // Recycle the broadcast allocation as the caller's next encode
            // buffer (the PS convention) — keeps steady-state rounds free
            // of full-gradient reallocations.
            *encoded = bytes;
        }
        if mean_out.len() != n {
            return Err(Error::Shape(format!(
                "hier mean has {} elements, worker {} contributed {n}",
                mean_out.len(),
                self.id
            )));
        }
        self.finish_round(mean_out)
    }

    fn push_section(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this hier exchange was not built for streaming".into(),
            ));
        };
        if section >= nsec {
            return Err(Error::InvalidArg(format!(
                "section {section} out of range ({nsec} sections)"
            )));
        }
        if !ready_s.is_finite() || ready_s < 0.0 {
            return Err(Error::InvalidArg(format!(
                "readiness stamp must be finite and non-negative, got {ready_s}"
            )));
        }
        if self.sec_bufs.is_empty() {
            self.sec_bufs.resize_with(nsec, Vec::new);
            self.sec_ready.resize(nsec, 0.0);
        }
        if self.sec_order.contains(&section) {
            return Err(Error::InvalidArg(format!(
                "duplicate section {section} staged this round"
            )));
        }
        self.sec_bufs[section].clear();
        self.sec_bufs[section].extend_from_slice(payload);
        self.sec_ready[section] = ready_s;
        self.sec_order.push(section);
        if !self.sec_lens.is_empty() {
            // Layout known (round ≥ 1): put the frame on the wire now.
            let (len, _) = codec::peek_shape(payload)?;
            if len != self.sec_lens[section] {
                return Err(Error::Shape(format!(
                    "section {section} has {len} elements, round 0 had {}",
                    self.sec_lens[section]
                )));
            }
            self.send_streamed_frames(section, payload, ready_s)?;
        }
        Ok(())
    }

    fn finish_streamed(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this hier exchange was not built for streaming".into(),
            ));
        };
        if self.sec_order.len() != nsec {
            return Err(Error::InvalidArg(format!(
                "round staged {} sections, expected {nsec}",
                self.sec_order.len()
            )));
        }
        if self.sec_lens.is_empty() {
            // Round 0: learn the layout, then flush the parked frames in
            // their send-schedule order.
            let mut lens = Vec::with_capacity(nsec);
            for b in &self.sec_bufs {
                let (total, _) = codec::peek_shape(b)?;
                lens.push(total);
            }
            self.sec_lens = lens;
            let order = std::mem::take(&mut self.sec_order);
            for &sec in &order {
                let payload = std::mem::take(&mut self.sec_bufs[sec]);
                let ready = self.sec_ready[sec];
                self.send_streamed_frames(sec, &payload, ready)?;
                self.sec_bufs[sec] = payload;
            }
            self.sec_order = order;
        }
        let res = self.run_streamed_round(nsec, mean_out);
        self.sec_order.clear();
        self.round += 1;
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::link::Link;

    fn links(intra_bw: f64, inter_bw: f64) -> LinkMap {
        LinkMap::new(Link::new(intra_bw, 0.0), Link::new(inter_bw, 0.0))
    }

    #[test]
    fn hier_time_edge_cases() {
        let lm = LinkMap::uniform(Link::ten_gbps());
        assert_eq!(hier_time(&lm, 1, 1, 1 << 20, 1 << 22), 0.0);
        // groups == workers: star only — quantized up + fp down.
        let t = hier_time(&lm, 4, 4, 1000, 4000);
        let want = lm.inter.transfer_time(1000) + lm.inter.transfer_time(4000);
        assert!((t - want).abs() < 1e-15);
        // one group: intra ring + gather + intra multicast, no star.
        let t = hier_time(&lm, 4, 1, 4000, 16000);
        let want = 4.0 * lm.intra.transfer_time(1000) + lm.intra.transfer_time(16000);
        assert!((t - want).abs() < 1e-12);
    }

    #[test]
    fn hier_beats_flat_star_on_slow_inter_links() {
        // 8 workers, fast 100 Gbps racks, slow 1 Gbps cross-rack: the
        // hierarchy sends 2 cross-rack gradients instead of 8 uplinks.
        let lm = links(100e9, 1e9);
        let q = 1 << 20; // quantized gradient bytes
        let fp = 1 << 22; // fp mean bytes
        let hier = hier_time(&lm, 8, 2, q, fp);
        // flat PS on the same cluster: every edge is inter-class.
        let ps = lm.inter.transfer_time(q) + lm.inter.transfer_time(fp);
        // PS pays max-of-8-uplinks + broadcast just like 1 uplink here, so
        // the hierarchy cannot beat the *time* model of an idealized
        // multicast star — but it must stay in the same ballpark while
        // moving most bytes onto intra edges (asserted in the equivalence
        // tests). Sanity: hier is within 2× of flat PS on this cluster.
        assert!(hier < ps * 2.0, "hier={hier} ps={ps}");
        // And with latency-free fat intra pipes, shrinking inter traffic
        // helps: compare against a PS whose uplinks serialize (worst case).
        let ps_serial = 8.0 * lm.inter.transfer_time(q) + lm.inter.transfer_time(fp);
        assert!(hier < ps_serial, "hier={hier} ps_serial={ps_serial}");
    }

    #[test]
    fn new_rejects_bad_grouping() {
        let lm = LinkMap::uniform(Link::ten_gbps());
        let spec = WireSpec::new("terngrad", 64);
        assert!(HierarchicalCollective::new(0, 1, lm, &spec, false, false, None).is_err());
        assert!(HierarchicalCollective::new(4, 0, lm, &spec, false, false, None).is_err());
        assert!(HierarchicalCollective::new(4, 3, lm, &spec, false, false, None).is_err());
        assert!(HierarchicalCollective::new(4, 2, lm, &spec, false, false, None).is_ok());
        assert!(HierarchicalCollective::new(4, 4, lm, &spec, false, false, None).is_ok());
        assert!(HierarchicalCollective::new(4, 1, lm, &spec, false, false, None).is_ok());
        assert!(HierarchicalCollective::new(4, 2, lm, &spec, true, true, None).is_ok());
        assert!(HierarchicalCollective::new(4, 2, lm, &spec, false, false, Some(3)).is_ok());
        let bad = WireSpec::new("bogus", 64);
        assert!(HierarchicalCollective::new(2, 1, lm, &bad, false, false, None).is_err());
    }

    /// Codec-routed decodes (hop chunks, gathered chunks, leader
    /// uploads, own gradient, fp mean) through the parallel pipeline:
    /// deterministic decode + thread-count-invariant per-bucket encode
    /// streams ⇒ the cluster-wide mean matches bit for bit across every
    /// parallel thread count, for every grouping, quantized and fp.
    #[test]
    fn hier_mean_bit_identical_across_decode_thread_counts() {
        use super::super::collective::{run_once, ExchangeConfig};
        let workers = 4;
        let n = 1000; // ragged final bucket on the 64 grid
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|w| {
                (0..n)
                    .map(|i| ((i * 37 + w * 101) % 997) as f32 / 997.0 - 0.5)
                    .collect()
            })
            .collect();
        for method in ["terngrad", "fp"] {
            for groups in [1usize, 2, 4] {
                let cfg = ExchangeConfig::hier(groups, LinkMap::uniform(Link::ten_gbps()));
                let mut reference: Option<Vec<f32>> = None;
                for threads in [2usize, 3, 4] {
                    let spec = WireSpec::new(method, 64).with_threads(threads);
                    let (mean, _) = run_once(&cfg, &spec, &grads).unwrap();
                    assert_eq!(mean.len(), n);
                    match &reference {
                        None => reference = Some(mean),
                        Some(r) => assert_eq!(
                            r, &mean,
                            "{method} hier mean (groups={groups}) diverged at {threads} threads"
                        ),
                    }
                }
            }
        }
    }

    #[test]
    fn step_grid_classes() {
        let lm = LinkMap::uniform(Link::ten_gbps());
        let spec = WireSpec::new("fp", 64);
        let (coll, _ends) =
            HierarchicalCollective::new(6, 2, lm, &spec, false, false, None).unwrap();
        // m = 3: steps 0,1 = RS, 2 = gather (intra); 3,4 = star (inter);
        // 5 = group multicast (intra).
        assert_eq!(coll.step_class(0), EdgeClass::Intra);
        assert_eq!(coll.step_class(2), EdgeClass::Intra);
        assert_eq!(coll.step_class(3), EdgeClass::Inter);
        assert_eq!(coll.step_class(4), EdgeClass::Inter);
        assert_eq!(coll.step_class(5), EdgeClass::Intra);
    }
}
